"""Benchmark harness entry point (deliverable d): one module per paper
table/figure.  Prints ``name,us_per_call,derived`` CSV.

  python -m benchmarks.run [--only impossibility,pareto,...]
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = [
    "impossibility",   # Thm 3.4 ratio table
    "dp_scaling",      # Thm 4.5 / 5.2 preprocessing complexity
    "policy_latency",  # Thm 4.5 O(1)/node inference cost
    "ifstop",          # Fig. 8 if-stop matrices (synthetic)
    "pareto",          # Figs. 4-5 accuracy-latency frontiers
    "dag",             # §5 skip/tree value + optimality-gap
    "serving",         # engine-level EE savings (§6 serving analogue)
    "runtime",         # continuous-batching goodput / lane recycling
    "roofline",        # EXPERIMENTS.md §Roofline (reads dryrun JSONs)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    todo = args.only.split(",") if args.only else MODULES
    unknown = sorted(set(todo) - set(MODULES))
    if unknown:
        ap.error(f"unknown benchmark(s): {', '.join(unknown)} "
                 f"(choose from: {', '.join(MODULES)})")

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in todo:
        try:
            mod = importlib.import_module(f"benchmarks.bench_{mod_name}")
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}",
                      flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"bench_{mod_name},-1,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
