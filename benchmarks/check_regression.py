"""Perf-trajectory guardrail: compare a freshly produced
``BENCH_runtime.json`` against the committed snapshot and FAIL on a
goodput regression at matching (rate, strategy, kv, prefill) points.

Rows are matched by their stable ``name`` (which encodes the sweep
point) and cross-checked on the axis fields (rate/strategy/kv/prefill/
cascade/adaptive), so a renamed or re-scoped row never silently
compares apples to oranges.  Besides goodput, rows carrying the v6
``regret_mean`` decision-quality axis are guarded the same way — a
>20% regret worsening on a deterministic sim row fails (with an
absolute epsilon floor, since the recall legs sit at exactly zero
where relative change is meaningless).  Two thresholds:

  * virtual-clock rows (``kv == "sim"``) are DETERMINISTIC — seeded
    workloads, virtual time — so any drop beyond ``--max-drop``
    (default 20%) is a real scheduling/cost regression, not noise;
  * wall-clock rows (the real-model runs) breathe with the runner —
    the committed baseline may come from a different machine entirely —
    so they WARN above ``--max-drop`` and never fail unless an
    explicit ``--max-drop-wall`` threshold is opted into (e.g. on a
    dedicated perf box where the baseline is same-hardware).

Usage (what CI runs after regenerating the snapshot):

    git show HEAD:BENCH_runtime.json > /tmp/bench-committed.json
    python -m benchmarks.check_regression /tmp/bench-committed.json \
        BENCH_runtime.json
"""

from __future__ import annotations

import argparse
import json
import sys

AXES = ("rate", "strategy", "kv", "prefill", "cascade", "adaptive")
# regret guard floor: below this absolute regret a row counts as "at
# zero" (the recall legs), where relative worsening is meaningless —
# crossing the floor from ~0 upward is what fails
REGRET_EPS = 1e-3


def compare(old: dict, new: dict, *, max_drop: float = 0.20,
            max_drop_wall: float | None = None):
    """Returns (failures, warnings, n_checked) comparing goodput per
    matching row.  Rows present on only one side are skipped (schema
    evolution is allowed; the guard protects existing points).
    ``max_drop_wall=None`` (the default) makes wall-clock rows
    warn-only — they cannot fail a run whose baseline was produced on
    different hardware."""
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    failures: list[str] = []
    warnings: list[str] = []
    checked = 0
    for row in new.get("rows", []):
        ref = old_rows.get(row["name"])
        if ref is None:
            continue
        mismatch = [a for a in AXES
                    if a in ref and ref.get(a) != row.get(a)]
        if mismatch:
            failures.append(
                f"{row['name']}: axis drift on {mismatch} "
                f"(committed {[ref.get(a) for a in mismatch]} vs "
                f"{[row.get(a) for a in mismatch]}) — rename the row "
                "instead of repointing it")
            continue
        wall = row.get("kv") != "sim"
        g_old = ref.get("goodput_tok_s")
        g_new = row.get("goodput_tok_s")
        if g_old and g_new is not None:
            checked += 1
            drop = 1.0 - g_new / g_old
            limit = max_drop_wall if wall else max_drop
            msg = (f"{row['name']}: goodput {g_old:.2f} -> {g_new:.2f} "
                   f"tok/s ({100 * drop:.0f}% drop"
                   f"{', wall-clock' if wall else ''})")
            if limit is not None and drop > limit:
                failures.append(msg)
            elif drop > max_drop:
                warnings.append(msg)
        # decision-quality axis (v6): regret WORSENS upward, so the
        # guarded direction flips.  Only deterministic sim rows can
        # fail, same policy as goodput.
        r_old = ref.get("regret_mean")
        r_new = row.get("regret_mean")
        if r_old is not None and r_new is not None:
            checked += 1
            worse = (r_new - r_old) / max(r_old, REGRET_EPS)
            msg = (f"{row['name']}: regret {r_old:.4f} -> {r_new:.4f} "
                   f"({100 * worse:.0f}% worse"
                   f"{', wall-clock' if wall else ''})")
            if worse > max_drop and r_new > REGRET_EPS:
                if wall:
                    warnings.append(msg)
                else:
                    failures.append(msg)
    return failures, warnings, checked


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("committed", help="the committed BENCH_runtime.json")
    ap.add_argument("fresh", help="the freshly produced snapshot")
    ap.add_argument("--max-drop", type=float, default=0.20,
                    help="max goodput drop for virtual-clock rows")
    ap.add_argument("--max-drop-wall", type=float, default=None,
                    help="opt-in hard limit for wall-clock rows "
                         "(default: warn-only — baselines may come "
                         "from different hardware)")
    args = ap.parse_args()
    with open(args.committed) as f:
        old = json.load(f)
    with open(args.fresh) as f:
        new = json.load(f)
    failures, warnings, checked = compare(
        old, new, max_drop=args.max_drop, max_drop_wall=args.max_drop_wall)
    for msg in warnings:
        print(f"WARN  {msg}")
    for msg in failures:
        print(f"FAIL  {msg}")
    print(f"checked {checked} matching goodput points "
          f"({len(failures)} failures, {len(warnings)} warnings)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
