"""Perf-trajectory guardrail: compare a freshly produced
``BENCH_runtime.json`` against the committed snapshot and FAIL on a
goodput regression at matching (rate, strategy, kv, prefill) points.

Rows are matched by their stable ``name`` (which encodes the sweep
point) and cross-checked on the axis fields (rate/strategy/kv/prefill/
cascade/adaptive), so a renamed or re-scoped row never silently
compares apples to oranges.  Two thresholds:

  * virtual-clock rows (``kv == "sim"``) are DETERMINISTIC — seeded
    workloads, virtual time — so any drop beyond ``--max-drop``
    (default 20%) is a real scheduling/cost regression, not noise;
  * wall-clock rows (the real-model runs) breathe with the runner —
    the committed baseline may come from a different machine entirely —
    so they WARN above ``--max-drop`` and never fail unless an
    explicit ``--max-drop-wall`` threshold is opted into (e.g. on a
    dedicated perf box where the baseline is same-hardware).

Usage (what CI runs after regenerating the snapshot):

    git show HEAD:BENCH_runtime.json > /tmp/bench-committed.json
    python -m benchmarks.check_regression /tmp/bench-committed.json \
        BENCH_runtime.json
"""

from __future__ import annotations

import argparse
import json
import sys

AXES = ("rate", "strategy", "kv", "prefill", "cascade", "adaptive")


def compare(old: dict, new: dict, *, max_drop: float = 0.20,
            max_drop_wall: float | None = None):
    """Returns (failures, warnings, n_checked) comparing goodput per
    matching row.  Rows present on only one side are skipped (schema
    evolution is allowed; the guard protects existing points).
    ``max_drop_wall=None`` (the default) makes wall-clock rows
    warn-only — they cannot fail a run whose baseline was produced on
    different hardware."""
    old_rows = {r["name"]: r for r in old.get("rows", [])}
    failures: list[str] = []
    warnings: list[str] = []
    checked = 0
    for row in new.get("rows", []):
        ref = old_rows.get(row["name"])
        if ref is None:
            continue
        mismatch = [a for a in AXES
                    if a in ref and ref.get(a) != row.get(a)]
        if mismatch:
            failures.append(
                f"{row['name']}: axis drift on {mismatch} "
                f"(committed {[ref.get(a) for a in mismatch]} vs "
                f"{[row.get(a) for a in mismatch]}) — rename the row "
                "instead of repointing it")
            continue
        g_old = ref.get("goodput_tok_s")
        g_new = row.get("goodput_tok_s")
        if not g_old or g_new is None:
            continue
        checked += 1
        drop = 1.0 - g_new / g_old
        wall = row.get("kv") != "sim"
        limit = max_drop_wall if wall else max_drop
        msg = (f"{row['name']}: goodput {g_old:.2f} -> {g_new:.2f} tok/s "
               f"({100 * drop:.0f}% drop"
               f"{', wall-clock' if wall else ''})")
        if limit is not None and drop > limit:
            failures.append(msg)
        elif drop > max_drop:
            warnings.append(msg)
    return failures, warnings, checked


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("committed", help="the committed BENCH_runtime.json")
    ap.add_argument("fresh", help="the freshly produced snapshot")
    ap.add_argument("--max-drop", type=float, default=0.20,
                    help="max goodput drop for virtual-clock rows")
    ap.add_argument("--max-drop-wall", type=float, default=None,
                    help="opt-in hard limit for wall-clock rows "
                         "(default: warn-only — baselines may come "
                         "from different hardware)")
    args = ap.parse_args()
    with open(args.committed) as f:
        old = json.load(f)
    with open(args.fresh) as f:
        new = json.load(f)
    failures, warnings, checked = compare(
        old, new, max_drop=args.max_drop, max_drop_wall=args.max_drop_wall)
    for msg in warnings:
        print(f"WARN  {msg}")
    for msg in failures:
        print(f"FAIL  {msg}")
    print(f"checked {checked} matching goodput points "
          f"({len(failures)} failures, {len(warnings)} warnings)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
