"""CI regret smoke: the decision-quality acceptance gate (DESIGN.md
§15).

Runs the recall and no-recall cascade legs of
`bench_runtime.cascade_vs_monolith` under the `RegretMeter` at a
deterministic seed (virtual clock, SimStepper — no model params,
CI-fast) across a ladder-depth sweep, and asserts the separation
theorem's live-telemetry shadow:

  1. RECALL IS REGRET-FREE: the recall cascade's mean per-request
     regret is ~0 (``RECALL_TOL``) at EVERY ladder depth — serving the
     oracle policy over the same calibrated tables makes realized
     loss meet the offline-optimal walk token for token.
  2. SEPARATION: the no-recall (commit) cascade's mean regret strictly
     exceeds the recall cascade's at every depth — once committed, a
     wrong early exit can never be taken back.
  3. DEPTH GROWTH: the no-recall regret is monotone-increasing as the
     large-model rungs stretch apart (the paper's no-constant-factor
     statement: the price of commitment grows with the ladder, while
     recall stays pinned at zero).

The depth sweep stretches the SPREAD of the large-model ladder
(4.0, 4.0 + 4k, 4.0 + 8k for k in ``DEPTH_KS``) rather than scaling
all depths uniformly — uniform scaling also slows the oracle's own
best walk, which mutes the gap; stretching the spread grows exactly
the part the commit policy forfeits.

Exit code 1 on any violated claim, so the CI job fails loudly.
"""

from __future__ import annotations

import argparse
import json
import sys

RECALL_TOL = 1e-6      # recall regret is exactly 0 by construction
DEPTH_KS = (1.0, 1.5, 2.0)   # large-ladder spread stretch factors
RATE = 2.0             # pre-wall rate: both ladders fully exercised
DURATION = 30.0
VARIANTS = ("cascade_norecall", "cascade_recall")


def _depths(k: float, base) -> tuple:
    """Stretch the large-model ladder spread by ``k`` (small ladder
    and the cheapest large rung stay fixed)."""
    return (base[0], (4.0, 4.0 + 4.0 * k, 4.0 + 8.0 * k))


def check(sweeps: dict[float, dict[str, float]]) -> list[str]:
    """Verify the claims on per-depth mean regrets; returns failure
    messages.  ``sweeps`` maps stretch factor k -> variant -> regret."""
    failures = []
    for k in sorted(sweeps):
        reg = sweeps[k]
        missing = [v for v in VARIANTS if v not in reg]
        if missing:
            failures.append(f"k={k:g}: sweep missing variants {missing}")
            continue
        if reg["cascade_recall"] > RECALL_TOL:
            failures.append(
                f"k={k:g}: recall regret {reg['cascade_recall']:.6f} > "
                f"{RECALL_TOL} — the oracle policy should be regret-free")
        if not reg["cascade_norecall"] > reg["cascade_recall"]:
            failures.append(
                f"k={k:g}: no-recall regret {reg['cascade_norecall']:.6f}"
                f" <= recall {reg['cascade_recall']:.6f} — separation "
                "claim violated")
    ks = sorted(k for k in sweeps if "cascade_norecall" in sweeps[k])
    nr = [sweeps[k]["cascade_norecall"] for k in ks]
    for a, b, ka, kb in zip(nr, nr[1:], ks, ks[1:]):
        if not b > a:
            failures.append(
                f"no-recall regret not monotone in ladder depth: "
                f"{a:.6f} (k={ka:g}) >= {b:.6f} (k={kb:g})")
    return failures


def main() -> int:
    from benchmarks.bench_runtime import DEPTHS, cascade_vs_monolith
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="regret-metrics.json",
                    help="write the sweep rows JSON here (CI artifact)")
    ap.add_argument("--regret-out", default=None,
                    help="write the deepest-ladder recall leg's "
                         "obs_regret/v1 report (the artifact "
                         "benchmarks.check_trace --regret validates)")
    ap.add_argument("--pareto-out", default=None,
                    help="write that leg's obs_pareto/v1 frontier doc")
    args = ap.parse_args()
    keep = bool(args.regret_out or args.pareto_out)
    all_rows: list[dict] = []
    sweeps: dict[float, dict[str, float]] = {}
    meters: dict[tuple[float, str], object] = {}
    for k in DEPTH_KS:
        rows = cascade_vs_monolith(
            rates=(RATE,), duration=DURATION, variants=VARIANTS,
            keep_trace=keep, depths=_depths(k, DEPTHS))
        sweeps[k] = {}
        for row in rows:
            row.pop("_trace", None)
            meter = row.pop("_regret", None)
            if meter is not None:
                meters[(k, row["cascade"])] = meter
            row["depth_k"] = k
            if row.get("regret_mean") is not None:
                sweeps[k][row["cascade"]] = row["regret_mean"]
            all_rows.append(row)
    if keep:
        # the deepest ladder is where the separation is widest — that
        # leg's report is the representative CI artifact
        meter = meters[(max(DEPTH_KS), "cascade_recall")]
        if args.regret_out:
            with open(args.regret_out, "w") as f:
                json.dump(meter.report(), f, indent=1, default=float)
            print(f"wrote {args.regret_out}")
        if args.pareto_out:
            with open(args.pareto_out, "w") as f:
                json.dump(meter.pareto.as_doc(), f, indent=1,
                          default=float)
            print(f"wrote {args.pareto_out}")
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1, default=float)
    for k in sorted(sweeps):
        line = "  ".join(f"{v}={sweeps[k][v]:.5f}"
                         for v in VARIANTS if v in sweeps[k])
        print(f"k={k:g}: {line}")
    failures = check(sweeps)
    for msg in failures:
        print(f"FAIL  {msg}")
    print(f"wrote {args.out}; {len(failures)} failed claims")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
