"""§Roofline table (deliverable g): per (arch x shape x mesh), the three
roofline terms derived from the compiled dry-run artifacts, the dominant
bottleneck, and the useful-compute ratio.

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s HBM)
  collective term = wire_bytes / (chips x 50 GB/s ICI per link)

HLO_FLOPs / bytes / wire_bytes are PER-DEVICE numbers from the trip-count
-aware HLO walker (launch/hlo_cost.py), so the division by chips is
already folded in — terms are seconds for one step.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun);
writes benchmarks/results/roofline.csv.  Combos whose dry-run hasn't been
executed yet are skipped with a note.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

RESULTS = os.path.join(os.path.dirname(__file__), "results")
DRYRUN = os.path.join(RESULTS, "dryrun")


def load_all() -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def terms(rec: dict) -> dict:
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["hbm_bytes_per_device"] / HBM_BW
    t_x = rec["wire_bytes_per_device"] / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    total_flops = rec["flops_per_device"] * rec["devices"]
    ratio = rec["model_flops"] / total_flops if total_flops else 0.0
    bound = max(t_c, t_m, t_x)
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom, "useful_ratio": ratio,
            "roofline_frac": t_c / bound if bound else 0.0}


def run() -> list[dict]:
    rows = []
    recs = load_all()
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.csv"), "w") as f:
        f.write("arch,shape,mesh,rules,t_compute_s,t_memory_s,"
                "t_collective_s,dominant,useful_ratio,roofline_frac,"
                "temp_gib\n")
        for rec in recs:
            t = terms(rec)
            f.write(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
                    f"{rec['rules']},{t['t_compute']:.4g},"
                    f"{t['t_memory']:.4g},{t['t_collective']:.4g},"
                    f"{t['dominant']},{t['useful_ratio']:.3f},"
                    f"{t['roofline_frac']:.3f},"
                    f"{rec['memory']['temp_bytes'] / 2**30:.2f}\n")
            if rec["rules"] == "baseline" and rec["mesh"] == "pod16x16":
                rows.append({
                    "name": f"roofline_{rec['arch']}_{rec['shape']}",
                    "us_per_call": t["t_compute"] * 1e6,
                    "derived": (f"dom={t['dominant']} "
                                f"mem_s={t['t_memory']:.3g} "
                                f"coll_s={t['t_collective']:.3g} "
                                f"useful={t['useful_ratio']:.2f} "
                                f"frac={t['roofline_frac']:.2f}"),
                })
    if not rows:
        rows.append({"name": "roofline", "us_per_call": 0.0,
                     "derived": "no dryrun artifacts yet — run "
                                "python -m repro.launch.dryrun --all"})
    return rows
