"""CI validator for observability artifacts (DESIGN.md §12/§13).

Hand-rolled structural checks — the repo deliberately carries no
jsonschema dependency — over every document a traced serve writes:

  * the Chrome/Perfetto trace-event JSON from ``--trace-out`` /
    `repro.serving.obs.export.write_trace`: every event must be a
    well-formed trace-event phase (M metadata, X complete span,
    i instant, C counter) with numeric non-negative timestamps, the
    three process tracks (lanes / models / control) must be named,
    and every request span must sit on a named lane thread;
  * the metrics snapshot from ``--metrics-out`` /
    `MetricsRegistry.to_json` (schema ``obs_metrics/v1``): a flat
    ``name{labels}`` -> value mapping with JSON-scalar (or histogram
    dict) values;
  * flight-recorder / ledger-freeze dumps (schema ``flight_bundle/v1``
    from `FlightRecorder` or `InvariantLedger._freeze`): trigger +
    event window + triggering request's span history;
  * the lossless event log from ``--obs-dir`` /
    `repro.serving.obs.export.write_events` (schema ``obs_trace/v1``):
    the replayable raw ring with embedded digests;
  * the audit verdicts (schema ``ledger_report/v1`` from
    `InvariantLedger.report`): per-contract checks/violations with
    internally-consistent totals, and the fault-plane contracts
    (cancel / page-release / stall-liveness) must be known;
  * the chaos script (schema ``faults/v1`` from `FaultPlan.as_doc`)
    embedded in traces and event logs served under fault injection;
  * the decision-quality report (schema ``obs_regret/v1`` from
    `RegretMeter.report`): named cause buckets that exactly partition
    the total, a pinned 64-hex digest, and a known verdict — an
    unverifiable report must demote its numbers, not assert them;
  * the accuracy-latency frontier (schema ``obs_pareto/v1`` from
    `ParetoTracker.as_doc`): well-formed frontier points with
    internally-consistent point/frontier counts per gear.

Usage (exit 1 on any violation, so the CI step fails loudly):

  python -m benchmarks.check_trace --trace serve-trace.json \
      --metrics serve-metrics.json --bundle 'obs/flight-*.json' \
      --events obs/events.json --ledger obs/ledger.json \
      --regret obs/regret.json --pareto obs/pareto.json
"""

from __future__ import annotations

import argparse
import glob as _glob
import json
import sys

_PHASES = {"M", "X", "i", "C"}
_SCALARS = (int, float, str, bool)

# every span kind the tracer documents (obs/trace.py) — an event log
# carrying anything else is from a different (or future) producer and
# must fail loudly rather than validate by accident
_EVENT_KINDS = {
    "queued", "admitted", "token", "prefill_chunk", "finish",
    "cancel", "deadline_miss", "escalate", "esc_wait", "esc_grant",
    "esc_resolve", "recall", "deescalate", "rung_stall", "gear_switch",
    "recal", "page_blocked", "counter",
}

# contracts every current ledger must know about; a report missing one
# was produced by a pre-fault-plane audit and cannot vouch for a chaos
# serve
_REQUIRED_CONTRACTS = ("cancel_halts_stream", "cancel_releases_pages",
                       "rung_stall_liveness")

# the exact cause partition a regret report must carry (obs/regret.py)
_REGRET_CAUSES = ("exited_too_early", "escalated_too_late",
                  "recall_forgone", "governor_denied", "gear_transient")


def _err(errors: list[str], where: str, msg: str) -> None:
    errors.append(f"{where}: {msg}")


def validate_trace(doc: dict) -> list[str]:
    """Structural checks on a Chrome trace-event document; returns the
    list of violations (empty == valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["trace: document is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["trace: traceEvents missing or empty"]

    named_procs: dict[int, str] = {}
    named_threads: set[tuple[int, int]] = set()
    spans = instants = counters = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            _err(errors, where, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            _err(errors, where, f"unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            _err(errors, where, "missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int) or ev[key] < 0:
                _err(errors, where, f"bad {key} {ev.get(key)!r}")
        if ph == "M":
            args = ev.get("args") or {}
            if ev.get("name") == "process_name":
                named_procs[ev.get("pid", -1)] = args.get("name", "")
            elif ev.get("name") == "thread_name":
                named_threads.add((ev.get("pid", -1), ev.get("tid", -1)))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            _err(errors, where, f"bad ts {ts!r}")
        if ph == "X":
            spans += 1
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                _err(errors, where, f"X span with bad dur {dur!r}")
            if ev.get("pid") == 0 and \
                    (0, ev.get("tid")) not in named_threads:
                _err(errors, where,
                     f"request span on unnamed lane tid {ev.get('tid')}")
        elif ph == "i":
            instants += 1
            if ev.get("s") not in ("t", "p", "g"):
                _err(errors, where, f"instant with bad scope "
                     f"{ev.get('s')!r}")
        elif ph == "C":
            counters += 1
            args = ev.get("args")
            if not isinstance(args, dict) or not any(
                    isinstance(v, (int, float)) for v in args.values()):
                _err(errors, where, "counter without a numeric value")

    for pid, expect in ((0, "lanes"), (1, "models"), (2, "control")):
        if named_procs.get(pid) != expect:
            _err(errors, "trace", f"process {pid} not named {expect!r} "
                 f"(got {named_procs.get(pid)!r})")
    if spans + instants == 0:
        _err(errors, "trace", "no spans or instants — nothing was traced")
    other = doc.get("otherData")
    if not isinstance(other, dict) or "events_dropped" not in other:
        _err(errors, "trace", "otherData.events_dropped missing")
    if isinstance(other, dict) and "faults" in other:
        errors += validate_faults(other["faults"])
    return errors


def validate_faults(doc) -> list[str]:
    """Structural checks on an embedded ``faults/v1`` plan block."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["faults: plan block is not a JSON object"]
    if doc.get("schema") != "faults/v1":
        _err(errors, "faults", f"schema {doc.get('schema')!r} != "
             "'faults/v1'")
    if not isinstance(doc.get("seed"), int):
        _err(errors, "faults", f"bad seed {doc.get('seed')!r}")
    for key in ("cancel_at", "deadline"):
        m = doc.get(key, {})
        if not isinstance(m, dict):
            _err(errors, "faults", f"{key} is not a mapping")
            continue
        for rid, t in m.items():
            if not (isinstance(rid, str) and rid.lstrip("-").isdigit()):
                _err(errors, "faults", f"{key}: non-integer rid {rid!r}")
            if not isinstance(t, (int, float)) or t < 0:
                _err(errors, "faults", f"{key}[{rid}]: bad time {t!r}")
    for i, w in enumerate(doc.get("stalls", ())):
        if (not isinstance(w, list) or len(w) != 3
                or not isinstance(w[0], int) or w[0] < 0
                or not all(isinstance(x, (int, float)) for x in w[1:])
                or w[1] >= w[2]):
            _err(errors, "faults", f"stalls[{i}]: bad window {w!r} "
                 "(want [model, t0, t1] with t0 < t1)")
    for i, w in enumerate(doc.get("squeezes", ())):
        if (not isinstance(w, list) or len(w) != 3
                or not all(isinstance(x, (int, float)) for x in w[:2])
                or w[0] >= w[1]
                or not isinstance(w[2], int) or w[2] < 0):
            _err(errors, "faults", f"squeezes[{i}]: bad window {w!r} "
                 "(want [t0, t1, pages] with t0 < t1)")
    return errors


def validate_metrics(doc: dict) -> list[str]:
    """Structural checks on an ``obs_metrics/v1`` snapshot."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["metrics: document is not a JSON object"]
    if doc.get("schema") != "obs_metrics/v1":
        _err(errors, "metrics", f"schema {doc.get('schema')!r} != "
             "'obs_metrics/v1'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        return errors + ["metrics: metrics mapping missing or empty"]
    for key, value in metrics.items():
        if not isinstance(key, str) or not key:
            _err(errors, "metrics", f"bad series key {key!r}")
            continue
        name = key.split("{", 1)[0]
        if not name or not all(c.isalnum() or c == "_" for c in name):
            _err(errors, "metrics", f"malformed series name {key!r}")
        if "{" in key and not key.endswith("}"):
            _err(errors, "metrics", f"unterminated label set in {key!r}")
        if isinstance(value, dict):
            # histogram: bucket map + sum + count
            if not ({"buckets", "sum", "count"} <= set(value)):
                _err(errors, "metrics",
                     f"{key}: histogram missing buckets/sum/count")
        elif not isinstance(value, _SCALARS) and value is not None:
            _err(errors, "metrics", f"{key}: non-scalar value "
                 f"{type(value).__name__}")
    return errors


def _check_event_dicts(errors: list[str], where: str, events, *,
                       monotonic: bool = False) -> None:
    """Shared shape check for `Event.as_dict` lists (bundles + event
    logs): numeric non-negative t, non-empty kind.  ``monotonic``
    additionally requires non-decreasing t — true only for a single
    request's span (the global ring interleaves ``queued`` events
    carrying their arrival stamp with later-clock token events)."""
    if not isinstance(events, list):
        _err(errors, where, "events is not a list")
        return
    last_t = None
    for i, ev in enumerate(events):
        ew = f"{where}[{i}]"
        if not isinstance(ev, dict):
            _err(errors, ew, "event is not an object")
            continue
        t = ev.get("t")
        if not isinstance(t, (int, float)) or t < 0:
            _err(errors, ew, f"bad t {t!r}")
            continue
        if not isinstance(ev.get("kind"), str) or not ev["kind"]:
            _err(errors, ew, "missing kind")
        if monotonic and last_t is not None and t < last_t:
            _err(errors, ew, f"time went backwards ({t} < {last_t})")
        last_t = t


def validate_bundle(doc: dict) -> list[str]:
    """Structural checks on a ``flight_bundle/v1`` dump (flight
    recorder anomaly triggers AND ledger violation freezes)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["bundle: document is not a JSON object"]
    if doc.get("schema") != "flight_bundle/v1":
        _err(errors, "bundle", f"schema {doc.get('schema')!r} != "
             "'flight_bundle/v1'")
    if not isinstance(doc.get("trigger"), str) or not doc["trigger"]:
        _err(errors, "bundle", "missing trigger")
    t = doc.get("t")
    if not isinstance(t, (int, float)) or t < 0:
        _err(errors, "bundle", f"bad trigger time {t!r}")
    rid = doc.get("rid")
    if rid is not None and not isinstance(rid, int):
        _err(errors, "bundle", f"bad rid {rid!r}")
    if not isinstance(doc.get("detail"), dict):
        _err(errors, "bundle", "detail missing or not an object")
    _check_event_dicts(errors, "bundle.events", doc.get("events"))
    _check_event_dicts(errors, "bundle.request_span",
                       doc.get("request_span", []), monotonic=True)
    dropped = doc.get("span_events_dropped", 0)
    if not isinstance(dropped, int) or dropped < 0:
        _err(errors, "bundle", f"bad span_events_dropped {dropped!r}")
    # a bundle must carry SOME evidence: the window or the span
    if not doc.get("events") and not doc.get("request_span"):
        _err(errors, "bundle", "carries neither events nor request_span")
    return errors


def validate_events(doc: dict) -> list[str]:
    """Structural checks on an ``obs_trace/v1`` event log (the
    lossless replay artifact `write_events` emits)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["events: document is not a JSON object"]
    if doc.get("schema") != "obs_trace/v1":
        _err(errors, "events", f"schema {doc.get('schema')!r} != "
             "'obs_trace/v1'")
    _check_event_dicts(errors, "events", doc.get("events"))
    for i, ev in enumerate(doc.get("events") or ()):
        kind = ev.get("kind") if isinstance(ev, dict) else None
        if isinstance(kind, str) and kind and kind not in _EVENT_KINDS:
            _err(errors, f"events[{i}]", f"unknown span kind {kind!r}")
    dropped = doc.get("events_dropped")
    if not isinstance(dropped, int) or dropped < 0:
        _err(errors, "events", f"bad events_dropped {dropped!r}")
    for key in ("span_digest", "decision_digest"):
        dig = doc.get(key)
        if not isinstance(dig, str) or len(dig) != 64:
            _err(errors, "events", f"{key} is not a sha256 hex digest")
    if "faults" in doc:
        errors += validate_faults(doc["faults"])
    return errors


def validate_ledger(doc: dict) -> list[str]:
    """Structural + consistency checks on a ``ledger_report/v1``."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["ledger: document is not a JSON object"]
    if doc.get("schema") != "ledger_report/v1":
        _err(errors, "ledger", f"schema {doc.get('schema')!r} != "
             "'ledger_report/v1'")
    contracts = doc.get("contracts")
    if not isinstance(contracts, dict) or not contracts:
        return errors + ["ledger: contracts mapping missing or empty"]
    for name in _REQUIRED_CONTRACTS:
        if name not in contracts:
            _err(errors, "ledger", f"contract {name!r} unknown to this "
                 "ledger — report predates the fault plane")
    tally = 0
    for name, c in contracts.items():
        where = f"ledger.contracts[{name}]"
        if not isinstance(c, dict):
            _err(errors, where, "not an object")
            continue
        for key in ("checks", "violations"):
            v = c.get(key)
            if not isinstance(v, int) or v < 0:
                _err(errors, where, f"bad {key} {v!r}")
        if c.get("verdict") not in ("pass", "violated", "unverifiable"):
            _err(errors, where, f"bad verdict {c.get('verdict')!r}")
        tally += c.get("violations", 0) \
            if isinstance(c.get("violations"), int) else 0
    total = doc.get("total_violations")
    if not isinstance(total, int) or total < 0:
        _err(errors, "ledger", f"bad total_violations {total!r}")
    elif total != tally:
        _err(errors, "ledger", f"total_violations {total} != "
             f"per-contract sum {tally}")
    if not isinstance(doc.get("violations"), list):
        _err(errors, "ledger", "violations list missing")
    return errors


def validate_regret(doc: dict) -> list[str]:
    """Structural + consistency checks on an ``obs_regret/v1`` report
    (the `RegretMeter` decision-quality document)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["regret: document is not a JSON object"]
    if doc.get("schema") != "obs_regret/v1":
        _err(errors, "regret", f"schema {doc.get('schema')!r} != "
             "'obs_regret/v1'")
    verdict = doc.get("verdict")
    if verdict not in ("exact", "expected", "unverifiable"):
        _err(errors, "regret", f"bad verdict {verdict!r}")
    unverifiable = verdict == "unverifiable"
    for key in ("requests", "tokens"):
        v = doc.get(key)
        if not isinstance(v, int) or v < 0:
            _err(errors, "regret", f"bad {key} {v!r}")
    dig = doc.get("digest")
    if not isinstance(dig, str) or len(dig) != 64:
        _err(errors, "regret", "digest is not a sha256 hex digest")
    for key in ("regret_mean", "regret_p99", "regret_max",
                "regret_total"):
        v = doc.get(key)
        if unverifiable:
            # an unverifiable report must DEMOTE its numbers to
            # ``suspect``, not assert them
            if v is not None:
                _err(errors, "regret", f"unverifiable report asserts "
                     f"{key}={v!r} (must be null, demoted to suspect)")
        elif not isinstance(v, (int, float)) or v < 0:
            _err(errors, "regret", f"bad {key} {v!r}")
    if unverifiable and not isinstance(doc.get("suspect"), dict):
        _err(errors, "regret", "unverifiable report without a suspect "
             "block")
    causes = doc.get("causes")
    if not isinstance(causes, dict):
        _err(errors, "regret", "causes mapping missing")
    elif not unverifiable:
        unknown = sorted(set(causes) - set(_REGRET_CAUSES))
        if unknown:
            _err(errors, "regret", f"unknown cause buckets {unknown}")
        for name, v in causes.items():
            if not isinstance(v, (int, float)) or v < 0:
                _err(errors, "regret", f"causes[{name}]: bad value {v!r}")
        total = doc.get("regret_total")
        if isinstance(total, (int, float)) and not unknown and all(
                isinstance(v, (int, float)) for v in causes.values()):
            tally = sum(causes.values())
            if abs(tally - total) > 1e-6 + 1e-6 * abs(total):
                _err(errors, "regret", f"cause sum {tally} does not "
                     f"partition regret_total {total}")
    for i, w in enumerate(doc.get("worst") or ()):
        where = f"regret.worst[{i}]"
        if not isinstance(w, dict):
            _err(errors, where, "not an object")
            continue
        if not isinstance(w.get("rid"), int):
            _err(errors, where, f"bad rid {w.get('rid')!r}")
        for key in ("regret", "latency_s"):
            v = w.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                _err(errors, where, f"bad {key} {v!r}")
    return errors


def validate_pareto(doc: dict) -> list[str]:
    """Structural + consistency checks on an ``obs_pareto/v1``
    accuracy-latency frontier document."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["pareto: document is not a JSON object"]
    if doc.get("schema") != "obs_pareto/v1":
        _err(errors, "pareto", f"schema {doc.get('schema')!r} != "
             "'obs_pareto/v1'")
    points = doc.get("points")
    if not isinstance(points, int) or points < 0:
        _err(errors, "pareto", f"bad points {points!r}")
    frontier = doc.get("frontier")
    if not isinstance(frontier, list):
        return errors + ["pareto: frontier list missing"]
    if doc.get("frontier_size") != len(frontier):
        _err(errors, "pareto", f"frontier_size "
             f"{doc.get('frontier_size')!r} != {len(frontier)} points")
    if isinstance(points, int) and len(frontier) > points:
        _err(errors, "pareto", f"frontier larger ({len(frontier)}) than "
             f"the served population ({points})")
    last = None
    for i, p in enumerate(frontier):
        where = f"pareto.frontier[{i}]"
        if not isinstance(p, dict):
            _err(errors, where, "point is not an object")
            continue
        if not isinstance(p.get("rid"), int):
            _err(errors, where, f"bad rid {p.get('rid')!r}")
        if not isinstance(p.get("gear"), str) or not p["gear"]:
            _err(errors, where, "missing gear label")
        lat, loss = p.get("latency_s"), p.get("loss")
        if not isinstance(lat, (int, float)) or lat < 0:
            _err(errors, where, f"bad latency_s {lat!r}")
            continue
        if not isinstance(loss, (int, float)):
            _err(errors, where, f"bad loss {loss!r}")
            continue
        # a frontier is sorted by latency and strictly improving in
        # loss — anything else contains a dominated point
        if last is not None and not (lat > last[0] and loss < last[1]):
            _err(errors, where, f"not on a frontier: ({lat}, {loss}) "
                 f"vs previous ({last[0]}, {last[1]})")
        last = (lat, loss)
    by_gear = doc.get("by_gear")
    if not isinstance(by_gear, dict):
        _err(errors, "pareto", "by_gear mapping missing")
    else:
        tally = 0
        for gear, s in by_gear.items():
            where = f"pareto.by_gear[{gear}]"
            if not isinstance(s, dict):
                _err(errors, where, "not an object")
                continue
            for key in ("points", "frontier"):
                v = s.get(key)
                if not isinstance(v, int) or v < 0:
                    _err(errors, where, f"bad {key} {v!r}")
            tally += s.get("points", 0) \
                if isinstance(s.get("points"), int) else 0
        if isinstance(points, int) and tally != points:
            _err(errors, "pareto", f"per-gear point sum {tally} != "
                 f"points {points}")
    return errors


def _run_one(path: str, validator, describe) -> list[str]:
    with open(path) as f:
        doc = json.load(f)
    errs = validator(doc)
    print(f"{path}: {describe(doc)}, {len(errs)} violations")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None,
                    help="Perfetto trace-event JSON to validate")
    ap.add_argument("--metrics", default=None,
                    help="obs_metrics/v1 snapshot JSON to validate")
    ap.add_argument("--bundle", action="append", default=[],
                    help="flight_bundle/v1 dump(s) to validate "
                         "(repeatable; shell-style globs expanded — an "
                         "empty glob is fine, a named file must exist)")
    ap.add_argument("--events", default=None,
                    help="obs_trace/v1 event log to validate")
    ap.add_argument("--ledger", default=None,
                    help="ledger_report/v1 audit verdicts to validate")
    ap.add_argument("--regret", default=None,
                    help="obs_regret/v1 decision-quality report to "
                         "validate")
    ap.add_argument("--pareto", default=None,
                    help="obs_pareto/v1 frontier document to validate")
    args = ap.parse_args()
    if not (args.trace or args.metrics or args.bundle or args.events
            or args.ledger or args.regret or args.pareto):
        ap.error("nothing to check: pass --trace, --metrics, --bundle, "
                 "--events, --ledger, --regret and/or --pareto")
    failures: list[str] = []
    if args.trace:
        failures += _run_one(
            args.trace, validate_trace,
            lambda d: f"{len(d.get('traceEvents', ()))} trace events"
            if isinstance(d, dict) else "0 trace events")
    if args.metrics:
        failures += _run_one(
            args.metrics, validate_metrics,
            lambda d: f"{len(d.get('metrics', ()))} series"
            if isinstance(d, dict) else "0 series")
    for pattern in args.bundle:
        paths = sorted(_glob.glob(pattern))
        if not paths and not _glob.has_magic(pattern):
            failures.append(f"bundle: {pattern} does not exist")
            continue
        for path in paths:
            failures += _run_one(
                path, validate_bundle,
                lambda d: f"trigger {d.get('trigger')!r}, "
                          f"{len(d.get('events', ()))} events"
                if isinstance(d, dict) else "not an object")
    if args.events:
        failures += _run_one(
            args.events, validate_events,
            lambda d: f"{len(d.get('events', ()))} events"
            if isinstance(d, dict) else "0 events")
    if args.ledger:
        failures += _run_one(
            args.ledger, validate_ledger,
            lambda d: f"{len(d.get('contracts', ()))} contracts, "
                      f"{d.get('total_violations')} violations"
            if isinstance(d, dict) else "not an object")
    if args.regret:
        failures += _run_one(
            args.regret, validate_regret,
            lambda d: f"{d.get('requests')} requests, verdict "
                      f"{d.get('verdict')!r}"
            if isinstance(d, dict) else "not an object")
    if args.pareto:
        failures += _run_one(
            args.pareto, validate_pareto,
            lambda d: f"{d.get('frontier_size')} frontier points of "
                      f"{d.get('points')}"
            if isinstance(d, dict) else "not an object")
    for msg in failures:
        print(f"FAIL  {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
