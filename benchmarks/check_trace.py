"""CI validator for observability artifacts (DESIGN.md §12).

Hand-rolled structural checks — the repo deliberately carries no
jsonschema dependency — over the two documents a traced serve writes:

  * the Chrome/Perfetto trace-event JSON from ``--trace-out`` /
    `repro.serving.obs.export.write_trace`: every event must be a
    well-formed trace-event phase (M metadata, X complete span,
    i instant, C counter) with numeric non-negative timestamps, the
    three process tracks (lanes / models / control) must be named,
    and every request span must sit on a named lane thread;
  * the metrics snapshot from ``--metrics-out`` /
    `MetricsRegistry.to_json` (schema ``obs_metrics/v1``): a flat
    ``name{labels}`` -> value mapping with JSON-scalar (or histogram
    dict) values.

Usage (exit 1 on any violation, so the CI step fails loudly):

  python -m benchmarks.check_trace --trace serve-trace.json \
      --metrics serve-metrics.json
"""

from __future__ import annotations

import argparse
import json
import sys

_PHASES = {"M", "X", "i", "C"}
_SCALARS = (int, float, str, bool)


def _err(errors: list[str], where: str, msg: str) -> None:
    errors.append(f"{where}: {msg}")


def validate_trace(doc: dict) -> list[str]:
    """Structural checks on a Chrome trace-event document; returns the
    list of violations (empty == valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["trace: document is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["trace: traceEvents missing or empty"]

    named_procs: dict[int, str] = {}
    named_threads: set[tuple[int, int]] = set()
    spans = instants = counters = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            _err(errors, where, "event is not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            _err(errors, where, f"unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            _err(errors, where, "missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int) or ev[key] < 0:
                _err(errors, where, f"bad {key} {ev.get(key)!r}")
        if ph == "M":
            args = ev.get("args") or {}
            if ev.get("name") == "process_name":
                named_procs[ev.get("pid", -1)] = args.get("name", "")
            elif ev.get("name") == "thread_name":
                named_threads.add((ev.get("pid", -1), ev.get("tid", -1)))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            _err(errors, where, f"bad ts {ts!r}")
        if ph == "X":
            spans += 1
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                _err(errors, where, f"X span with bad dur {dur!r}")
            if ev.get("pid") == 0 and \
                    (0, ev.get("tid")) not in named_threads:
                _err(errors, where,
                     f"request span on unnamed lane tid {ev.get('tid')}")
        elif ph == "i":
            instants += 1
            if ev.get("s") not in ("t", "p", "g"):
                _err(errors, where, f"instant with bad scope "
                     f"{ev.get('s')!r}")
        elif ph == "C":
            counters += 1
            args = ev.get("args")
            if not isinstance(args, dict) or not any(
                    isinstance(v, (int, float)) for v in args.values()):
                _err(errors, where, "counter without a numeric value")

    for pid, expect in ((0, "lanes"), (1, "models"), (2, "control")):
        if named_procs.get(pid) != expect:
            _err(errors, "trace", f"process {pid} not named {expect!r} "
                 f"(got {named_procs.get(pid)!r})")
    if spans + instants == 0:
        _err(errors, "trace", "no spans or instants — nothing was traced")
    other = doc.get("otherData")
    if not isinstance(other, dict) or "events_dropped" not in other:
        _err(errors, "trace", "otherData.events_dropped missing")
    return errors


def validate_metrics(doc: dict) -> list[str]:
    """Structural checks on an ``obs_metrics/v1`` snapshot."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["metrics: document is not a JSON object"]
    if doc.get("schema") != "obs_metrics/v1":
        _err(errors, "metrics", f"schema {doc.get('schema')!r} != "
             "'obs_metrics/v1'")
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        return errors + ["metrics: metrics mapping missing or empty"]
    for key, value in metrics.items():
        if not isinstance(key, str) or not key:
            _err(errors, "metrics", f"bad series key {key!r}")
            continue
        name = key.split("{", 1)[0]
        if not name or not all(c.isalnum() or c == "_" for c in name):
            _err(errors, "metrics", f"malformed series name {key!r}")
        if "{" in key and not key.endswith("}"):
            _err(errors, "metrics", f"unterminated label set in {key!r}")
        if isinstance(value, dict):
            # histogram: bucket map + sum + count
            if not ({"buckets", "sum", "count"} <= set(value)):
                _err(errors, "metrics",
                     f"{key}: histogram missing buckets/sum/count")
        elif not isinstance(value, _SCALARS) and value is not None:
            _err(errors, "metrics", f"{key}: non-scalar value "
                 f"{type(value).__name__}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None,
                    help="Perfetto trace-event JSON to validate")
    ap.add_argument("--metrics", default=None,
                    help="obs_metrics/v1 snapshot JSON to validate")
    args = ap.parse_args()
    if not (args.trace or args.metrics):
        ap.error("nothing to check: pass --trace and/or --metrics")
    failures: list[str] = []
    if args.trace:
        with open(args.trace) as f:
            doc = json.load(f)
        errs = validate_trace(doc)
        n = len(doc.get("traceEvents", ())) if isinstance(doc, dict) else 0
        print(f"{args.trace}: {n} trace events, {len(errs)} violations")
        failures += errs
    if args.metrics:
        with open(args.metrics) as f:
            doc = json.load(f)
        errs = validate_metrics(doc)
        n = len(doc.get("metrics", ())) if isinstance(doc, dict) else 0
        print(f"{args.metrics}: {n} series, {len(errs)} violations")
        failures += errs
    for msg in failures:
        print(f"FAIL  {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
