"""Thm 3.4 table: the no-recall approximation ratio grows linearly in
alpha on the paper's construction (analytic + Monte-Carlo columns)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import impossibility


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for alpha in (2.0, 4.0, 8.0, 16.0, 32.0, 64.0):
        inst = impossibility.make_instance(alpha)
        t0 = time.perf_counter()
        alg = impossibility.best_norecall_value(inst)
        opt = impossibility.offline_opt_value(inst)
        _, _, mc_ratio = impossibility.empirical_ratio(inst, rng, t=200_000)
        us = (time.perf_counter() - t0) * 1e6
        rows.append({
            "name": f"impossibility_alpha={alpha:g}",
            "us_per_call": us,
            "derived": (f"ratio={alg / opt:.2f} mc={mc_ratio:.2f} "
                        f"alg={alg:.3e} opt={opt:.3e}"),
        })
    return rows
