"""Render EXPERIMENTS.md §Roofline / §Dry-run tables from the dry-run
JSONs.  Usage: PYTHONPATH=src python -m benchmarks.report [--mesh pod16x16]
"""

from __future__ import annotations

import argparse

from benchmarks.bench_roofline import load_all, terms

SUGGEST = {
    ("compute",): "raise arithmetic intensity (fuse attention via the "
                  "Pallas kernel; larger microbatch)",
    ("memory",): "cut HBM round-trips: fuse attention scores (flash), "
                 "bf16 caches, avoid f32 converts of logits",
    ("collective",): "reshard: fewer weight all-gathers (cache across "
                     "microbatches), reduce-scatter grads, 2D logit "
                     "sharding",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--rules", default=None)
    args = ap.parse_args()
    recs = load_all()
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    if args.rules:
        recs = [r for r in recs if r["rules"] == args.rules]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"], r["rules"]))
    print("| arch | shape | mesh | rules | compute s | memory s | "
          "collective s | dominant | useful | temp GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        t = terms(r)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['rules']} "
              f"| {t['t_compute']:.3g} | {t['t_memory']:.3g} "
              f"| {t['t_collective']:.3g} | {t['dominant']} "
              f"| {t['useful_ratio']:.2f} "
              f"| {r['memory']['temp_bytes'] / 2**30:.2f} |")


if __name__ == "__main__":
    main()
