"""Seeded soak / burn-in for the audit & replay plane (DESIGN.md §13).

Serves several simulated hours of adversarial traffic — bursty and
diurnal arrival processes, page-pressure chaos through deliberately
shrunken KV pools, and escalation storms on an overthinking cascade —
entirely on the virtual clock (minutes of wall time), with the full
observability plane armed:

  * the `InvariantLedger` audits every contract live; ANY violation
    fails the soak (exit 1) and leaves a ``ledger-*.json`` freeze
    bundle next to the artifacts,
  * at exit each leg's exported ``obs_trace/v1`` log is REPLAYED
    through a fresh stepper and both digests must match — the
    end-to-end determinism check CI gates on,
  * every flight/ledger bundle the run emits is validated in-process
    with the same `benchmarks.check_trace` checkers CI runs,
  * ``--obs-dir DIR`` writes one artifact directory per leg (events +
    Perfetto trace + metrics + ledger report + bundles) plus a
    ``soak_report/v1`` summary.

Legs (each runs hours/3 of virtual time):

  * ``bursty_pagepressure`` — single-model sim serve, bursty arrivals,
    a real paged `KVPool` shrunk so admission blocks under bursts
    (allocator/COW/prefix-cache invariants audited every step);
  * ``diurnal_escalation``  — two-model cascade under a diurnal wave,
    ``recall`` residency, 30% head-overthink traces: constant
    escalate/grant/recall/de-escalate churn;
  * ``bursty_commit``       — the same cascade under ``commit``
    residency: the walk-floor monotonicity contract is live.

Usage:

  PYTHONPATH=src python -m benchmarks.soak --hours 2 --obs-dir soak-obs
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

import numpy as np

from repro import strategy
from repro.core import traces
from repro.serving import runtime as rt
from repro.serving.kvpool import KVPool
from repro.serving.obs import (FlightRecorder, InvariantLedger,
                               Observability, SpanTracer)
from repro.serving.obs.export import events_doc, write_trace
from repro.serving.obs.lossmap import goodput_lossmap, sim_token_ceiling
from repro.serving.obs.replay import replay
from repro.serving.runtime.workload import WorkloadSpec, make_workload

from benchmarks.check_trace import (validate_bundle, validate_events,
                                    validate_ledger)

SLO = 2.0
N_NODES = 5
N0, N1 = 2, 3           # cascade rung depths (small, large)


def _tracer(duration: float) -> SpanTracer:
    """A ring generously sized for the leg: replay equality needs a
    lossless record, so capacity scales with virtual duration (~250
    events per busy virtual second, with headroom)."""
    return SpanTracer(capacity=max(200_000, int(600 * duration)))


# --------------------------------------------------------------------------
# leg builders: each returns (requests, serve_fn, ledger_kwargs, ceiling)
# where serve_fn(requests, obs) runs one fully fresh serve
# --------------------------------------------------------------------------

def _leg_bursty_pagepressure(duration: float, seed: int):
    rng = np.random.default_rng(seed)
    losses, _, flops = traces.ee_like_traces(rng, 6_000, N_NODES)
    casc = strategy.Cascade.from_traces(losses[:3_000], 0.4 * flops,
                                        k=12, lam=0.6)
    bank = losses[3_000:]
    spec = WorkloadSpec(rate=3.0, duration=duration, prompt_len=4,
                        max_tokens=(2, 12), seed=seed + 1,
                        strategy="recall_index")
    requests = make_workload("bursty", spec)

    def serve(reqs, obs):
        strategies, sid_of = rt.build_bank(
            reqs, rt.cascade_factory(casc), ("recall_index", None))
        # 10 usable pages vs 4-page worst-case requests on 3 lanes:
        # the third concurrent reservation blocks — sustained,
        # recoverable page pressure, never PoolExhausted
        pool = KVPool(n_lanes=3, page_size=4, lane_pages=8, n_pages=11)
        stepper = rt.SimStepper(strategies, bank, n_lanes=3,
                                seg_time=0.05, overhead=0.01, pool=pool)
        server = rt.Server(stepper, rt.LaneScheduler(3), sid_of,
                           slo=SLO, obs=obs)
        return server.serve(reqs)

    return requests, serve, {}, sim_token_ceiling(3, 0.05, 0.01)


def _cascade_setup(seed: int):
    from repro.serving.cascade import ModelBank, ModelSpec
    rng = np.random.default_rng(seed)
    losses, boundaries = traces.cascade_traces(
        rng, 6_000, [(2.0, 3.0), (5.0, 8.0, 12.0)], head_overthink=0.3)
    costs = np.concatenate([np.full(N0, 0.5 / N0), np.full(N1, 2.0 / N1)])
    casc = strategy.Cascade.from_traces(losses[:3_000], 0.1 * costs,
                                        k=10, lam=0.9,
                                        boundaries=boundaries)
    bank = ModelBank([
        ModelSpec("small", N0, n_lanes=3, seg_time=0.01,
                  prefill_tok_time=0.001),
        ModelSpec("large", N1, n_lanes=2, seg_time=0.04,
                  prefill_tok_time=0.004),
    ])
    return casc, bank, losses[3_000:]


def _leg_cascade(duration: float, seed: int, *, workload: str,
                 policy: str):
    from repro.serving.cascade import CascadeSimStepper
    casc, bank, bank_traces = _cascade_setup(seed)
    if policy == "commit":
        name = "norecall_threshold"

        def mk(sname, lam):
            return strategy.make("norecall_threshold", casc,
                                 threshold=0.2, lam=1.0)
    else:
        name = "skip_recall"

        def mk(sname, lam):
            return strategy.make("skip_recall", casc, mode="cascade")

    spec = WorkloadSpec(rate=1.5, duration=duration, prompt_len=8,
                        max_tokens=(3, 12), seed=seed + 2, strategy=name)
    requests = make_workload(workload, spec)

    def serve(reqs, obs):
        strat_bank, sid_of = rt.build_bank(reqs, mk, (name, None))
        pool = KVPool(n_lanes=3, page_size=4, lane_pages=8, n_pages=12)
        stepper = CascadeSimStepper(bank, strat_bank, bank_traces,
                                    overhead=0.002, policy=policy,
                                    patience=3, chunk=16, pool=pool)
        server = rt.Server(stepper, rt.LaneScheduler(3), sid_of,
                           slo=SLO, obs=obs)
        return server.serve(reqs)

    ledger_kwargs = {"policy": policy, "boundaries": casc.boundaries}
    return requests, serve, ledger_kwargs, None


LEGS = {
    "bursty_pagepressure": lambda d, s: _leg_bursty_pagepressure(d, s),
    "diurnal_escalation": lambda d, s: _leg_cascade(
        d, s, workload="diurnal", policy="recall"),
    "bursty_commit": lambda d, s: _leg_cascade(
        d, s, workload="bursty", policy="commit"),
}


# --------------------------------------------------------------------------
# the soak driver
# --------------------------------------------------------------------------

def run_leg(leg: str, duration: float, seed: int,
            out_dir: str | None) -> dict:
    requests, serve, ledger_kwargs, ceiling = LEGS[leg](duration, seed)
    t0 = time.time()
    ledger = InvariantLedger(out_dir=out_dir, **ledger_kwargs)
    flight = FlightRecorder(out_dir=out_dir,
                            rearm_interval=max(60.0, duration / 8))
    obs = Observability(tracer=_tracer(duration), flight=flight,
                        ledger=ledger)
    metrics = serve(requests, obs)
    wall = time.time() - t0
    summary = metrics.summary(slo=SLO)

    rep = ledger.report()
    doc = events_doc(obs.tracer)

    def reserve(reqs):
        fresh = Observability(tracer=_tracer(duration))
        serve(reqs, fresh)
        return fresh

    res = replay(doc, reserve)

    lossmap = goodput_lossmap(obs.tracer.events, slo=SLO,
                              duration=summary["duration"],
                              ceiling_tok_s=ceiling) \
        if not obs.tracer.dropped else None

    bundle_errors: list[str] = []
    if out_dir is not None:
        with open(os.path.join(out_dir, "events.json"), "w") as f:
            json.dump(doc, f, default=float)
        write_trace(obs.tracer, os.path.join(out_dir, "trace.json"),
                    title=f"soak:{leg}")
        with open(os.path.join(out_dir, "ledger.json"), "w") as f:
            json.dump(rep, f, indent=1, default=float)
        with open(os.path.join(out_dir, "metrics.json"), "w") as f:
            json.dump({"schema": "obs_metrics/v1",
                       "metrics": {f"runtime_{k}": v
                                   for k, v in summary.items()
                                   if isinstance(v, (int, float))},
                       "lossmap": lossmap}, f, indent=1, default=float)
        bundle_errors += validate_events(doc)
        bundle_errors += validate_ledger(rep)
        for path in sorted(glob.glob(os.path.join(out_dir, "flight-*.json"))
                           + glob.glob(os.path.join(out_dir,
                                                    "ledger-*.json"))):
            with open(path) as f:
                bundle_errors += [f"{path}: {e}"
                                  for e in validate_bundle(json.load(f))]

    row = {
        "leg": leg,
        "duration_s": duration,
        "wall_s": round(wall, 2),
        "requests": len(requests),
        "completed": summary["completed"],
        "tokens": summary["tokens"],
        "events": obs.tracer.n_emitted,
        "events_dropped": obs.tracer.dropped,
        "ledger_checks": sum(c["checks"]
                             for c in rep["contracts"].values()),
        "ledger_violations": rep["total_violations"],
        "flight_bundles": len(flight.bundles),
        "flight_rearms": flight.stats()["rearms"],
        "replay_ok": res.ok,
        "replay_detail": res.summary(),
        "span_digest": doc["span_digest"],
        "decision_digest": doc["decision_digest"],
        "artifact_errors": bundle_errors,
        "lossmap": lossmap,
    }
    ok = (rep["total_violations"] == 0 and res.ok
          and not bundle_errors and obs.tracer.dropped == 0)
    row["ok"] = ok
    return row


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hours", type=float, default=2.0,
                    help="total simulated hours across all legs "
                         "(virtual clock; wall time is minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="write per-leg artifact directories plus a "
                         "soak_report/v1 summary under DIR")
    ap.add_argument("--legs", default=",".join(LEGS),
                    help=f"comma-separated subset of: {', '.join(LEGS)}")
    args = ap.parse_args()
    legs = [l.strip() for l in args.legs.split(",") if l.strip()]
    unknown = [l for l in legs if l not in LEGS]
    if unknown:
        ap.error(f"unknown legs {unknown}; choose from {list(LEGS)}")
    per_leg = args.hours * 3600.0 / len(legs)

    rows = []
    for i, leg in enumerate(legs):
        out_dir = None
        if args.obs_dir:
            out_dir = os.path.join(args.obs_dir, leg)
            os.makedirs(out_dir, exist_ok=True)
        print(f"[{leg}] serving {per_leg:.0f} virtual seconds "
              f"(seed {args.seed + 17 * i}) ...")
        row = run_leg(leg, per_leg, args.seed + 17 * i, out_dir)
        rows.append(row)
        print(f"[{leg}] {row['completed']}/{row['requests']} requests, "
              f"{row['tokens']} tokens, {row['events']} events "
              f"({row['events_dropped']} dropped) "
              f"in {row['wall_s']:.1f}s wall")
        print(f"[{leg}] ledger: {row['ledger_checks']} checks, "
              f"{row['ledger_violations']} violations; "
              f"flight: {row['flight_bundles']} bundles "
              f"({row['flight_rearms']} re-arms)")
        print(f"[{leg}] {row['replay_detail']}")
        if row["lossmap"]:
            lm = row["lossmap"]
            parts = ", ".join(f"{c} {v:.2f}"
                              for c, v in sorted(lm["loss_tok_s"].items(),
                                                 key=lambda kv: -kv[1])
                              if v > 0)
            print(f"[{leg}] lossmap: ceiling {lm['ceiling_tok_s']:.1f} "
                  f"goodput {lm['goodput_tok_s']:.1f} tok/s"
                  + (f" ({parts})" if parts else ""))
        for err in row["artifact_errors"]:
            print(f"[{leg}] ARTIFACT FAIL  {err}")
        if not row["ok"]:
            print(f"[{leg}] FAILED")

    report = {"schema": "soak_report/v1",
              "hours": args.hours,
              "seed": args.seed,
              "legs": rows,
              "ok": all(r["ok"] for r in rows)}
    if args.obs_dir:
        os.makedirs(args.obs_dir, exist_ok=True)
        with open(os.path.join(args.obs_dir, "soak_report.json"),
                  "w") as f:
            json.dump(report, f, indent=1, default=float)
        print(f"wrote soak report to "
              f"{os.path.join(args.obs_dir, 'soak_report.json')}")
    verdict = "PASS" if report["ok"] else "FAIL"
    total_checks = sum(r["ledger_checks"] for r in rows)
    total_viol = sum(r["ledger_violations"] for r in rows)
    print(f"soak {verdict}: {args.hours:.2f} simulated hours over "
          f"{len(legs)} legs, {total_checks} ledger checks, "
          f"{total_viol} violations, replay "
          f"{'MATCH' if all(r['replay_ok'] for r in rows) else 'MISMATCH'}"
          )
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
