"""Seeded soak / burn-in for the audit & replay plane (DESIGN.md §13).

Serves several simulated hours of adversarial traffic — bursty and
diurnal arrival processes, page-pressure chaos through deliberately
shrunken KV pools, and escalation storms on an overthinking cascade —
entirely on the virtual clock (minutes of wall time), with the full
observability plane armed:

  * the `InvariantLedger` audits every contract live; ANY violation
    fails the soak (exit 1) and leaves a ``ledger-*.json`` freeze
    bundle next to the artifacts,
  * at exit each leg's exported ``obs_trace/v1`` log is REPLAYED
    through a fresh stepper and both digests must match — the
    end-to-end determinism check CI gates on,
  * every flight/ledger bundle the run emits is validated in-process
    with the same `benchmarks.check_trace` checkers CI runs,
  * ``--obs-dir DIR`` writes one artifact directory per leg (events +
    Perfetto trace + metrics + ledger report + bundles) plus a
    ``soak_report/v1`` summary.

Legs (virtual time is split evenly across the selected legs):

  * ``bursty_pagepressure`` — single-model sim serve, bursty arrivals,
    a real paged `KVPool` shrunk so admission blocks under bursts
    (allocator/COW/prefix-cache invariants audited every step);
  * ``diurnal_escalation``  — two-model cascade under a diurnal wave,
    ``recall`` residency, 30% head-overthink traces: constant
    escalate/grant/recall/de-escalate churn;
  * ``bursty_commit``       — the same cascade under ``commit``
    residency: the walk-floor monotonicity contract is live;
  * ``chaos_faults``        — the recall cascade under a scripted
    `FaultPlan` (cancellation storm, deadline squeezes, rung stalls,
    page squeezes) with the `DegradeGovernor`, deadline reaping and
    KV reclamation armed; gates on zero leaked pages at exit and on
    governor-on goodput strictly beating a governor-off re-serve.

Usage:

  PYTHONPATH=src python -m benchmarks.soak --hours 2 --obs-dir soak-obs
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

import numpy as np

from repro import strategy
from repro.core import traces
from repro.serving import runtime as rt
from repro.serving.kvpool import KVPool
from repro.serving.obs import (FlightRecorder, InvariantLedger,
                               Observability, SpanTracer)
from repro.serving.obs.export import events_doc, write_trace
from repro.serving.obs.lossmap import goodput_lossmap, sim_token_ceiling
from repro.serving.obs.replay import replay
from repro.serving.runtime.workload import WorkloadSpec, make_workload

from benchmarks.check_trace import (validate_bundle, validate_events,
                                    validate_ledger)

SLO = 2.0
N_NODES = 5
N0, N1 = 2, 3           # cascade rung depths (small, large)


def _tracer(duration: float) -> SpanTracer:
    """A ring generously sized for the leg: replay equality needs a
    lossless record, so capacity scales with virtual duration (~250
    events per busy virtual second, with headroom)."""
    return SpanTracer(capacity=max(200_000, int(600 * duration)))


# --------------------------------------------------------------------------
# leg builders: each returns (requests, serve_fn, ledger_kwargs, ceiling)
# where serve_fn(requests, obs) runs one fully fresh serve
# --------------------------------------------------------------------------

def _leg_bursty_pagepressure(duration: float, seed: int):
    rng = np.random.default_rng(seed)
    losses, _, flops = traces.ee_like_traces(rng, 6_000, N_NODES)
    casc = strategy.Cascade.from_traces(losses[:3_000], 0.4 * flops,
                                        k=12, lam=0.6)
    bank = losses[3_000:]
    spec = WorkloadSpec(rate=3.0, duration=duration, prompt_len=4,
                        max_tokens=(2, 12), seed=seed + 1,
                        strategy="recall_index")
    requests = make_workload("bursty", spec)

    def serve(reqs, obs):
        strategies, sid_of = rt.build_bank(
            reqs, rt.cascade_factory(casc), ("recall_index", None))
        # 10 usable pages vs 4-page worst-case requests on 3 lanes:
        # the third concurrent reservation blocks — sustained,
        # recoverable page pressure, never PoolExhausted
        pool = KVPool(n_lanes=3, page_size=4, lane_pages=8, n_pages=11)
        stepper = rt.SimStepper(strategies, bank, n_lanes=3,
                                seg_time=0.05, overhead=0.01, pool=pool)
        server = rt.Server(stepper, rt.LaneScheduler(3), sid_of,
                           slo=SLO, obs=obs)
        return server.serve(reqs)

    return requests, serve, {}, sim_token_ceiling(3, 0.05, 0.01)


def _cascade_setup(seed: int):
    from repro.serving.cascade import ModelBank, ModelSpec
    rng = np.random.default_rng(seed)
    losses, boundaries = traces.cascade_traces(
        rng, 6_000, [(2.0, 3.0), (5.0, 8.0, 12.0)], head_overthink=0.3)
    costs = np.concatenate([np.full(N0, 0.5 / N0), np.full(N1, 2.0 / N1)])
    casc = strategy.Cascade.from_traces(losses[:3_000], 0.1 * costs,
                                        k=10, lam=0.9,
                                        boundaries=boundaries)
    bank = ModelBank([
        ModelSpec("small", N0, n_lanes=3, seg_time=0.01,
                  prefill_tok_time=0.001),
        ModelSpec("large", N1, n_lanes=2, seg_time=0.04,
                  prefill_tok_time=0.004),
    ])
    return casc, bank, losses[3_000:]


def _leg_cascade(duration: float, seed: int, *, workload: str,
                 policy: str):
    from repro.serving.cascade import CascadeSimStepper
    casc, bank, bank_traces = _cascade_setup(seed)
    if policy == "commit":
        name = "norecall_threshold"

        def mk(sname, lam):
            return strategy.make("norecall_threshold", casc,
                                 threshold=0.2, lam=1.0)
    else:
        name = "skip_recall"

        def mk(sname, lam):
            return strategy.make("skip_recall", casc, mode="cascade")

    spec = WorkloadSpec(rate=1.5, duration=duration, prompt_len=8,
                        max_tokens=(3, 12), seed=seed + 2, strategy=name)
    requests = make_workload(workload, spec)

    def serve(reqs, obs):
        strat_bank, sid_of = rt.build_bank(reqs, mk, (name, None))
        pool = KVPool(n_lanes=3, page_size=4, lane_pages=8, n_pages=12)
        stepper = CascadeSimStepper(bank, strat_bank, bank_traces,
                                    overhead=0.002, policy=policy,
                                    patience=3, chunk=16, pool=pool)
        server = rt.Server(stepper, rt.LaneScheduler(3), sid_of,
                           slo=SLO, obs=obs)
        return server.serve(reqs)

    ledger_kwargs = {"policy": policy, "boundaries": casc.boundaries}
    return requests, serve, ledger_kwargs, None


def _leg_chaos_faults(duration: float, seed: int):
    """Cascade recall serve under a scripted `FaultPlan`: a
    cancellation storm, per-request deadline squeezes, periodic
    rung-1 stall windows and KV page squeezes — with the
    `DegradeGovernor` demoting instead of failing, deadline
    enforcement reaping expired lanes, and sliding-window page
    reclamation armed.  Two extra gates ride the leg: the pool must
    end the serve with ZERO pages in use, and governor-on goodput
    must strictly beat a governor-off re-serve of the same stamped
    workload."""
    from repro.serving.cascade import CascadeSimStepper
    from repro.serving.faults import DegradeGovernor, FaultPlan
    casc, bank, bank_traces = _cascade_setup(seed)
    name = "skip_recall"

    def mk(sname, lam):
        return strategy.make("skip_recall", casc, mode="cascade")

    spec = WorkloadSpec(rate=2.0, duration=duration, prompt_len=8,
                        max_tokens=(6, 22), seed=seed + 2, strategy=name)
    requests = make_workload("bursty", spec)

    # serve-borne chaos windows scale with the leg: rung-1 freezes
    # roughly every quarter of the leg, page squeezes every third
    stall_len = min(6.0, max(0.5, duration * 0.05))
    stalls, t = [], duration * 0.15
    while t < duration * 0.95:
        stalls.append((1, round(t, 3), round(t + stall_len, 3)))
        t += max(stall_len * 4, duration / 4)
    squeeze_len = min(8.0, max(0.5, duration * 0.06))
    squeezes, t = [], duration * 0.30
    while t < duration * 0.95:
        squeezes.append((round(t, 3), round(t + squeeze_len, 3), 2))
        t += max(squeeze_len * 3, duration / 3)
    plan = FaultPlan.generate(requests, seed=seed + 7,
                              cancel_rate=0.15, cancel_after=(0.1, 1.5),
                              deadline=(2.0, 6.0),
                              stalls=stalls, squeezes=squeezes)
    requests = plan.stamp(requests)

    pool_box: dict = {}

    def _serve(reqs, obs, governor):
        strat_bank, sid_of = rt.build_bank(reqs, mk, (name, None))
        pool = KVPool(n_lanes=3, page_size=4, lane_pages=8, n_pages=12,
                      reclaim_watermark=0.6)
        pool_box["pool"] = pool
        stepper = CascadeSimStepper(bank, strat_bank, bank_traces,
                                    overhead=0.002, policy="recall",
                                    patience=3, chunk=16, pool=pool,
                                    faults=plan, governor=governor)
        server = rt.Server(stepper, rt.LaneScheduler(3), sid_of,
                           slo=SLO, obs=obs, enforce_deadlines=True)
        return server.serve(reqs)

    def serve(reqs, obs):
        gov = DegradeGovernor()
        pool_box["governor"] = gov
        return _serve(reqs, obs, gov)

    def gates(summary) -> list[str]:
        errs: list[str] = []
        pool = pool_box.get("pool")
        if pool is not None:
            # drop cached prefixes, then demand a page-clean exit
            pool.prefix.clear()
            in_use = pool.pages_in_use
            if in_use:
                errs.append(f"{in_use} KV pages still in use at exit")
            errs += [f"pool at exit: {m}"
                     for m in pool.check_invariants()]
        # degradation must PAY: same stamped workload, governor off.
        # Strict improvement is demanded whenever the governor actually
        # intervened; if it never denied, the two serves are identical
        # and equality is the honest outcome.
        base = Observability(tracer=_tracer(duration))
        off = _serve(requests, base, None).summary(slo=SLO)
        on_good, off_good = summary["goodput_tok_s"], \
            off["goodput_tok_s"]
        gov = pool_box.get("governor")
        denied = gov.denied if gov is not None else 0
        if denied > 0 and not on_good > off_good:
            errs.append(f"governor denied {denied} escalations but "
                        f"goodput {on_good:.3f} tok/s does not beat "
                        f"governor-off {off_good:.3f}")
        elif denied == 0 and not on_good >= off_good:
            errs.append(f"governor idle yet goodput {on_good:.3f} "
                        f"tok/s fell below governor-off {off_good:.3f}")
        return errs

    return (requests, serve, {}, None,
            {"faults": plan, "gates": gates})


LEGS = {
    "bursty_pagepressure": lambda d, s: _leg_bursty_pagepressure(d, s),
    "diurnal_escalation": lambda d, s: _leg_cascade(
        d, s, workload="diurnal", policy="recall"),
    "bursty_commit": lambda d, s: _leg_cascade(
        d, s, workload="bursty", policy="commit"),
    "chaos_faults": lambda d, s: _leg_chaos_faults(d, s),
}


# --------------------------------------------------------------------------
# the soak driver
# --------------------------------------------------------------------------

def run_leg(leg: str, duration: float, seed: int,
            out_dir: str | None) -> dict:
    requests, serve, ledger_kwargs, ceiling, *rest = \
        LEGS[leg](duration, seed)
    extra = rest[0] if rest else {}
    plan = extra.get("faults")
    t0 = time.time()
    ledger = InvariantLedger(out_dir=out_dir, **ledger_kwargs)
    flight = FlightRecorder(out_dir=out_dir,
                            rearm_interval=max(60.0, duration / 8))
    obs = Observability(tracer=_tracer(duration), flight=flight,
                        ledger=ledger)
    metrics = serve(requests, obs)
    wall = time.time() - t0
    summary = metrics.summary(slo=SLO)

    rep = ledger.report()
    doc = events_doc(obs.tracer, faults=plan)

    def reserve(reqs):
        fresh = Observability(tracer=_tracer(duration))
        serve(reqs, fresh)
        return fresh

    res = replay(doc, reserve)

    lossmap = goodput_lossmap(obs.tracer.events, slo=SLO,
                              duration=summary["duration"],
                              ceiling_tok_s=ceiling) \
        if not obs.tracer.dropped else None

    bundle_errors: list[str] = []
    if out_dir is not None:
        with open(os.path.join(out_dir, "events.json"), "w") as f:
            json.dump(doc, f, default=float)
        write_trace(obs.tracer, os.path.join(out_dir, "trace.json"),
                    title=f"soak:{leg}", faults=plan)
        with open(os.path.join(out_dir, "ledger.json"), "w") as f:
            json.dump(rep, f, indent=1, default=float)
        with open(os.path.join(out_dir, "metrics.json"), "w") as f:
            json.dump({"schema": "obs_metrics/v1",
                       "metrics": {f"runtime_{k}": v
                                   for k, v in summary.items()
                                   if isinstance(v, (int, float))},
                       "lossmap": lossmap}, f, indent=1, default=float)
        bundle_errors += validate_events(doc)
        bundle_errors += validate_ledger(rep)
        for path in sorted(glob.glob(os.path.join(out_dir, "flight-*.json"))
                           + glob.glob(os.path.join(out_dir,
                                                    "ledger-*.json"))):
            with open(path) as f:
                bundle_errors += [f"{path}: {e}"
                                  for e in validate_bundle(json.load(f))]

    gate_errors: list[str] = []
    if "gates" in extra:
        gate_errors = extra["gates"](summary)

    row = {
        "leg": leg,
        "duration_s": duration,
        "wall_s": round(wall, 2),
        "requests": len(requests),
        "completed": summary["completed"],
        "cancelled": summary.get("cancelled", 0),
        "timed_out": summary.get("timed_out", 0),
        "tokens": summary["tokens"],
        "events": obs.tracer.n_emitted,
        "events_dropped": obs.tracer.dropped,
        "ledger_checks": sum(c["checks"]
                             for c in rep["contracts"].values()),
        "ledger_violations": rep["total_violations"],
        "flight_bundles": len(flight.bundles),
        "flight_rearms": flight.stats()["rearms"],
        "replay_ok": res.ok,
        "replay_detail": res.summary(),
        "span_digest": doc["span_digest"],
        "decision_digest": doc["decision_digest"],
        "artifact_errors": bundle_errors,
        "gate_errors": gate_errors,
        "lossmap": lossmap,
    }
    ok = (rep["total_violations"] == 0 and res.ok
          and not bundle_errors and not gate_errors
          and obs.tracer.dropped == 0)
    row["ok"] = ok
    return row


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hours", type=float, default=2.0,
                    help="total simulated hours across all legs "
                         "(virtual clock; wall time is minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="write per-leg artifact directories plus a "
                         "soak_report/v1 summary under DIR")
    ap.add_argument("--legs", default=",".join(LEGS),
                    help=f"comma-separated subset of: {', '.join(LEGS)}")
    args = ap.parse_args()
    legs = [l.strip() for l in args.legs.split(",") if l.strip()]
    unknown = [l for l in legs if l not in LEGS]
    if unknown:
        ap.error(f"unknown legs {unknown}; choose from {list(LEGS)}")
    per_leg = args.hours * 3600.0 / len(legs)

    rows = []
    for i, leg in enumerate(legs):
        out_dir = None
        if args.obs_dir:
            out_dir = os.path.join(args.obs_dir, leg)
            os.makedirs(out_dir, exist_ok=True)
        print(f"[{leg}] serving {per_leg:.0f} virtual seconds "
              f"(seed {args.seed + 17 * i}) ...")
        row = run_leg(leg, per_leg, args.seed + 17 * i, out_dir)
        rows.append(row)
        reap = ""
        if row["cancelled"] or row["timed_out"]:
            reap = (f" ({row['cancelled']} cancelled, "
                    f"{row['timed_out']} deadline-missed)")
        print(f"[{leg}] {row['completed']}/{row['requests']} requests"
              f"{reap}, {row['tokens']} tokens, {row['events']} events "
              f"({row['events_dropped']} dropped) "
              f"in {row['wall_s']:.1f}s wall")
        print(f"[{leg}] ledger: {row['ledger_checks']} checks, "
              f"{row['ledger_violations']} violations; "
              f"flight: {row['flight_bundles']} bundles "
              f"({row['flight_rearms']} re-arms)")
        print(f"[{leg}] {row['replay_detail']}")
        if row["lossmap"]:
            lm = row["lossmap"]
            parts = ", ".join(f"{c} {v:.2f}"
                              for c, v in sorted(lm["loss_tok_s"].items(),
                                                 key=lambda kv: -kv[1])
                              if v > 0)
            print(f"[{leg}] lossmap: ceiling {lm['ceiling_tok_s']:.1f} "
                  f"goodput {lm['goodput_tok_s']:.1f} tok/s"
                  + (f" ({parts})" if parts else ""))
        for err in row["artifact_errors"]:
            print(f"[{leg}] ARTIFACT FAIL  {err}")
        for err in row["gate_errors"]:
            print(f"[{leg}] GATE FAIL  {err}")
        if not row["ok"]:
            print(f"[{leg}] FAILED")

    report = {"schema": "soak_report/v1",
              "hours": args.hours,
              "seed": args.seed,
              "legs": rows,
              "ok": all(r["ok"] for r in rows)}
    if args.obs_dir:
        os.makedirs(args.obs_dir, exist_ok=True)
        with open(os.path.join(args.obs_dir, "soak_report.json"),
                  "w") as f:
            json.dump(report, f, indent=1, default=float)
        print(f"wrote soak report to "
              f"{os.path.join(args.obs_dir, 'soak_report.json')}")
    verdict = "PASS" if report["ok"] else "FAIL"
    total_checks = sum(r["ledger_checks"] for r in rows)
    total_viol = sum(r["ledger_violations"] for r in rows)
    print(f"soak {verdict}: {args.hours:.2f} simulated hours over "
          f"{len(legs)} legs, {total_checks} ledger checks, "
          f"{total_viol} violations, replay "
          f"{'MATCH' if all(r['replay_ok'] for r in rows) else 'MISMATCH'}"
          )
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
