"""§5 table: value of richer DAG topologies.  Skip (transitive closure)
vs strict line on the same instances, and tree index-policy optimality
gap vs exact expectimax (Thm 5.1/5.2 validation at benchmark scale)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import skip_dp, tree_dp
from repro.core.brute_force import bf_line
from repro.core.markov import MarkovChain
from repro.core.support import Support
from repro.core.traces import random_instance


def run() -> list[dict]:
    rng = np.random.default_rng(5)
    rows = []
    # skip vs line across cost scales
    for cost_scale, tag in [(0.05, "cheap"), (0.3, "expensive")]:
        gains = []
        t0 = time.perf_counter()
        for _ in range(10):
            p0, trans, costs, grid = random_instance(rng, 6, 8,
                                                     cost_scale=cost_scale)
            g = jnp.asarray(grid, jnp.float32)
            sup = Support(grid=g, edges=(g[1:] + g[:-1]) / 2)
            chain = MarkovChain(p0=jnp.asarray(p0, jnp.float32),
                                trans=jnp.asarray(trans, jnp.float32))
            line_val = bf_line(p0, trans, costs, grid)
            ec = skip_dp.edge_costs_skip_free(costs)
            skip_val = float(skip_dp.solve_skip(chain, ec, sup).value)
            gains.append((line_val - skip_val) / line_val)
        us = (time.perf_counter() - t0) * 1e6 / 10
        rows.append({
            "name": f"skip_vs_line_costs={tag}",
            "us_per_call": us,
            "derived": (f"mean_gain={np.mean(gains) * 100:.1f}% "
                        f"max={np.max(gains) * 100:.1f}%"),
        })
    # tree: index policy == optimal (gap should be ~0)
    def random_forest(rr, n, k, max_children=2):
        grid = np.sort(rr.uniform(0.05, 1.0, size=k)) + np.arange(k) * 1e-6
        parents, root_pmfs, trans_d = [], {}, {}
        for v in range(n):
            cands = [-1] + [u for u in range(v)
                            if sum(1 for p in parents if p == u)
                            < max_children]
            p = int(rr.choice(cands))
            parents.append(p)
            if p < 0:
                root_pmfs[v] = rr.dirichlet(np.ones(k))
            else:
                trans_d[v] = rr.dirichlet(np.ones(k), size=k)
        costs = rr.uniform(0.01, 0.2, size=n)
        return tree_dp.Forest(parents=tuple(parents), root_pmfs=root_pmfs,
                              trans=trans_d, costs=costs, grid=grid)

    gaps = []
    t0 = time.perf_counter()
    for seed in range(8):
        rr = np.random.default_rng(seed)
        forest = random_forest(rr, 5, 3)
        opt = tree_dp.solve_forest_exact(forest)
        pol = tree_dp.index_policy_value(forest)
        gaps.append(abs(pol - opt) / max(opt, 1e-9))
    us = (time.perf_counter() - t0) * 1e6 / 8
    rows.append({
        "name": "tree_index_vs_expectimax",
        "us_per_call": us,
        "derived": f"max_rel_gap={max(gaps):.2e} (Thm 5.1: 0 expected)",
    })
    return rows
