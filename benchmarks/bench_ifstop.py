"""Fig. 8 analogue: if-stop matrices on synthetic distributions.

Validates the paper's structural claim (App. D.3): the optimal stop rule
depends JOINTLY on (running min X, current loss R_i) and does not reduce
to any fixed per-ramp threshold.  Emits the matrices as CSV
(benchmarks/results/ifstop_*.csv) and reports a "thresholdness" score:
the best fixed-threshold agreement with the optimal rule (1.0 would mean
thresholding is optimal)."""

from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.line_dp import solve_line
from repro.core.markov import estimate_chain
from repro.core.support import build_support, quantize

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _traces(rng, kind: str, t: int, n: int) -> np.ndarray:
    if kind == "uniform_iid":
        return rng.uniform(0.01, 1.0, (t, n))
    if kind == "beta_decreasing":
        base = rng.beta(2, 5, (t, n))
        scale = np.linspace(1.0, 0.4, n)
        return np.clip(base * scale, 1e-3, 1.0)
    if kind == "markov_overthink":
        x = np.zeros((t, n))
        x[:, 0] = rng.uniform(0.2, 1.0, t)
        for i in range(1, n):
            bump = (rng.uniform(size=t) < 0.2) * rng.uniform(0, 0.5, t)
            x[:, i] = np.clip(0.7 * x[:, i - 1] * 0.8 + 0.1
                              + rng.normal(0, 0.05, t) + bump, 1e-3, 1.0)
        return x
    raise ValueError(kind)


def run() -> list[dict]:
    os.makedirs(RESULTS, exist_ok=True)
    rng = np.random.default_rng(3)
    rows = []
    n, k, t = 6, 24, 30_000
    for kind in ("uniform_iid", "beta_decreasing", "markov_overthink"):
        t0 = time.perf_counter()
        losses = _traces(rng, kind, t, n)
        costs = jnp.full((n,), 0.1, jnp.float32)  # 0.1 ms per ramp (D.3)
        sup = build_support(losses, k)
        bins = quantize(sup, jnp.asarray(losses))
        chain = estimate_chain(bins, k)
        tables = solve_line(chain, costs, sup)
        stop = np.asarray(tables.stop)            # (n, K, K+2)
        us = (time.perf_counter() - t0) * 1e6

        np.savetxt(os.path.join(RESULTS, f"ifstop_{kind}.csv"),
                   stop.reshape(n, -1), fmt="%d", delimiter=",")

        # thresholdness: best fixed threshold on R_i replicating the rule
        # (decision at node i+1 given current loss bin s, min over x rows
        # that are reachable).
        grid_rows = stop[:, :, 1:k + 1]           # exclude 0/inf sentinels
        best_agree = 0.0
        for thr_bin in range(k):
            # threshold rule: stop iff current loss bin <= thr
            pred = np.zeros_like(grid_rows)
            pred[:, :thr_bin + 1, :] = 1
            best_agree = max(best_agree,
                             float((pred == grid_rows).mean()))
        x_dependence = float(np.mean(
            grid_rows.min(axis=2) != grid_rows.max(axis=2)))
        rows.append({
            "name": f"ifstop_{kind}",
            "us_per_call": us,
            "derived": (f"best_threshold_agreement={best_agree:.3f} "
                        f"x_dependent_frac={x_dependence:.3f} "
                        f"value={float(tables.value):.4f}"),
        })
    return rows
