"""CI cascade smoke: the multi-model acceptance gate (DESIGN.md §10).

Runs the `bench_runtime.cascade_vs_monolith` sweep at a deterministic
seed (virtual clock, SimStepper — no model params, CI-fast), writes the
metrics JSON artifact, and asserts the recall cascade's claims:

  1. RECALL-ON BEATS RECALL-OFF: at the highest pre-wall rate, the
     recall cascade's goodput strictly exceeds the no-recall (commit)
     cascade's — de-escalation recycles the scarce large-model lanes
     that the commit policy hoards for whole request lifetimes — while
     its mean served loss is also strictly better (argmin over probed
     nodes vs last-probed).
  2. PARETO: at that rate the recall cascade dominates large-only and
     the no-recall cascade OUTRIGHT (better goodput AND better loss),
     and dominates small-only in the toleranced sense: goodput within
     ``GOODPUT_TOL`` (2%) while improving mean served loss by at least
     ``LOSS_MARGIN`` (0.01 absolute; in practice ~40% relative).  The
     tolerance is explicit and honest: escalation catch-up is real
     compute, so a quality-improving cascade can tie the cheapest
     monolith's goodput only up to virtual-clock step granularity —
     the claim is "frontier-dominant at negligible goodput concession",
     which is exactly the paper's taming-the-trade-off statement.

Exit code 1 on any violated claim, so the CI job fails loudly.
"""

from __future__ import annotations

import argparse
import json
import sys

GOODPUT_TOL = 0.02     # relative goodput concession on the tie axis
LOSS_MARGIN = 0.01     # required absolute served-loss improvement
RATES = (2.0, 3.0)     # the bench's mid / highest pre-wall rates
DURATION = 30.0
PARETO_RATE = 2.0      # where the toleranced frontier claim is checked
NR_RATE = 3.0          # highest pre-wall rate: lane-hoarding shows


def _points(rows, rate):
    pts = {r["cascade"]: r for r in rows
           if r.get("rate") == rate and r.get("cascade")}
    missing = [v for v in ("small_only", "large_only",
                           "cascade_norecall", "cascade_recall")
               if v not in pts]
    if missing:
        raise KeyError(f"sweep rows missing variants {missing} at "
                       f"rate {rate}")
    gp = {v: pts[v]["summary"]["goodput_tok_s"] for v in pts}
    loss = {v: pts[v]["served_loss_mean"] for v in pts}
    return pts, gp, loss


def check(rows: list[dict]) -> list[str]:
    """Verify the claims on sweep rows; returns failure messages."""
    failures = []
    try:
        pts, gp, loss = _points(rows, PARETO_RATE)
    except KeyError as e:
        return [str(e)]
    # the frontier claim: at PARETO_RATE the recall cascade dominates
    # large-only OUTRIGHT and small-only / no-recall in the toleranced
    # sense (goodput within GOODPUT_TOL, loss better by >= LOSS_MARGIN)
    rec_g, rec_l = gp["cascade_recall"], loss["cascade_recall"]
    if not (rec_g > gp["large_only"] and rec_l < loss["large_only"]):
        failures.append(
            f"recall ({rec_g:.2f}, {rec_l:.3f}) does not dominate "
            f"large_only ({gp['large_only']:.2f}, "
            f"{loss['large_only']:.3f}) at rate {PARETO_RATE}")
    for v in ("small_only", "cascade_norecall"):
        dominated = (rec_g >= (1 - GOODPUT_TOL) * gp[v]
                     and rec_l <= loss[v] - LOSS_MARGIN) \
            or (rec_g > gp[v] and rec_l <= loss[v])
        if not dominated:
            failures.append(
                f"recall ({rec_g:.2f}, {rec_l:.3f}) does not dominate "
                f"{v} ({gp[v]:.2f}, {loss[v]:.3f}) within "
                f"tol={GOODPUT_TOL} / margin={LOSS_MARGIN} at rate "
                f"{PARETO_RATE}")
    # sanity: the machinery actually escalated and re-pinned
    cs = pts["cascade_recall"].get("cascade_stats") or {}
    if not cs.get("escalations", 0) > 0:
        failures.append("recall cascade never escalated — the sweep is "
                        "not exercising the ladder")

    # recall-on vs recall-off at the highest pre-wall rate: strict
    # goodput win (de-escalation recycles the scarce large lanes the
    # commit policy hoards) AND strictly better served loss
    try:
        _, gp_hi, loss_hi = _points(rows, NR_RATE)
    except KeyError as e:
        return failures + [str(e)]
    if not gp_hi["cascade_recall"] > gp_hi["cascade_norecall"]:
        failures.append(
            f"recall goodput {gp_hi['cascade_recall']:.2f} <= "
            f"no-recall {gp_hi['cascade_norecall']:.2f} at rate "
            f"{NR_RATE}")
    if not loss_hi["cascade_recall"] < loss_hi["cascade_norecall"]:
        failures.append(
            f"recall loss {loss_hi['cascade_recall']:.3f} >= no-recall "
            f"{loss_hi['cascade_norecall']:.3f} at rate {NR_RATE}")
    return failures


def main() -> int:
    from benchmarks.bench_runtime import cascade_vs_monolith
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="cascade-metrics.json",
                    help="write the sweep rows JSON here (CI artifact)")
    ap.add_argument("--trace-out", default=None,
                    help="export the recall-cascade leg's decision "
                         "trace (Perfetto JSON) at the highest rate — "
                         "the artifact benchmarks.check_trace validates")
    ap.add_argument("--metrics-out", default=None,
                    help="write an obs_metrics/v1 snapshot of the "
                         "traced leg's summary + cascade counters")
    args = ap.parse_args()
    rows = cascade_vs_monolith(rates=RATES, duration=DURATION,
                               keep_trace=bool(args.trace_out
                                               or args.metrics_out))
    tracers = {row["name"]: row.pop("_trace")
               for row in rows if "_trace" in row}
    for row in rows:  # regret_smoke owns the meter docs; drop the live handle
        row.pop("_regret", None)
    if args.trace_out or args.metrics_out:
        name = f"runtime_sim_cascade_cascade_recall_r{max(RATES):g}"
        row = next(r for r in rows if r["name"] == name)
        if args.trace_out:
            from repro.serving.obs.export import write_trace
            write_trace(tracers[name], args.trace_out, title=name)
            print(f"wrote {args.trace_out}")
        if args.metrics_out:
            from repro.serving.obs import MetricsRegistry
            reg = MetricsRegistry()
            reg.absorb("runtime", row["summary"], leg=name)
            reg.absorb("cascade", row["cascade_stats"], leg=name)
            reg.absorb("trace", tracers[name].stats(), leg=name)
            reg.to_json(args.metrics_out)
            print(f"wrote {args.metrics_out}")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    for row in rows:
        print(f"{row['name']}: {row['derived']}")
    failures = check(rows)
    for msg in failures:
        print(f"FAIL  {msg}")
    print(f"wrote {args.out}; {len(failures)} failed claims")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
