"""Thm 4.5 inference-cost table: the recall-index strategy is an
O(1)/node table lookup — per-sample decision latency vs n and batch size
through the jit'd ``strategy.evaluate`` scan, the number the serving
engine pays per segment.

The evaluator `lax.scan`s one `observe` body over the (static) node
axis, so trace/compile time is ~constant in n instead of growing with an
unrolled per-node Python loop — ``trace_ms`` in the derived column
reports the first-call (trace + compile) cost alongside steady-state
latency."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategy
from repro.core.line_dp import solve_line
from repro.core.markov import MarkovChain, sample_chain
from repro.core.support import Support
from repro.core.traces import random_instance


def run() -> list[dict]:
    rng = np.random.default_rng(2)
    rows = []
    for n, t in [(6, 4096), (12, 4096), (24, 4096), (48, 4096),
                 (12, 65_536)]:
        p0, trans, costs, grid = random_instance(rng, n, 32)
        g = jnp.asarray(grid, jnp.float32)
        sup = Support(grid=g, edges=(g[1:] + g[:-1]) / 2)
        chain = MarkovChain(p0=jnp.asarray(p0, jnp.float32),
                            trans=jnp.asarray(trans, jnp.float32))
        cj = jnp.asarray(costs, jnp.float32)
        tables = solve_line(chain, cj, sup)
        bins = sample_chain(chain, jax.random.PRNGKey(0), t)
        losses = g[bins]
        strat = strategy.RecallIndexStrategy(tables, sup, costs=cj)

        fn = jax.jit(lambda l: strategy.evaluate(strat, l).served_node)
        t0 = time.perf_counter()
        fn(losses).block_until_ready()
        trace_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            fn(losses).block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({
            "name": f"policy_lookup_n={n}_batch={t}",
            "us_per_call": us,
            "derived": (f"ns_per_sample_per_node={us * 1e3 / (t * n):.1f} "
                        f"trace_ms={trace_ms:.0f}"),
        })
    return rows
