"""Thm 4.5 inference-cost table: the recall-index policy is an O(1)/node
table lookup — per-sample decision latency vs n and batch size (jit'd,
vectorized), the number the serving engine pays per segment."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies
from repro.core.line_dp import solve_line
from repro.core.markov import MarkovChain, sample_chain
from repro.core.support import Support
from repro.core.traces import random_instance


def run() -> list[dict]:
    rng = np.random.default_rng(2)
    rows = []
    for n, t in [(6, 4096), (12, 4096), (24, 4096), (12, 65_536)]:
        p0, trans, costs, grid = random_instance(rng, n, 32)
        g = jnp.asarray(grid, jnp.float32)
        sup = Support(grid=g, edges=(g[1:] + g[:-1]) / 2)
        chain = MarkovChain(p0=jnp.asarray(p0, jnp.float32),
                            trans=jnp.asarray(trans, jnp.float32))
        cj = jnp.asarray(costs, jnp.float32)
        tables = solve_line(chain, cj, sup)
        bins = sample_chain(chain, jax.random.PRNGKey(0), t)
        losses = g[bins]

        fn = jax.jit(lambda l, b: policies.recall_index(
            tables, l, b, cj).served_node)
        fn(losses, bins).block_until_ready()
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            fn(losses, bins).block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({
            "name": f"policy_lookup_n={n}_batch={t}",
            "us_per_call": us,
            "derived": f"ns_per_sample_per_node={us * 1e3 / (t * n):.1f}",
        })
    return rows
