"""CI adaptive smoke: the control-plane acceptance gate (DESIGN.md
§11).

Runs `bench_runtime.adaptive_vs_frozen` at its deterministic seeds
(virtual clock, SimStepper — no model params, CI-fast), writes the
metrics JSON artifact, and asserts the controller's claims on the
seeded diurnal workload whose steepest inflections the gear switches
must ride:

  1. STRICT GOODPUT DOMINANCE: the adaptive leg's goodput strictly
     exceeds EVERY single frozen gear's.  The frozen gears' stale
     calibration over-probes the drifted serve mix, so their real
     capacity sits below the diurnal peak; switching + recalibration
     is what holds the peak.
  2. NO QUALITY GIVEBACK: the adaptive leg's mean served loss is <=
     the loss of the best-goodput frozen gear — the trade-off is
     tamed, not shifted onto the quality axis.
  3. THE MACHINERY RAN: >= ``MIN_SWITCHES`` gear switches and >=
     ``MIN_RECALS`` online recalibrations actually landed.
  4. ZERO DROPPED OR STALLED LANES: every admitted request finished,
     on the adaptive leg and on every frozen leg.
  5. ZERO MID-SERVE RETRACES: the stepper's jitted decide compiled
     exactly once across all swaps and publishes
     (``decide_cache_size() == 1`` — the arrays-as-args hot-swap
     contract).

Exit code 1 on any violated claim, so the CI job fails loudly.
"""

from __future__ import annotations

import argparse
import json
import sys

MIN_SWITCHES = 2
MIN_RECALS = 1


def check(rows: list[dict]) -> list[str]:
    """Verify the claims on sweep rows; returns failure messages."""
    adaptive = [r for r in rows if r.get("adaptive") == "adaptive"]
    frozen = [r for r in rows
              if str(r.get("adaptive", "")).startswith("frozen_")]
    if len(adaptive) != 1 or not frozen:
        return [f"expected 1 adaptive + >=1 frozen rows, got "
                f"{len(adaptive)} adaptive / {len(frozen)} frozen"]
    ad = adaptive[0]
    failures = []

    # 1. strict goodput dominance over every frozen gear
    ad_g = ad["summary"]["goodput_tok_s"]
    for r in frozen:
        g = r["summary"]["goodput_tok_s"]
        if not ad_g > g:
            failures.append(
                f"adaptive goodput {ad_g:.2f} <= frozen "
                f"{r['gear']} {g:.2f}")

    # 2. served loss no worse than the best-goodput frozen gear
    best = max(frozen, key=lambda r: r["summary"]["goodput_tok_s"])
    ad_l, best_l = ad["served_loss_mean"], best["served_loss_mean"]
    if not ad_l <= best_l:
        failures.append(
            f"adaptive served loss {ad_l:.4f} > best-goodput frozen "
            f"({best['gear']}) {best_l:.4f}")

    # 3. the control plane actually switched and recalibrated
    if ad.get("gear_switches", 0) < MIN_SWITCHES:
        failures.append(f"only {ad.get('gear_switches', 0)} gear "
                        f"switches (need >= {MIN_SWITCHES})")
    if ad.get("recalibrations", 0) < MIN_RECALS:
        failures.append(f"only {ad.get('recalibrations', 0)} "
                        f"recalibrations (need >= {MIN_RECALS})")

    # 4. zero dropped/stalled lanes on every leg
    for r in [ad] + frozen:
        if r.get("completed") != r.get("n_requests"):
            failures.append(
                f"{r['name']}: {r.get('completed')}/{r.get('n_requests')}"
                f" requests finished — dropped or stalled lanes")

    # 5. zero jit retraces mid-serve across swaps + publishes
    if ad.get("decide_cache_size") != 1:
        failures.append(
            f"decide compiled {ad.get('decide_cache_size')} times — "
            f"a swap or publish retraced mid-serve")
    return failures


def main() -> int:
    from benchmarks.bench_runtime import adaptive_vs_frozen
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="adaptive-metrics.json",
                    help="write the sweep rows JSON here (CI artifact)")
    args = ap.parse_args()
    rows = adaptive_vs_frozen()
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    for row in rows:
        print(f"{row['name']}: {row['derived']}")
    failures = check(rows)
    for msg in failures:
        print(f"FAIL  {msg}")
    print(f"wrote {args.out}; {len(failures)} failed claims")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
