"""Continuous-batching runtime benchmarks (DESIGN.md §7-8):

  1. Arrival-rate x strategy sweep in SIMULATION mode — the same
     scheduler/queue/metrics stack as real serving, with tokens replayed
     from synthetic early-exit traces and a virtual clock pricing each
     step at per-lane probe cost.  Shows the T-Tamer recall strategies
     converting probe savings into GOODPUT (tokens/s within the TTFT
     SLO) as load approaches the always_last capacity wall.

  2. Lane recycling vs the fixed-batch discipline, twice: in sim units
     (batch-cost model, heterogeneous token budgets — stragglers idle
     the whole width), and on the REAL smoke model, continuous batching
     through `serving.runtime` vs batched `Engine.generate` at equal
     batch width (the fixed batch pads every request to its batch max).

  3. Paged vs ring KV on the REAL smoke model at EQUAL HBM budget
     (serving.kvpool): a shared-prefix workload under both ``kv`` modes
     reports goodput/TTFT side by side plus pages-in-use, prefix hit
     rate, and COW splits — the memory headroom prefix sharing frees is
     the admission capacity the ring discipline burns on duplicates.
     A third leg serves the same workload with CHUNKED PREFILL enabled
     (DESIGN.md §9) — same streams, plus the chunk/skip counters.

  4. Chunked vs stop-the-world admission (``chunked_vs_stopworld``):
     a rate x prompt-length-mix sweep under the sim cost model, where
     stop-the-world prefill is a serial stall and the co-scheduled
     chunk is priced at the piggyback roofline max(decode, chunk) —
     TTFT p50/p99 and goodput as load approaches the wall.

  5. Adaptive control plane vs frozen gears (``adaptive_vs_frozen``,
     DESIGN.md §11): the same seeded diurnal workload and drifted
     serve mix under the `AdaptiveController` (gear switching + online
     recalibration) and under each gear frozen — the CI adaptive smoke
     pins strict goodput dominance at equal-or-better served loss.

Run standalone for the CI smoke + JSON artifacts:

  python -m benchmarks.bench_runtime --smoke --out runtime-metrics.json \
      --json

``--json`` (over)writes the stable ``BENCH_runtime.json`` at the repo
root (schema ``bench_runtime/v6``: one row per rate x strategy x
kv-mode x prefill-mode x cascade-variant x adaptive-leg with goodput /
TTFT p50/p99 / pages-in-use; earlier fields are unchanged — v2 added
the ``prefill`` axis + chunk token counters, v3 the ``cascade`` axis +
served-loss quality axis, v4 the ``adaptive`` axis + active gear id +
gear-switch / recalibration counters, v5 the decision-attribution
cells rolled up from the observability tracer, v6 the decision-quality
regret/frontier axis from the `RegretMeter`).  Each run is one
snapshot; the
trajectory accumulates across commits via git history and the per-run
CI artifact upload, and ``benchmarks/check_regression.py`` (CI) fails
>20% goodput drops at matching virtual-clock points.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro import strategy
from repro.core import traces
from repro.serving import runtime as rt
from repro.serving.obs import (Observability, RegretMeter,
                               decision_attribution)
from repro.serving.runtime.request import Request
from repro.serving.runtime.workload import WorkloadSpec, make_workload

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# virtual cost model: one node-probe on one lane costs SEG_TIME/lane,
# plus a fixed per-step dispatch overhead (both in sim seconds);
# PREFILL_TOK prices one prompt token of admission prefill
SEG_TIME = 0.01
OVERHEAD = 0.002
PREFILL_TOK = 0.0025
SLO = 0.5
LANES = 4
N_NODES = 6


def _sim_setup(seed: int = 0):
    rng = np.random.default_rng(seed)
    losses, _, flops = traces.ee_like_traces(rng, 6_000, N_NODES,
                                             overthink_prob=0.25)
    lam = 0.6
    casc = strategy.Cascade.from_traces(losses[:3_000], (1 - lam) * flops,
                                        k=16, lam=lam)
    return casc, losses[3_000:]


def _serve_sim(casc, bank_traces, requests, *, cost="lane",
               static_batching=False, lanes=LANES):
    bank, sid_of = rt.build_bank(requests, rt.cascade_factory(casc),
                                 ("recall_index", None))
    stepper = rt.SimStepper(bank, bank_traces, n_lanes=lanes,
                            seg_time=SEG_TIME, overhead=OVERHEAD,
                            cost=cost)
    server = rt.Server(stepper, rt.LaneScheduler(lanes), sid_of, slo=SLO,
                       static_batching=static_batching)
    return server.serve(requests).summary(slo=SLO)


def sweep_rate_strategy(*, rates, names, duration, seed=0):
    """Arrival rate x strategy -> goodput/throughput rows (sim mode)."""
    casc, bank_traces = _sim_setup(seed)
    rows = []
    for rate in rates:
        for name in names:
            spec = WorkloadSpec(rate=rate, duration=duration,
                                prompt_len=8, max_tokens=(4, 32),
                                seed=seed + 17, strategy=name)
            requests = make_workload("poisson", spec)
            s = _serve_sim(casc, bank_traces, requests)
            rows.append({
                "name": f"runtime_sim_{name}_r{rate:g}",
                "us_per_call": s["duration"] / max(s["tokens"], 1) * 1e6,
                "derived": (f"goodput={s['goodput_tok_s']:.1f}tok_s "
                            f"thru={s['throughput_tok_s']:.1f}tok_s "
                            f"slo_att={100 * s['slo_attainment']:.0f}% "
                            f"ttft_p95={s['ttft']['p95']:.2f}s "
                            f"seg_saved_lane="
                            f"{100 * s['segments_saved_lane']:.0f}% "
                            f"gear=static:{name}"),
                "summary": s, "rate": rate, "strategy": name, "kv": "sim",
                "gear": f"static:{name}",
            })
    return rows


def recycling_vs_static_sim(*, n_requests, seed=0):
    """Equal-width continuous vs fixed-batch admission, sim batch-cost
    model (what the masked batch engine pays): heterogeneous budgets
    make stragglers idle the width under static batching."""
    casc, bank_traces = _sim_setup(seed)
    spec = WorkloadSpec(rate=1e9, duration=n_requests / 1e9 + 1e-6,
                        prompt_len=8, max_tokens=(4, 32), seed=seed + 29,
                        strategy="recall_index")
    requests = make_workload("poisson", spec)[:n_requests]
    rows = []
    for label, static in (("continuous", False), ("static", True)):
        s = _serve_sim(casc, bank_traces, requests, cost="batch",
                       static_batching=static)
        rows.append({
            "name": f"runtime_sim_recycle_{label}",
            "us_per_call": s["duration"] / max(s["tokens"], 1) * 1e6,
            "derived": (f"thru={s['throughput_tok_s']:.1f}tok_s "
                        f"duration={s['duration']:.1f}s "
                        f"tokens={s['tokens']}"),
            "summary": s, "strategy": "recall_index", "kv": "sim",
        })
    return rows


def recycling_vs_engine_real(*, n_requests=12, lanes=LANES, seed=0):
    """REAL smoke model: continuous batching vs fixed-batch
    `Engine.generate` at equal batch width.  The fixed batch must decode
    every request to the batch max, so useful-token throughput drops."""
    import jax
    import time
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models.param import materialize
    from repro.serving.engine import Engine

    cfg = get_config("paper-ee-100m", smoke=True)
    key = jax.random.PRNGKey(seed)
    params = materialize(M.model_defs(cfg), key)
    casc = strategy.Cascade.calibrate(params, cfg, key, 0.5, k=12,
                                      t=128, seq=16)
    prompt_len, cache_len = 16, 48
    spec = WorkloadSpec(rate=1e9, duration=n_requests / 1e9 + 1e-6,
                        prompt_len=prompt_len, vocab=cfg.vocab,
                        max_tokens=(2, 12), seed=seed,
                        strategy="recall_index")
    requests = make_workload("poisson", spec)[:n_requests]

    mk = rt.cascade_factory(casc)
    # continuous batching (compile off the clock via server warmup)
    bank, sid_of = rt.build_bank(requests, mk, ("recall_index", None))
    stepper = rt.EngineStepper(params, cfg, bank, n_lanes=lanes,
                               cache_len=cache_len, prompt_len=prompt_len)
    server = rt.Server(stepper, rt.LaneScheduler(lanes), sid_of, slo=SLO)
    s = server.serve(requests).summary(slo=SLO)

    # fixed-batch baseline: batches of `lanes`, each decoded to its max
    engine = Engine(params, cfg, mk("recall_index", None),
                    cache_len=cache_len)
    warm = {"tokens": np.stack([r.prompt for r in requests[:lanes]])}
    engine.generate(warm, 2)  # compile off the clock
    useful = 0
    t0 = time.perf_counter()
    for i in range(0, len(requests), lanes):
        batch = requests[i:i + lanes]
        prompts = {"tokens": np.stack(
            [batch[j % len(batch)].prompt for j in range(lanes)])}
        engine.generate(prompts, max(r.max_tokens for r in batch))
        useful += sum(r.max_tokens for r in batch)
    dt = max(time.perf_counter() - t0, 1e-9)

    return [
        {"name": "runtime_engine_continuous",
         "us_per_call": 1e6 / max(s["throughput_tok_s"], 1e-9),
         "derived": (f"thru={s['throughput_tok_s']:.1f}tok_s "
                     f"tokens={s['tokens']} "
                     f"seg_saved_batch="
                     f"{100 * s['segments_saved_batch']:.0f}%"),
         "summary": s, "strategy": "recall_index", "kv": "ring"},
        {"name": "runtime_engine_fixed_batch",
         "us_per_call": 1e6 / (useful / dt),
         "derived": (f"thru={useful / dt:.1f}tok_s tokens={useful} "
                     f"(each batch padded to its max budget)"),
         "summary": {"throughput_tok_s": useful / dt, "tokens": useful,
                     "duration": dt},
         "strategy": "recall_index", "kv": "ring"},
    ]


def mixed_prompt_requests(rate, duration, seed, *, short_len=8,
                          long_len=64, strategy="recall_index"):
    """A rate x prompt-length MIX: 3/4 of the arrival rate carries
    short prompts, 1/4 long ones — the workload where stop-the-world
    admission hurts most (every long prefill stalls every decode lane)
    and where the chunk planner's prompt-length buckets matter."""
    short = make_workload("poisson", WorkloadSpec(
        rate=rate * 0.75, duration=duration, prompt_len=short_len,
        max_tokens=(4, 24), seed=seed + 101, strategy=strategy))
    long = make_workload("poisson", WorkloadSpec(
        rate=rate * 0.25, duration=duration, prompt_len=long_len,
        max_tokens=(4, 24), seed=seed + 103, strategy=strategy))
    merged = sorted(short + long, key=lambda r: (r.arrival, len(r.prompt)))
    return [Request(rid=rid, prompt=r.prompt, max_tokens=r.max_tokens,
                    arrival=r.arrival, lam=r.lam, strategy=r.strategy)
            for rid, r in enumerate(merged)]


def chunked_vs_stopworld(*, rates, duration, seed=0, chunk=16, budget=32):
    """Chunked prefill co-scheduled with decode vs stop-the-world
    admission, same virtual cost model, same mixed-prompt workload
    (DESIGN.md §9).  Token decisions are (rid, token)-keyed in sim, so
    the two modes emit bit-identical streams by construction — this
    sweep measures what the restructuring buys on the CLOCK: TTFT
    p50/p99 and goodput as the arrival rate approaches the wall."""
    casc, bank_traces = _sim_setup(seed)
    rows = []
    for rate in rates:
        requests = mixed_prompt_requests(rate, duration, seed)
        for mode in ("stopworld", "chunked"):
            bank, sid_of = rt.build_bank(requests,
                                         rt.cascade_factory(casc),
                                         ("recall_index", None))
            stepper = rt.SimStepper(
                bank, bank_traces, n_lanes=LANES, seg_time=SEG_TIME,
                overhead=OVERHEAD, prefill_tok_time=PREFILL_TOK,
                prefill_chunk=(chunk if mode == "chunked" else None),
                prefill_budget=budget)
            server = rt.Server(stepper, rt.LaneScheduler(LANES), sid_of,
                               slo=SLO)
            s = server.serve(requests).summary(slo=SLO)
            rows.append({
                "name": f"runtime_sim_prefill_{mode}_r{rate:g}",
                "us_per_call": s["duration"] / max(s["tokens"], 1) * 1e6,
                "derived": (f"goodput={s['goodput_tok_s']:.1f}tok_s "
                            f"ttft_p50={s['ttft']['p50']:.3f}s "
                            f"ttft_p99={s['ttft']['p99']:.3f}s "
                            f"slo_att={100 * s['slo_attainment']:.0f}% "
                            f"gear=static:recall_index"),
                "summary": s, "rate": rate, "strategy": "recall_index",
                "kv": "sim", "prefill": mode,
                "gear": "static:recall_index",
            })
    return rows


# ---------------------------------------------------------------------------
# multi-model cascade vs monoliths (serving.cascade, DESIGN.md §10)
# ---------------------------------------------------------------------------

# the ladder's virtual cost model: the large model is 4x the small
# model per node-probe, with fewer lanes (scarce escalation capacity —
# what the no-recall commit policy hoards for request lifetimes and the
# recall policy's de-escalations recycle).  Prefill/catch-up tokens are
# priced far below decode probes: compute-bound chunks amortize (the
# same physics as §9's piggyback roofline).
N_SMALL, N_LARGE = 3, 3
SEG_SMALL, SEG_LARGE = SEG_TIME, 4 * SEG_TIME
PT_SMALL, PT_LARGE = 0.001, 0.004
LANES_LARGE = 3
CASCADE_LAM = 0.92
NR_THRESHOLD = 0.45            # no-recall cascade's escalation trigger
CASCADE_PATIENCE = 8           # recall: release a rung idle this long
CASCADE_CHUNK = 64             # catch-up chunk cap (1-step catch-ups)
CASCADE_BUDGETS = (64, 128)    # per-model catch-up tokens per step
HEAD_OVERTHINK = 0.35          # extra overthink prob on model heads
# effective node depths: each model is a COMPLETE network — a small
# model's ramps sit near its own head, while a deep model's FIRST ramp
# is far from its head: a committed no-recall ladder that must serve
# whatever node it stopped on cannot reach the frontier there
DEPTHS = ((2.2, 2.8, 3.2), (4.0, 8.0, 12.0))


def _cascade_sim_setup(seed: int = 0, depths=DEPTHS):
    """Multi-model calibration traces: one (T, 6) bank whose first 3
    columns are the small model's ramps+head and last 3 the large
    model's (`core.traces.cascade_traces`) — the large model is better
    ON AVERAGE, but both heads overthink a sizable fraction of tokens
    (the §6 regime): a no-recall server is stuck serving the last node
    it probed, while recall serves the argmin over everything it
    probed, exits the small model early on easy tokens, and escalates
    only the hard ones.  That asymmetry is what the frontier
    measures.  ``depths`` parameterizes the effective node depths
    (`regret_smoke` sweeps deeper large-model ladders through it)."""
    from repro.serving.cascade import ModelBank, ModelSpec
    rng = np.random.default_rng(seed)
    losses, boundaries = traces.cascade_traces(
        rng, 6_000, depths, overthink_prob=0.15,
        head_overthink=HEAD_OVERTHINK)
    assert boundaries == (N_SMALL, N_LARGE)
    lam = CASCADE_LAM
    # objective-unit node costs mirror the seg-time ratio: uniform
    # per-segment cost within a model, the large model 4x per node
    costs = np.concatenate([np.full(N_SMALL, 1.0 / N_SMALL),
                            np.full(N_LARGE, 4.0 / N_LARGE)])
    casc = strategy.Cascade.from_traces(
        losses[:3_000], (1 - lam) * costs, k=16, lam=lam,
        boundaries=(N_SMALL, N_LARGE))
    bank = ModelBank([
        ModelSpec("small", N_SMALL, n_lanes=LANES,
                  seg_time=SEG_SMALL, prefill_tok_time=PT_SMALL),
        ModelSpec("large", N_LARGE, n_lanes=LANES_LARGE,
                  seg_time=SEG_LARGE, prefill_tok_time=PT_LARGE),
    ])
    return casc, bank, losses[3_000:]


def _cascade_variant_stepper(variant, casc, bank, bank_traces, requests):
    """One sweep leg: a (stepper, sid_of, n_slots, label) quadruple."""
    from repro.serving.cascade import CascadeSimStepper

    if variant in ("small_only", "large_only"):
        # a monolith serves its model at full depth (always_last) over
        # its OWN trace columns, lanes, and per-token cost
        lo, hi = ((0, N_SMALL) if variant == "small_only"
                  else (N_SMALL, N_SMALL + N_LARGE))
        n = hi - lo
        lanes = LANES if variant == "small_only" else LANES_LARGE
        seg = SEG_SMALL if variant == "small_only" else SEG_LARGE
        pt = PT_SMALL if variant == "small_only" else PT_LARGE
        mono = strategy.Cascade.uniform(n, lam=1.0)
        bank_s, sid_of = rt.build_bank(
            requests, lambda name, lam: strategy.make(
                "always_last", mono), ("always_last", None))
        stepper = rt.SimStepper(bank_s, bank_traces[:, lo:hi],
                                n_lanes=lanes, seg_time=seg,
                                overhead=OVERHEAD, prefill_tok_time=pt,
                                prefill_chunk=16, prefill_budget=32)
        return stepper, sid_of, lanes
    if variant == "cascade_recall":
        def mk(name, lam):
            return strategy.make("skip_recall", casc, mode="cascade")
        policy = "recall"
    elif variant == "cascade_norecall":
        def mk(name, lam):
            return strategy.make("norecall_threshold", casc,
                                 threshold=NR_THRESHOLD, lam=1.0)
        policy = "commit"
    else:
        raise ValueError(f"unknown cascade variant {variant!r}")
    bank_s, sid_of = rt.build_bank(requests, mk, ("cascade", None))
    stepper = CascadeSimStepper(bank, bank_s, bank_traces,
                                overhead=OVERHEAD, policy=policy,
                                patience=CASCADE_PATIENCE,
                                chunk=CASCADE_CHUNK,
                                budgets=list(CASCADE_BUDGETS))
    return stepper, sid_of, LANES


CASCADE_VARIANTS = ("small_only", "large_only", "cascade_norecall",
                    "cascade_recall")


def cascade_vs_monolith(*, rates, duration, seed=0,
                        variants=CASCADE_VARIANTS, keep_trace=False,
                        depths=DEPTHS):
    """Rate x variant sweep: {small-only, large-only, cascade-no-recall,
    cascade-recall} on the SAME request stream and trace rows, reporting
    goodput AND mean served trace loss — the two Pareto axes.  The
    recall cascade's argmin serving plus retained-residency re-pins are
    what let it dominate both monoliths and the no-recall ladder at the
    pre-wall rates (pinned by the CI cascade smoke).

    Every leg serves TRACED (repro.serving.obs): goodput is virtual-
    clock, so the host-side tracer cannot move it, and the trace's
    token events roll up into per-row decision-ATTRIBUTION cells
    (exit node x gear x escalated -> tokens / latency / served loss).
    ``keep_trace=True`` additionally hands each row its live tracer
    under the non-JSON ``"_trace"`` key (cascade_smoke exports one) and
    the live `RegretMeter` under ``"_regret"`` (regret_smoke exports
    its ``obs_regret/v1``/``obs_pareto/v1`` docs).

    From v6 on, every cascade leg also serves with the `RegretMeter`
    armed: per-request distance from the offline-optimal walk over the
    SAME trace bank the stepper replays (exact mode), rolled up as the
    ``regret_mean``/``regret_p99``/``pareto_points`` row keys — the
    separation theorem as a regression axis."""
    casc, bank, bank_traces = _cascade_sim_setup(seed, depths=depths)
    rows = []
    for rate in rates:
        spec = WorkloadSpec(rate=rate, duration=duration, prompt_len=8,
                            max_tokens=(4, 32), seed=seed + 41)
        requests = make_workload("poisson", spec)
        for variant in variants:
            stepper, sid_of, lanes = _cascade_variant_stepper(
                variant, casc, bank, bank_traces, requests)
            obs = Observability()
            if variant.startswith("cascade_"):
                # monoliths serve sliced trace columns under their own
                # uniform ladder — the calibrated oracle is not defined
                # for them, so only ladder variants meter regret
                obs.regret = RegretMeter(casc)
            server = rt.Server(stepper, rt.LaneScheduler(lanes), sid_of,
                               slo=SLO, obs=obs)
            s = server.serve(requests).summary(slo=SLO)
            cs = stepper.cascade_stats() \
                if hasattr(stepper, "cascade_stats") else None
            loss = (cs["mean_served_loss"] if cs
                    else stepper.mean_served_loss)
            row = {
                "name": f"runtime_sim_cascade_{variant}_r{rate:g}",
                "us_per_call": s["duration"] / max(s["tokens"], 1) * 1e6,
                "derived": (f"goodput={s['goodput_tok_s']:.1f}tok_s "
                            f"loss={loss:.3f} "
                            f"ttft_p99={s['ttft']['p99']:.2f}s "
                            f"slo_att={100 * s['slo_attainment']:.0f}% "
                            f"gear=static:{variant}"),
                "summary": s, "rate": rate, "strategy": "cascade",
                "kv": "sim", "cascade": variant,
                "gear": f"static:{variant}",
                "served_loss_mean": loss,
            }
            if cs:
                row["cascade_stats"] = cs
                row["derived"] += (
                    f" esc={cs['escalations']}"
                    f" recalls={cs['recalls']}"
                    f" repin={cs['repin_tokens']}")
            row["attribution"] = decision_attribution(
                obs.tracer.events,
                gear_of=lambda sid, v=variant: f"static:{v}")
            if obs.regret is not None:
                reg = obs.regret.report()
                row["regret_mean"] = reg["regret_mean"]
                row["regret_p99"] = reg["regret_p99"]
                row["pareto_points"] = \
                    obs.regret.pareto.as_doc()["frontier_size"]
                row["derived"] += f" regret={reg['regret_mean']:.4f}"
            if keep_trace:
                row["_trace"] = obs.tracer
                if obs.regret is not None:
                    row["_regret"] = obs.regret
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# adaptive control plane vs frozen gears (serving.control, DESIGN.md §11)
# ---------------------------------------------------------------------------

# the gear bank: three lambda points of the same skip_recall family —
# quality probes deep, turbo rides the value function's cheap side.
# The planner prices each against the sim's OWN cost model (probed
# nodes per token) and indexes them by sustainable arrival rate.
ADAPT_PEAK = 12.5       # diurnal peak arrival rate (requests/sec)
ADAPT_PERIOD = 15.0     # diurnal period (two full cycles in 30s)
ADAPT_DURATION = 30.0
ADAPT_SEED = 7          # workload seed (arrival pattern)
ADAPT_MIX_SEED = 1      # serve-mix seed (trace rows)
ADAPT_UTIL = 0.9        # planner headroom: ride gears hot, buy loss
# controller: 1.5s telemetry window + 1.5s slope lead anticipates the
# diurnal ramp; hold=20 steps of hysteresis stops noise thrash;
# recalibration every 2.5s of serve time once 192 rows accumulated
ADAPT_SPAN, ADAPT_HOLD, ADAPT_LEAD = 1.5, 20, 1.5
ADAPT_RECAL_INTERVAL, ADAPT_RECAL_MIN_ROWS = 2.5, 192


def _adapt_specs():
    from repro.serving.control import GearSpec
    return (GearSpec("quality", 0.95), GearSpec("balanced", 0.92),
            GearSpec("turbo", 0.75))


def _overthink_rows(rng, t, n):
    """Serve-time drift rows: losses RISE with depth (overthinking) —
    the regime where calibration-stale tables keep probing nodes that
    no longer pay, and online refit collapses the probe depth."""
    start = rng.uniform(0.02, 0.12, (t, 1))
    drift = np.linspace(0.0, 0.3, n)[None, :] * rng.uniform(0.3, 1.0,
                                                            (t, 1))
    noise = rng.normal(0, 0.02, (t, n))
    for i in range(1, n):
        noise[:, i] = 0.7 * noise[:, i - 1] + 0.3 * noise[:, i]
    return np.clip(start + drift + noise, 1e-4, 1.0)


def _adaptive_serve_mix(seed, t):
    """The drifted SERVE distribution: 3/4 overthinking-up rows (easy
    tokens the stale tables over-probe), 1/4 uniformly-hard rows where
    deep probing still buys loss.  Calibration (seed 0, overthink 0.05)
    never saw this mix — the gap is what recalibration closes."""
    rng = np.random.default_rng(seed)
    hard, _, _ = traces.ee_like_traces(rng, t, N_NODES, overthink_prob=0.1,
                                       difficulty_spread=0.3)
    easy = _overthink_rows(rng, t, N_NODES)
    mask = rng.uniform(size=t) < 0.75
    return np.where(mask[:, None], easy, hard).astype(np.float64)


def _adaptive_setup(seed: int = 0):
    """A FRESH (planner, bank) per serve leg.  Fresh matters: the
    `Recalibrator` re-fits gears in place, so a bank that served an
    adaptive leg carries refit tables — reusing it would hand the
    frozen baselines the adaptive leg's learning."""
    from repro.serving.control import GearPlanner
    rng = np.random.default_rng(seed)
    calib, _, flops = traces.ee_like_traces(rng, 3_000, N_NODES,
                                            overthink_prob=0.05)
    planner = GearPlanner(calib, flops, k=16, seg_time=SEG_TIME,
                          overhead=OVERHEAD, n_lanes=LANES,
                          mean_tokens=18.0, utilization=ADAPT_UTIL)
    return planner, planner.plan(_adapt_specs())


def adaptive_vs_frozen(*, peak=ADAPT_PEAK, duration=ADAPT_DURATION,
                       period=ADAPT_PERIOD, seed=ADAPT_SEED,
                       mix_seed=ADAPT_MIX_SEED):
    """Adaptive controller vs every frozen gear on the SAME seeded
    diurnal workload and drifted serve mix (DESIGN.md §11).  The frozen
    gears' stale calibration over-probes the drifted traffic, so their
    real capacity sits far below the diurnal peak; the controller rides
    gear switches through the inflections and recalibration restores
    the capacity the drift stole.  The CI adaptive smoke pins strict
    goodput dominance at equal-or-better mean served loss."""
    from repro.serving.control import AdaptiveController
    serve_rows = _adaptive_serve_mix(mix_seed, 4_000)
    spec = WorkloadSpec(rate=peak, duration=duration, prompt_len=8,
                        max_tokens=(4, 32), seed=seed)
    requests = make_workload("diurnal", spec, period=period)

    def leg(slot=None):
        planner, bank = _adaptive_setup()
        ctl = None
        if slot is None:
            ctl = AdaptiveController(
                bank, span=ADAPT_SPAN, slo=SLO, hold=ADAPT_HOLD,
                lead=ADAPT_LEAD, recal_interval=ADAPT_RECAL_INTERVAL,
                recal_min_rows=ADAPT_RECAL_MIN_ROWS, planner=planner)
        stepper = rt.SimStepper(bank.strategies, serve_rows,
                                n_lanes=LANES, seg_time=SEG_TIME,
                                overhead=OVERHEAD)
        sid_of = ctl.sid_of if ctl else (lambda r: slot)
        obs = Observability()
        server = rt.Server(stepper, rt.LaneScheduler(LANES), sid_of,
                           slo=SLO, controller=ctl, obs=obs)
        metrics = server.serve(requests)
        # sids ARE gear-bank slots here, so attribution resolves each
        # token's gear by name — the per-decision cost/quality split
        # the BENCH trajectory carries from v5 on
        attribution = decision_attribution(
            obs.tracer.events, gear_of=lambda sid: bank[int(sid)].name)
        return metrics, stepper, ctl, bank, attribution

    rows = []
    metrics, stepper, ctl, bank, attribution = leg()
    s = metrics.summary(slo=SLO)
    stats = ctl.stats()
    completed = sum(1 for r in metrics.records.values()
                    if r.finished is not None)
    rows.append({
        "name": f"runtime_sim_adaptive_r{peak:g}",
        "us_per_call": s["duration"] / max(s["tokens"], 1) * 1e6,
        "derived": (f"goodput={s['goodput_tok_s']:.1f}tok_s "
                    f"loss={stepper.mean_served_loss:.4f} "
                    f"slo_att={100 * s['slo_attainment']:.0f}% "
                    f"gear={stats['gear']} "
                    f"switches={stats['gear_switches']} "
                    f"recals={stats['recalibrations']} "
                    f"cache={stepper.decide_cache_size()}"),
        "summary": s, "rate": peak, "strategy": "skip_recall",
        "kv": "sim", "adaptive": "adaptive", "gear": stats["gear"],
        "gear_switches": stats["gear_switches"],
        "recalibrations": stats["recalibrations"],
        "served_loss_mean": stepper.mean_served_loss,
        "decide_cache_size": stepper.decide_cache_size(),
        "completed": completed, "n_requests": len(requests),
        "controller": stats,
        "attribution": attribution,
    })
    for slot, gear in enumerate(bank):
        metrics, stepper, _, _, attribution = leg(slot=slot)
        s = metrics.summary(slo=SLO)
        completed = sum(1 for r in metrics.records.values()
                        if r.finished is not None)
        rows.append({
            "name": f"runtime_sim_frozen_{gear.name}_r{peak:g}",
            "us_per_call": s["duration"] / max(s["tokens"], 1) * 1e6,
            "derived": (f"goodput={s['goodput_tok_s']:.1f}tok_s "
                        f"loss={stepper.mean_served_loss:.4f} "
                        f"slo_att={100 * s['slo_attainment']:.0f}% "
                        f"gear={gear.name}"),
            "summary": s, "rate": peak, "strategy": "skip_recall",
            "kv": "sim", "adaptive": f"frozen_{gear.name}",
            "gear": gear.name, "gear_switches": 0, "recalibrations": 0,
            "served_loss_mean": stepper.mean_served_loss,
            "completed": completed, "n_requests": len(requests),
            "attribution": attribution,
        })
    return rows


def _shared_prefix_requests(vocab, *, n_requests, prompt_len, seed):
    """Deterministic mix: 3 of every 4 requests reuse one of two base
    prompts (what a shared system preamble looks like), the rest are
    disjoint — the prefix-cache hit rate the paged pool should convert
    into page headroom."""
    rng = np.random.default_rng(seed)
    bases = [rng.integers(0, vocab, prompt_len, dtype=np.int32)
             for _ in range(2)]
    out = []
    for rid in range(n_requests):
        if rid % 4 < 3:
            prompt = bases[rid % 2].copy()
        else:
            prompt = rng.integers(0, vocab, prompt_len, dtype=np.int32)
        out.append(Request(rid=rid, prompt=prompt,
                           max_tokens=2 + rid % 5,
                           arrival=rid * 0.02,
                           strategy="recall_index"))
    return out


def paged_vs_ring_real(*, n_requests=8, lanes=2, prompt_len=16,
                       page_size=8, cache_len=32, seed=0):
    """REAL smoke model, shared-prefix workload, EQUAL HBM budget: the
    paged pool (default n_pages == lanes x lane_pages, the ring
    footprint) vs per-lane ring caches.  Reports goodput/TTFT plus the
    pool's occupancy and sharing counters."""
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.models.param import materialize

    cfg = get_config("paper-ee-100m", smoke=True)
    key = jax.random.PRNGKey(seed)
    params = materialize(M.model_defs(cfg), key)
    casc = strategy.Cascade.calibrate(params, cfg, key, 0.5, k=12,
                                      t=128, seq=16)
    requests = _shared_prefix_requests(cfg.vocab, n_requests=n_requests,
                                       prompt_len=prompt_len, seed=seed)
    rows = []
    for kv, chunk in (("ring", None), ("paged", None),
                      ("paged", page_size)):
        bank, sid_of = rt.build_bank(requests, rt.cascade_factory(casc),
                                     ("recall_index", None))
        stepper = rt.EngineStepper(params, cfg, bank, n_lanes=lanes,
                                   cache_len=cache_len,
                                   prompt_len=prompt_len, kv=kv,
                                   page_size=page_size,
                                   prefill_chunk=chunk,
                                   prefill_budget=(None if chunk is None
                                                   else 2 * chunk))
        server = rt.Server(stepper, rt.LaneScheduler(lanes), sid_of,
                           slo=SLO)
        s = server.serve(requests).summary(slo=SLO)
        name = f"runtime_engine_kv_{kv}" + \
            ("_chunked" if chunk is not None else "")
        row = {
            "name": name,
            "us_per_call": 1e6 / max(s["throughput_tok_s"], 1e-9),
            "derived": (f"thru={s['throughput_tok_s']:.1f}tok_s "
                        f"goodput={s['goodput_tok_s']:.1f}tok_s "
                        f"tokens={s['tokens']}"),
            "summary": s, "strategy": "recall_index", "kv": kv,
            "prefill": "chunked" if chunk is not None else "stopworld",
        }
        if stepper.pool is not None:
            ps = stepper.pool.stats()
            row["kv_pool"] = ps
            row["derived"] += (
                f" pages_peak={ps['pages_peak']}/{ps['n_pages'] - 1}"
                f" prefix_hit={100 * ps['prefix_hit_rate']:.0f}%"
                f" cow={ps['cow_splits']}")
        if chunk is not None:
            cs = stepper.chunk_stats
            row["chunked_prefill"] = cs
            row["derived"] += (
                f" chunk_tokens={cs['tokens_computed']}"
                f" chunk_skipped={cs['tokens_skipped']}")
        rows.append(row)
    return rows


def stable_report(rows: list[dict]) -> dict:
    """The accumulating perf-trajectory schema (BENCH_runtime.json):
    one flat row per rate x strategy x kv-mode x prefill-mode x
    cascade-variant x adaptive-leg.  The v1/v2 keys are stable across
    commits (absent dimensions are null); v2 added the ``prefill`` axis
    + chunk token counters, v3 the ``cascade`` axis (``small_only`` |
    ``large_only`` | ``cascade_norecall`` | ``cascade_recall`` | null)
    with the served-loss quality axis and escalation/recall counters,
    v4 the ``adaptive`` axis (``adaptive`` | ``frozen_<gear>`` | null)
    plus the active gear id and gear-switch / recalibration counters
    from the control plane (DESIGN.md §11), v5 adds per-row
    decision-ATTRIBUTION cells (exit node x gear x escalated ->
    tokens / latency contribution / served-loss contribution) rolled
    up from the observability tracer (DESIGN.md §12; null on untraced
    legs), and v6 the decision-quality axis (DESIGN.md §15):
    ``regret_mean`` / ``regret_p99`` (per-request distance from the
    offline-optimal walk, exact mode) and ``pareto_points`` (streaming
    frontier size) on the metered cascade legs, null elsewhere.
    `check_regression` matches rows by name and ignores keys
    it does not know, so every axis addition is backward-compatible."""
    out = []
    for row in rows:
        s = row.get("summary") or {}
        pool = row.get("kv_pool") or {}
        chunk = row.get("chunked_prefill") or {}
        casc = row.get("cascade_stats") or {}
        ttft = s.get("ttft") or {}
        out.append({
            "name": row["name"],
            "rate": row.get("rate"),
            "strategy": row.get("strategy"),
            "kv": row.get("kv"),
            "goodput_tok_s": s.get("goodput_tok_s"),
            "throughput_tok_s": s.get("throughput_tok_s"),
            "ttft_p50": ttft.get("p50"),
            "ttft_p99": ttft.get("p99"),
            "pages_in_use": pool.get("pages_peak"),
            "prefix_hit_rate": pool.get("prefix_hit_rate"),
            "cow_splits": pool.get("cow_splits"),
            # v2 axis: chunked-prefill co-scheduling (DESIGN.md §9)
            "prefill": row.get("prefill"),
            "prefill_tokens_computed": chunk.get("tokens_computed"),
            "prefill_tokens_skipped": chunk.get("tokens_skipped"),
            # v3 axis: multi-model cascade serving (DESIGN.md §10)
            "cascade": row.get("cascade"),
            "served_loss_mean": row.get("served_loss_mean"),
            "escalations": casc.get("escalations"),
            "recalls": casc.get("recalls"),
            "repin_tokens": casc.get("repin_tokens"),
            # v4 axis: adaptive control plane (DESIGN.md §11)
            "adaptive": row.get("adaptive"),
            "gear": row.get("gear"),
            "gear_switches": row.get("gear_switches"),
            "recalibrations": row.get("recalibrations"),
            # v5 axis: decision attribution (DESIGN.md §12)
            "attribution": row.get("attribution"),
            # v6 axis: decision-quality regret + frontier (DESIGN.md §15)
            "regret_mean": row.get("regret_mean"),
            "regret_p99": row.get("regret_p99"),
            "pareto_points": row.get("pareto_points"),
        })
    return {"schema": "bench_runtime/v6", "rows": out}


def run(smoke: bool = False) -> list[dict]:
    if smoke:
        rows = sweep_rate_strategy(rates=(2.0, 6.0),
                                   names=("recall_index", "always_last"),
                                   duration=15.0)
        rows += recycling_vs_static_sim(n_requests=24)
        rows += chunked_vs_stopworld(rates=(2.0, 6.0), duration=15.0)
        rows += cascade_vs_monolith(rates=(2.0, 3.0), duration=30.0)
        rows += adaptive_vs_frozen()
        rows += paged_vs_ring_real(n_requests=6)
    else:
        rows = sweep_rate_strategy(
            rates=(2.0, 4.0, 6.0),
            names=("recall_index", "tree_index", "always_last"),
            duration=30.0)
        rows += recycling_vs_static_sim(n_requests=48)
        rows += chunked_vs_stopworld(rates=(2.0, 4.0, 6.0),
                                     duration=30.0)
        rows += cascade_vs_monolith(rates=(1.0, 2.0, 3.0, 4.0),
                                    duration=30.0)
        rows += adaptive_vs_frozen()
        rows += recycling_vs_engine_real()
        rows += paged_vs_ring_real(n_requests=16, lanes=4)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="sim + tiny real-model subset (CI)")
    ap.add_argument("--out", default=None,
                    help="write the full metrics JSON here")
    ap.add_argument("--json", action="store_true",
                    help="write the stable BENCH_runtime.json at the "
                         "repo root (perf trajectory; CI artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},"
              f"{str(row['derived']).replace(',', ';')}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1, default=float)
        print(f"wrote {args.out}")
    if args.json:
        path = REPO_ROOT / "BENCH_runtime.json"
        with open(path, "w") as f:
            json.dump(stable_report(rows), f, indent=1, default=float)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
