"""Serving-engine table: end-to-end early-exit generation on a smoke
model — T-Tamer recall policy vs threshold baseline vs no-exit, measuring
segment savings (batch + per-lane policy accounting) and tokens/s on this
host.  (The serving analogue of the paper's latency reductions, §6.)"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.line_dp import solve_line
from repro.core.markov import estimate_chain
from repro.core.support import build_support, quantize
from repro.models import model as M
from repro.models.param import materialize
from repro.serving.engine import Engine, RecallIndexPolicy, ThresholdPolicy


def _calibrate(params, cfg, key, lam, k=16, t=256):
    """Run the model on calibration prompts, fit support+chain+tables."""
    toks = jax.random.randint(key, (t, 32), 0, cfg.vocab)
    _, _, node_losses, _ = M.prefill(params, cfg, {"tokens": toks}, 48)
    scaled = lam * np.asarray(node_losses)
    sup = build_support(scaled, k)
    bins = quantize(sup, jnp.asarray(scaled))
    chain = estimate_chain(bins, k)
    n = node_losses.shape[1]
    costs = jnp.full((n,), (1.0 - lam) / n, jnp.float32)
    return solve_line(chain, costs, sup), sup


def run() -> list[dict]:
    cfg = get_config("paper-ee-100m", smoke=True)
    key = jax.random.PRNGKey(0)
    params = materialize(M.model_defs(cfg), key)
    lam = 0.5
    tables, sup = _calibrate(params, cfg, key, lam)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    n_tokens = 16
    rows = []
    base_tps = None
    for name, policy in [
        ("recall_index", RecallIndexPolicy(tables, sup, lam)),
        ("norecall_thr", ThresholdPolicy(tables.n, threshold=0.45)),
        ("no_exit", ThresholdPolicy(tables.n, threshold=-1.0)),
    ]:
        eng = Engine(params, cfg, policy, cache_len=64)
        eng.generate(batch, 2)  # warm the jits
        t0 = time.perf_counter()
        stats = eng.generate(batch, n_tokens)
        dt = time.perf_counter() - t0
        tps = 8 * n_tokens / dt
        if base_tps is None and name == "no_exit":
            base_tps = tps
        save_batch = 1 - stats.segments_run_batch / (
            n_tokens * len(cfg.segments))
        save_policy = 1 - stats.segments_run_policy / stats.segments_full
        rows.append({
            "name": f"engine_{name}",
            "us_per_call": dt / (8 * n_tokens) * 1e6,
            "derived": (f"tok_s={tps:.1f} "
                        f"seg_saved_batch={save_batch * 100:.0f}% "
                        f"seg_saved_lane={save_policy * 100:.0f}% "
                        f"mean_node={stats.served_nodes.mean():.2f}"),
        })
    return rows
