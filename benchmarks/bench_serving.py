"""Serving-engine table: end-to-end early-exit generation on a smoke
model — every online strategy family from the `repro.strategy` registry
(T-Tamer recall index, the exact tree/sigma index, the skip-table
cascade, a confidence threshold, and the no-exit endpoint), measuring
segment savings (batch + per-lane policy accounting) and tokens/s on
this host.  (The serving analogue of the paper's latency reductions, §6.)"""

from __future__ import annotations

import time

import jax

from repro import strategy
from repro.configs import get_config
from repro.models import model as M
from repro.models.param import materialize
from repro.serving.engine import Engine


def run() -> list[dict]:
    cfg = get_config("paper-ee-100m", smoke=True)
    key = jax.random.PRNGKey(0)
    params = materialize(M.model_defs(cfg), key)
    lam = 0.5
    casc = strategy.Cascade.calibrate(params, cfg, key, lam,
                                      k=16, t=256, seq=32)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
    n_tokens = 16
    rows = []
    for name, strat in [
        ("recall_index", strategy.make("recall_index", casc)),
        ("tree_index", strategy.make("tree_index", casc)),
        ("skip_recall", strategy.make("skip_recall", casc,
                                      mode="cumulative")),
        ("norecall_thr", strategy.make("norecall_threshold", casc,
                                       threshold=0.45, lam=1.0)),
        ("no_exit", strategy.make("always_last", casc)),
    ]:
        eng = Engine(params, cfg, strat, cache_len=64)
        eng.generate(batch, 2)  # warm the jits
        t0 = time.perf_counter()
        stats = eng.generate(batch, n_tokens)
        dt = time.perf_counter() - t0
        tps = 8 * n_tokens / dt
        save_batch = 1 - stats.segments_run_batch / (
            n_tokens * len(cfg.segments))
        save_policy = 1 - stats.segments_run_policy / stats.segments_full
        rows.append({
            "name": f"engine_{name}",
            "us_per_call": dt / (8 * n_tokens) * 1e6,
            "derived": (f"tok_s={tps:.1f} "
                        f"seg_saved_batch={save_batch * 100:.0f}% "
                        f"seg_saved_lane={save_policy * 100:.0f}% "
                        f"mean_node={stats.served_nodes.mean():.2f}"),
        })
    return rows
