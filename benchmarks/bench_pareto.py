"""Figs. 4-5 analogue: accuracy-latency Pareto frontiers on early-exit
workloads (recall-index vs confidence thresholds vs oracle), swept over
lambda.  Each point runs a registry strategy through the batched
``strategy.evaluate`` scan (DESIGN.md §4).  Traces come from the
synthetic EE workload generator (offline container; DESIGN.md §6) — the
same pipeline accepts traces exported from a trained checkpoint via
examples/train_ee.py.

Emits benchmarks/results/pareto_points.csv and reports the headline
trade-off (latency at <=2% / <=7% error sacrifice, cf. paper Fig. 4a
"latency to 45% at <7% accuracy loss")."""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import pareto, traces

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def run() -> list[dict]:
    os.makedirs(RESULTS, exist_ok=True)
    rng = np.random.default_rng(4)
    losses, correct, flops = traces.ee_like_traces(rng, 24_000, 8,
                                                   overthink_prob=0.2)
    lambdas = np.concatenate([np.linspace(0.05, 0.95, 10),
                              [0.98, 0.995, 0.999]])
    t0 = time.perf_counter()
    pts = pareto.sweep(losses, correct, flops, lambdas, k=32)
    us = (time.perf_counter() - t0) * 1e6

    with open(os.path.join(RESULTS, "pareto_points.csv"), "w") as f:
        f.write("policy,lambda,error,latency,objective,mean_probed\n")
        for p in pts:
            f.write(f"{p.policy},{p.lam},{p.error},{p.latency},"
                    f"{p.objective},{p.mean_probed}\n")

    rows = []
    full_err = min(p.error for p in pts if p.policy == "always_last")
    for fam, prefix in [("recall_index", "recall_index"),
                        ("norecall_thr", "norecall_thr"),
                        ("oracle", "oracle")]:
        front = pareto.pareto_filter(pts, prefix)
        # latency needed to stay within +2% / +7% error of the backbone
        def lat_at(slack):
            ok = [p.latency for p in front if p.error <= full_err + slack]
            return min(ok) if ok else 1.0
        rows.append({
            "name": f"pareto_{fam}",
            "us_per_call": us / 3,
            "derived": (f"lat@+2%err={lat_at(0.02):.2f} "
                        f"lat@+7%err={lat_at(0.07):.2f} "
                        f"points={len(front)}"),
        })
    return rows
