"""Thm 4.5 / 5.2 complexity table: DP preprocessing wall-time scaling in
n (nodes), |V| (support) and the skip variant's extra factor n, plus the
Pallas bellman_backup kernel (interpret mode) for the fused path."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.line_dp import solve_line
from repro.core.markov import MarkovChain
from repro.core.skip_dp import edge_costs_skip_free, solve_skip
from repro.core.support import Support
from repro.core.traces import random_instance


def _mk(rng, n, k):
    p0, trans, costs, grid = random_instance(rng, n, k)
    g = jnp.asarray(grid, jnp.float32)
    sup = Support(grid=g, edges=(g[1:] + g[:-1]) / 2)
    chain = MarkovChain(p0=jnp.asarray(p0, jnp.float32),
                        trans=jnp.asarray(trans, jnp.float32))
    return chain, jnp.asarray(costs, jnp.float32), sup


def _time(f, reps=3):
    f()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f())
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[dict]:
    rng = np.random.default_rng(1)
    rows = []
    base = None
    for n, k in [(8, 32), (16, 32), (32, 32), (16, 64), (16, 128)]:
        chain, costs, sup = _mk(rng, n, k)
        us = _time(lambda: solve_line(chain, costs, sup).value)
        if base is None:
            base = us
        rows.append({"name": f"line_dp_n={n}_K={k}", "us_per_call": us,
                     "derived": f"vs_base={us / base:.2f}x"})
    for n, k in [(8, 16), (16, 16), (32, 16)]:
        chain, costs, sup = _mk(rng, n, k)
        ec = edge_costs_skip_free(np.asarray(costs))
        us = _time(lambda: solve_skip(chain, ec, sup).value, reps=1)
        rows.append({"name": f"skip_dp_n={n}_K={k}", "us_per_call": us,
                     "derived": "O(n^2 K^2) preprocessing (Thm 5.2)"})
    # fused kernel path
    chain, costs, sup = _mk(rng, 16, 126)
    us_j = _time(lambda: solve_line(chain, costs, sup).value)
    us_k = _time(lambda: solve_line(chain, costs, sup,
                                    use_kernel=True).value, reps=1)
    rows.append({"name": "line_dp_K=126_jnp", "us_per_call": us_j,
                 "derived": "gather+matmul unfused"})
    rows.append({"name": "line_dp_K=126_pallas_interp", "us_per_call": us_k,
                 "derived": "bellman_backup kernel (interpret; TPU target "
                            "fuses min-gather into MXU matmul)"})
    return rows
