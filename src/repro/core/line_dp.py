"""Dynamic-index DP for the directed line (paper §4, Alg. 2, Thm 4.5).

State ``(X, R_{i-1}, i)``: running-min loss X, previous node's binned loss s,
next candidate node i.  Bellman recursion (§4.2):

    Phi(X, s, i) = min{ X,  c_i + E_{R_i | R_{i-1}=s}[ Phi(min(X, R_i), R_i, i+1) ] }

with base case ``Phi(X, *, n) = X`` (after the last node one must stop and,
with recall, serve the argmin ramp).

Discretization.  Losses live on the common support ``grid`` (K bins).  The
running-min X additionally takes two sentinel values: ``0`` (an anchor used
only for exact off-grid index interpolation — unreachable at runtime) and
``+inf`` (Alg. 1 initializes X <- inf).  The X axis therefore has K+2
entries: ``xvals = [0, v_1..v_K, INF]``; a loss bin b maps to X-index b+1.

The backward pass is a sequence of (K x K) @ (K x (K+2)) matmuls over a
min-gathered table — the exact shape the ``bellman_backup`` Pallas kernel
fuses on TPU (gather never materialized in HBM).

Exact dynamic index.  Between adjacent X-grid points the continuation value
``cont(x)`` is *linear* in x (the recursion only branches at support
values), so the indifference point sigma of Def. 4.4 is recovered exactly
by linear interpolation at the stop/continue flip — this off-grid sigma is
what the multi-line / tree index policies compare across branches.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.markov import MarkovChain
from repro.core.support import Support

__all__ = ["LineTables", "solve_line", "x_index_of_bin", "INF_SENTINEL_MULT"]

INF_SENTINEL_MULT = 1e4  # sentinel = grid[-1]*MULT + MULT (finite "+inf")


def x_values(grid: jax.Array) -> jax.Array:
    """(K+2,) X axis: [0, v_1..v_K, INF-sentinel]."""
    big = grid[-1] * INF_SENTINEL_MULT + INF_SENTINEL_MULT
    zero = jnp.zeros((1,), grid.dtype)
    return jnp.concatenate([zero, grid, jnp.array([big], grid.dtype)])


def x_index_of_bin(bins: jax.Array) -> jax.Array:
    """Map a loss bin (0..K-1) to its X-axis index (1..K)."""
    return bins + 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LineTables:
    cont: jax.Array    # (n, K, K+2) float — continuation values [i, s, x]
    stop: jax.Array    # (n, K, K+2) bool  — True => stop before probing i
    phi: jax.Array     # (n+1, K, K+2) float — equivalent-loss tables
    sigma: jax.Array   # (n, K) float — exact dynamic index sigma(s, i)
    value: jax.Array   # () float — online-optimal expected total loss

    @property
    def n(self) -> int:
        return int(self.cont.shape[0])

    @property
    def k(self) -> int:
        return int(self.cont.shape[1])

    @property
    def inf_x(self) -> int:
        return self.k + 1


def _min_index_matrix(grid: jax.Array) -> jax.Array:
    """mi[x, y] = X-axis index of min(xvals[x], grid[y])."""
    k = grid.shape[0]
    xv = x_values(grid)
    grid_as_x = jnp.arange(1, k + 1)
    le = xv[:, None] <= grid[None, :]               # (K+2, K)
    return jnp.where(le, jnp.arange(k + 2)[:, None], grid_as_x[None, :])


def _backup(phi_next, trans_row, cost, xvals, mi, *, use_kernel=False):
    """cont[s, x] = c + sum_y trans[s, y] * phi_next[y, mi[x, y]]."""
    if use_kernel:
        from repro.kernels import ops as kops
        cont = kops.bellman_backup(phi_next, trans_row, cost, mi.T)
    else:
        m = jnp.take_along_axis(phi_next, mi.T, axis=1)  # (K, K+2): [y, x]
        cont = cost + trans_row @ m                      # (K, K+2): [s, x]
    phi = jnp.minimum(xvals[None, :], cont)
    return cont, phi


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _solve(p0, trans, costs, grid, *, use_kernel: bool = False):
    k = p0.shape[0]
    xvals = x_values(grid)
    mi = _min_index_matrix(grid)
    # Node 0 has no predecessor; its "transition row" is p0 for every s.
    trans_full = jnp.concatenate(
        [jnp.tile(p0[None, :], (k, 1))[None], trans], axis=0)  # (n, K, K)
    base = jnp.tile(xvals[None, :], (k, 1))                    # (K, K+2)

    def step(phi_next, inp):
        tr, c = inp
        cont, phi = _backup(phi_next, tr, c, xvals, mi, use_kernel=use_kernel)
        return phi, (cont, phi)

    _, (cont, phi_hist) = jax.lax.scan(
        step, base, (trans_full[::-1], costs[::-1]))
    cont = cont[::-1]
    phi = jnp.concatenate([phi_hist[::-1], base[None]], axis=0)

    # Ties break toward stopping ("smallest solution", Def. 4.4).
    stop = xvals[None, None, :] <= cont

    # ---- exact sigma via linear interpolation at the flip point ----------
    # H(x) = cont(x) - x is non-increasing (Lem. B.1); stop region is the
    # low-x prefix.  Find last stop index q along the X axis, interpolate
    # between (xvals[q], cont[q]) and (xvals[q+1], cont[q+1]) for cont(x)=x.
    nx = k + 2
    stop_f = stop.astype(jnp.float32)
    q = jnp.sum(stop_f, axis=-1).astype(jnp.int32) - 1   # last stop idx
    q = jnp.clip(q, 0, nx - 2)
    x0 = xvals[q]
    x1 = xvals[q + 1]
    c0 = jnp.take_along_axis(cont, q[..., None], axis=-1)[..., 0]
    c1 = jnp.take_along_axis(cont, (q + 1)[..., None], axis=-1)[..., 0]
    denom = (x1 - x0) - (c1 - c0)
    sigma = jnp.where(jnp.abs(denom) > 1e-12,
                      x0 + (c0 - x0) * (x1 - x0) / jnp.maximum(denom, 1e-12),
                      x0)
    # If the policy never stops on-grid for this (i, s) (q clipped at 0 but
    # stop[...,0] False) sigma interpolates on [0, v_1] which is still exact.
    sigma = jnp.clip(sigma, 0.0, xvals[-1])
    value = cont[0, 0, nx - 1]  # start: X = inf sentinel, s irrelevant
    return cont, stop, phi, sigma, value


def solve_line(chain: MarkovChain, costs: jax.Array, support: Support,
               *, use_kernel: bool = False) -> LineTables:
    """Solve the with-recall line problem (Prob. 4.1) exactly.

    Args:
      chain: fitted Markov chain over the binned losses (n nodes).
      costs: (n,) strictly-positive inspection costs c_i (edge costs folded
        into the destination node, App. C notations / Fig. 6a).
      support: the common discrete support V.
      use_kernel: route the Bellman backup through the Pallas kernel.
    """
    costs = jnp.asarray(costs, jnp.float32)
    if costs.shape != (chain.n,):
        raise ValueError(f"costs shape {costs.shape} != ({chain.n},)")
    cont, stop, phi, sigma, value = _solve(
        chain.p0, chain.trans, costs, support.grid, use_kernel=use_kernel)
    return LineTables(cont=cont, stop=stop, phi=phi, sigma=sigma, value=value)


def suffix_tables(chain: MarkovChain, costs: np.ndarray, support: Support,
                  start: int) -> LineTables:
    """Tables for the line suffix [start..n) — used by multi-line/tree
    indices, where a branch's index is computed on its remaining nodes."""
    if start == 0:
        return solve_line(chain, costs, support)
    sub = MarkovChain(p0=chain.p0 @ _chain_prod(chain, 0, start),
                      trans=chain.trans[start:])
    return solve_line(sub, jnp.asarray(costs)[start:], support)


def _chain_prod(chain: MarkovChain, i: int, j: int) -> jax.Array:
    acc = jnp.eye(chain.k, dtype=chain.p0.dtype)
    for t in range(i, j):
        acc = acc @ chain.trans[t]
    return acc
