"""Synthetic workload / instance generators for the costly-exploration core.

Two families:
  * ``random_instance`` — arbitrary random Markov chains + costs, used by
    the hypothesis property tests (DP optimality vs brute force).
  * ``ee_like_traces`` — early-exit-shaped loss traces: losses broadly
    decrease with depth, are positively correlated along the ramp sequence
    (App. D.3 notes real ramp losses are positively correlated), and
    occasionally *increase* at deeper ramps ("overthinking", Kaya et al.
    2019, §4) — exactly the phenomenon that makes recall valuable.
"""

from __future__ import annotations

import numpy as np

__all__ = ["random_instance", "ee_like_traces", "cascade_traces"]


def random_instance(rng: np.random.Generator, n: int, k: int,
                    cost_scale: float = 0.2, concentration: float = 1.0):
    """Random discrete Markov instance on support grid ~ sorted U(0,1].

    Returns (p0, trans, costs, grid) as float64 numpy arrays.
    """
    grid = np.sort(rng.uniform(0.05, 1.0, size=k))
    # enforce strict ascent
    grid += np.arange(k) * 1e-6
    p0 = rng.dirichlet(np.full(k, concentration))
    trans = rng.dirichlet(np.full(k, concentration), size=(n - 1, k)) \
        if n > 1 else np.zeros((0, k, k))
    costs = rng.uniform(0.01, cost_scale, size=n)
    return p0, trans, costs, grid


def ee_like_traces(rng: np.random.Generator, t: int, n: int,
                   overthink_prob: float = 0.15,
                   difficulty_spread: float = 1.0):
    """Generate (losses, correct, flops) for an n-ramp early-exit workload.

    Each sample has a latent difficulty d ~ LogNormal; ramp i's loss is a
    noisy decreasing function of depth scaled by d, with occasional
    "overthinking" bumps at later ramps.  ``correct[t, i]`` indicates
    whether ramp i's prediction would match the backbone (prob. decreasing
    in loss), and ``flops`` grows superlinearly with depth, mimicking
    transformer ramp placement.

    Returns:
      losses: (t, n) in (0, 1] — the proxy loss (1 - confidence).
      correct: (t, n) bool.
      flops: (n,) normalized cumulative-segment costs summing to 1.
    """
    d = rng.lognormal(mean=0.0, sigma=difficulty_spread, size=(t, 1))
    # deeper ramps converge toward the backbone (superlinear depth gain),
    # so the final ramp's disagreement-with-backbone proxy is small
    depth = (np.linspace(1.0, float(n), n) ** 1.6)[None, :]
    base = d / (d + depth)                       # decreasing in depth
    noise = rng.normal(0.0, 0.05, size=(t, n))
    # AR(1) correlation along ramps (Markov-ish)
    for i in range(1, n):
        noise[:, i] = 0.7 * noise[:, i - 1] + 0.3 * noise[:, i]
    bump = (rng.uniform(size=(t, n)) < overthink_prob) * \
        rng.uniform(0.05, 0.4, size=(t, n))
    bump[:, 0] = 0.0
    losses = np.clip(base + noise + bump, 1e-4, 1.0)
    # calibrated confidences: ramp agrees with the backbone w.p. 1 - loss
    # (real EE ramps are trained toward exactly this; App. D.2 uses
    # 1 - confidence as the loss proxy)
    correct = rng.uniform(size=(t, n)) > losses
    correct[:, -1] = True                        # backbone agrees with itself
    seg = np.linspace(1.0, 2.0, n)               # deeper segments cost more
    flops = np.cumsum(seg)
    flops = flops / flops[-1]
    # per-node incremental cost (segment i alone)
    inc = np.diff(np.concatenate([[0.0], flops]))
    return losses.astype(np.float64), correct, inc.astype(np.float64)


def cascade_traces(rng: np.random.Generator, t: int, depths,
                   overthink_prob: float = 0.15,
                   head_overthink: float = 0.0,
                   difficulty_spread: float = 1.0):
    """Multi-MODEL cascade loss traces: one (t, sum(n_m)) bank whose
    column groups are the node ladders of several models evaluated on
    the SAME inputs.

    ``depths`` is a list of per-model effective-depth vectors (one entry
    per node, ladder order).  Unlike `ee_like_traces` — where the first
    nodes are the shallow prefix of ONE network — each model here is a
    complete network: a small model's ramps sit close to its own head
    (flat depth profile), while a larger model's nodes are much deeper.
    All models share each sample's latent difficulty, and noise is
    AR(1)-correlated across the whole ladder (a hard token is hard for
    everyone; App. D.3's positive correlation).

    ``head_overthink`` adds extra overthinking probability on each
    model's LAST node — the §6 regime where a bigger model's head is
    sometimes beaten by an earlier node, which only recall can exploit.

    Returns (losses (t, n_total), boundaries tuple).
    """
    depths = [np.asarray(d, np.float64) for d in depths]
    boundaries = tuple(len(d) for d in depths)
    depth = np.concatenate(depths)[None, :]
    n = depth.shape[1]
    d = rng.lognormal(mean=0.0, sigma=difficulty_spread, size=(t, 1))
    base = d / (d + depth)
    noise = rng.normal(0.0, 0.05, size=(t, n))
    for i in range(1, n):
        noise[:, i] = 0.7 * noise[:, i - 1] + 0.3 * noise[:, i]
    bump = (rng.uniform(size=(t, n)) < overthink_prob) * \
        rng.uniform(0.05, 0.4, size=(t, n))
    bump[:, 0] = 0.0
    if head_overthink > 0.0:
        heads = np.cumsum(boundaries) - 1
        extra = (rng.uniform(size=(t, len(heads))) < head_overthink) * \
            rng.uniform(0.05, 0.45, size=(t, len(heads)))
        bump[:, heads] += extra
    losses = np.clip(base + noise + bump, 1e-4, 1.0)
    return losses.astype(np.float64), boundaries
