"""Dynamic indexing over the transitive closure of a directed line (§5.2).

After probing node i the policy may jump to ANY later node j > i (skipping
intermediates), paying edge cost ``C[i+1, j+1]``; the conditional loss
distribution across the skip is the Chapman-Kolmogorov product
``P^{(i->j)} = prod_t trans[t]``.  Bellman recursion (App. C.3):

    Phi(X, s, i) = min{ X, min_{j > i} [ C(i,j) + E_{R_j|R_i=s} Phi(min(X,R_j), R_j, j) ] }

Enumerating successors costs an extra factor n over the single line
(Thm 5.2: O(n^2 |V|^2 T) preprocessing), inference stays O(1)/node via the
precomputed NEXT table (stop / which node to probe).

X-axis conventions follow ``line_dp`` (K+2 entries: 0, grid, +inf).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import line_dp
from repro.core.markov import MarkovChain
from repro.core.support import Support

__all__ = ["SkipTables", "solve_skip", "edge_costs_skip_free",
           "edge_costs_cumulative", "edge_costs_cascade"]

STOP = -1  # NEXT-table entry meaning "stop and serve the argmin"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SkipTables:
    value_tab: jax.Array  # (n+1, K, K+2) — V[l+1][s, x]; row 0 = dummy root
    nxt: jax.Array        # (n+1, K, K+2) int32 — STOP or next node to probe
    value: jax.Array      # () — online-optimal expected loss from the root

    @property
    def n(self) -> int:
        return int(self.value_tab.shape[0]) - 1

    @property
    def k(self) -> int:
        return int(self.value_tab.shape[1])


def edge_costs_skip_free(costs: np.ndarray) -> np.ndarray:
    """C[i, j] = c_{j-1}: skipping avoids intermediate costs entirely
    (inter-model cascades: skipped models are simply never run)."""
    n = len(costs)
    c = np.zeros((n + 1, n + 1), np.float32)
    for j in range(1, n + 1):
        c[:j, j] = costs[j - 1]
    return c


def edge_costs_cumulative(costs: np.ndarray) -> np.ndarray:
    """C[i, j] = sum_{t in (i..j]} c_t: skipping still pays the backbone
    compute of intermediate segments, only their ramp heads are saved
    (intra-model early exit: you cannot skip backbone layers)."""
    n = len(costs)
    pref = np.concatenate([[0.0], np.cumsum(costs)])
    c = np.zeros((n + 1, n + 1), np.float32)
    for i in range(n + 1):
        for j in range(i + 1, n + 1):
            c[i, j] = pref[j] - pref[i]
    return c.astype(np.float32)


def edge_costs_cascade(costs: np.ndarray, boundaries,
                       entry_costs=None) -> np.ndarray:
    """Multi-MODEL cascade edge costs: the node line is partitioned into
    consecutive per-model groups (``boundaries`` = nodes per model, in
    ladder order) and the cost of an edge depends on whether it stays
    inside one model or crosses into a later one.

      * WITHIN model m (i, j in m): cumulative — skipping intermediate
        ramps still pays their backbone segments (exactly
        `edge_costs_cumulative` restricted to the model).
      * INTO model m' from an earlier model (or the root): the target
        model runs from ITS OWN first segment through node j — the
        source model's remaining segments are never executed
        (``skip_free`` across the boundary), and none of m''s segments
        can be skipped because the escalation prefill/backbone must
        traverse them all.  ``entry_costs[m']`` (optional, per model)
        adds a fixed escalation charge — the amortized catch-up prefill
        of moving a stream onto m'.

    Edges BACK to earlier models do not exist in the DP (the line is
    directed); recall — *serving* an earlier model's already-probed node
    — is free by construction (argmin bookkeeping), which is the runtime
    claim the cascade subsystem makes physical: retained pages make the
    recall a page-table re-pin, not a recompute.

    With a single model this reduces exactly to `edge_costs_cumulative`.
    """
    costs = np.asarray(costs, np.float64)
    n = len(costs)
    boundaries = tuple(int(b) for b in boundaries)
    if any(b < 1 for b in boundaries) or sum(boundaries) != n:
        raise ValueError(f"boundaries {boundaries} must be positive and "
                         f"sum to n_nodes={n}")
    if entry_costs is None:
        entry_costs = np.zeros(len(boundaries), np.float64)
    entry_costs = np.asarray(entry_costs, np.float64)
    if entry_costs.shape != (len(boundaries),):
        raise ValueError(f"entry_costs shape {entry_costs.shape} != "
                         f"({len(boundaries)},)")
    model_of = np.repeat(np.arange(len(boundaries)), boundaries)
    # cum[j] = model-local cumulative cost from model(j)'s first segment
    # through node j's segment (inclusive)
    cum = np.zeros(n, np.float64)
    start = 0
    for b in boundaries:
        cum[start:start + b] = np.cumsum(costs[start:start + b])
        start += b
    c = np.zeros((n + 1, n + 1), np.float64)
    for j in range(n):
        for i in range(-1, j):
            if i >= 0 and model_of[i] == model_of[j]:
                c[i + 1, j + 1] = cum[j] - cum[i]
            else:
                c[i + 1, j + 1] = cum[j] + entry_costs[model_of[j]]
    return c.astype(np.float32)


def solve_skip(chain: MarkovChain, edge_costs: np.ndarray,
               support: Support) -> SkipTables:
    """Exact DP for the skip (transitive-closure) setting.

    Args:
      chain: Markov chain over binned losses, n nodes.
      edge_costs: (n+1, n+1) matrix; [i+1, j+1] = cost of probing j right
        after i, row/col 0 = dummy root.  Use the constructors above.
      support: common discrete support V.
    """
    n, k = chain.n, chain.k
    grid = support.grid
    xvals = line_dp.x_values(grid)
    mi = line_dp._min_index_matrix(grid)          # (K+2, K)
    ec = jnp.asarray(edge_costs, jnp.float32)

    # cumulative conditionals cum[i][j] = P^{(i->j)}, python-managed.
    cum: list[list[jax.Array | None]] = [[None] * n for _ in range(n)]
    for i in range(n):
        acc = jnp.eye(k, dtype=jnp.float32)
        cum[i][i] = acc
        for j in range(i + 1, n):
            acc = acc @ chain.trans[j - 1]
            cum[i][j] = acc

    stop_val = jnp.tile(xvals[None, :], (k, 1))   # (K, K+2)
    v: list[jax.Array] = [None] * (n + 1)         # v[l+1] indexed by last=l
    nxt: list[jax.Array] = [None] * (n + 1)

    for last in range(n - 1, -2, -1):
        best = stop_val
        best_j = jnp.full((k, k + 2), STOP, jnp.int32)
        for j in range(last + 1, n):
            if last < 0:
                row_mat = jnp.tile((chain.p0 @ cum[0][j])[None, :], (k, 1))
            else:
                row_mat = cum[last][j]            # (K, K) Pr[R_j=y | R_last=s]
            m = jnp.take_along_axis(v[j + 1], mi.T, axis=1)  # (K, K+2)
            cont = ec[last + 1, j + 1] + row_mat @ m
            take = cont < best
            best_j = jnp.where(take, j, best_j)
            best = jnp.minimum(best, cont)
        if last < 0:
            root_v, root_nxt = best, best_j
        else:
            v[last + 1] = best
            nxt[last + 1] = best_j
    v[0], nxt[0] = root_v, root_nxt

    value_tab = jnp.stack(v)
    nxt_tab = jnp.stack(nxt)
    value = value_tab[0, 0, k + 1]
    return SkipTables(value_tab=value_tab, nxt=nxt_tab, value=value)


def simulate_skip(tables: SkipTables, losses: np.ndarray, bins: np.ndarray,
                  edge_costs: np.ndarray):
    """Run the skip policy on traces; returns (served_loss, explore_cost,
    probed_mask) per sample.  Numpy reference implementation."""
    t, n = bins.shape
    k = tables.k
    nxt = np.asarray(jax.device_get(tables.nxt))
    served = np.zeros(t, np.float32)
    spent = np.zeros(t, np.float32)
    probed = np.zeros((t, n), bool)
    for r in range(t):
        last, s, x_idx = -1, 0, k + 1
        best = np.inf
        while True:
            j = int(nxt[last + 1, s, x_idx])
            if j == STOP:
                break
            spent[r] += edge_costs[last + 1, j + 1]
            probed[r, j] = True
            best = min(best, float(losses[r, j]))
            s = int(bins[r, j])
            x_idx = min(x_idx, s + 1)
            last = j
            if last == n - 1:
                break
        served[r] = best
    return served, spent, probed
