"""T-Tamer core: Markovian costly exploration over DAGs (the paper's
contribution).  See DESIGN.md §1-2 for the mapping."""

from repro.core.support import Support, build_support, quantize
from repro.core.markov import MarkovChain, estimate_chain, estimate_from_losses
from repro.core.line_dp import LineTables, solve_line
from repro.core.skip_dp import SkipTables, solve_skip
from repro.core import tree_dp, pareto, traces, impossibility

__all__ = [
    "Support", "build_support", "quantize",
    "MarkovChain", "estimate_chain", "estimate_from_losses",
    "LineTables", "solve_line", "SkipTables", "solve_skip",
    "tree_dp", "pareto", "traces", "impossibility",
]
