"""Discrete loss support & quantizer.

The paper's DP (§4.2) assumes every ramp loss R_i takes values on a common
finite support ``V = {v_1 < ... < v_K}``.  Real losses are continuous, so we
expose the quantile quantizer that produces V from calibration traces
("Such discretization is standard in practice", §4.1).

Index conventions used throughout ``repro.core``:

* bins ``0..K-1`` map to grid values ``grid[0..K-1]`` (ascending, > 0 per
  Assumption 2.1 — losses are strictly positive).
* a *sentinel* bin ``K`` denotes ``X = +inf`` (the running-min before any
  node was inspected; Alg. 1 initializes ``X <- inf``).  DP tables carry
  ``K+1`` rows along the X axis for this reason.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Support", "build_support", "quantize"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Support:
    """A common finite loss support V.

    Attributes:
      grid: (K,) ascending strictly-positive grid values v_1..v_K.
      edges: (K-1,) bucket edges; value x maps to bin ``searchsorted(edges, x)``.
    """

    grid: jax.Array
    edges: jax.Array

    @property
    def size(self) -> int:
        return int(self.grid.shape[0])

    @property
    def inf_bin(self) -> int:
        """Sentinel bin index representing X = +inf."""
        return self.size

    def values_with_inf(self) -> jax.Array:
        """(K+1,) grid extended with a large-but-finite sentinel for X=inf.

        The sentinel only ever appears as a *stopping value before any node
        was probed*, which the optimal policy never chooses (it must serve
        some model), so any value strictly above ``grid[-1] + sum(costs)``
        is equivalent to +inf.  We use a large multiple of the top grid
        value to stay finite in float32 arithmetic.
        """
        big = self.grid[-1] * 1e4 + 1e4
        return jnp.concatenate([self.grid, jnp.array([big], self.grid.dtype)])


def build_support(samples: np.ndarray | jax.Array, k: int) -> Support:
    """Quantile-based support over pooled calibration losses.

    Args:
      samples: any-shape array of observed losses (pooled over ramps/inputs).
      k: support size |V|.
    """
    flat = np.asarray(jax.device_get(samples), dtype=np.float64).reshape(-1)
    flat = flat[np.isfinite(flat)]
    if flat.size == 0:
        raise ValueError("no finite calibration samples")
    lo = float(np.min(flat))
    # Assumption 2.1: strictly positive losses.  Shift if violated.
    shift = 0.0 if lo > 0 else (1e-6 - lo)
    flat = flat + shift
    qs = np.linspace(0.0, 1.0, k)
    grid = np.quantile(flat, qs)
    # De-duplicate (heavy ties collapse quantiles); enforce strict ascent.
    grid = np.maximum.accumulate(grid)
    eps = max(1e-9, 1e-9 * float(grid[-1]))
    for i in range(1, grid.size):
        if grid[i] <= grid[i - 1]:
            grid[i] = grid[i - 1] + eps
    edges = (grid[1:] + grid[:-1]) / 2.0
    return Support(grid=jnp.asarray(grid, jnp.float32),
                   edges=jnp.asarray(edges, jnp.float32))


def quantize(support: Support, x: jax.Array) -> jax.Array:
    """Map loss values to bin indices in [0, K)."""
    return jnp.searchsorted(support.edges, x.astype(support.edges.dtype))
