"""DEPRECATED — thin wrappers over `repro.strategy` (one-release shim).

The free functions below were the original offline trace evaluators.
Every behaviour now lives in the `Strategy` registry
(``repro.strategy.make``) and runs through the single batched evaluator
``repro.strategy.evaluate`` — the same objects that drive the serving
engine.  These wrappers reproduce the legacy signatures and decisions
exactly (per-lane cost sums match to float addition-order) and will be
removed in the next release; new code should use::

    from repro import strategy
    casc = strategy.Cascade.from_traces(losses, costs, k=32)
    res = strategy.evaluate(strategy.make("recall_index", casc), losses)

Implemented policies (§3.1, §4, §6 + classic EE baselines):
  * ``recall_index``      — the paper's Alg. 1 (optimal with-recall).
  * ``norecall_threshold``— confidence-threshold early exit
                            (DeeBERT / BranchyNet style; provably no
                            constant-factor approx, Thm 3.4).
  * ``recall_threshold``  — same stopping rule, but serves the argmin ramp
                            (ablation isolating the value of recall).
  * ``norecall_patience`` — PABEE-style: exit after `patience` consecutive
                            ramps agree on the prediction.
  * ``oracle``            — offline optimum with recall (Def. 3.2 analogue:
                            best prefix with full foresight).
  * ``oracle_norecall``   — offline optimum forced to serve the last probed.
  * ``always_last`` / ``always_first`` — static endpoints of the trade-off.
"""

from __future__ import annotations

import warnings

import jax

from repro.core.line_dp import LineTables
from repro.strategy.base import PolicyResult, evaluate
from repro.strategy.line import (FixedNodeStrategy, PatienceStrategy,
                                 RecallIndexStrategy, ThresholdStrategy)
from repro.strategy.oracle import OracleStrategy

__all__ = [
    "PolicyResult", "recall_index", "norecall_threshold", "recall_threshold",
    "norecall_patience", "oracle", "oracle_norecall", "always_last",
    "always_first",
]


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.policies.{name} is deprecated; use "
        f"repro.strategy.make(...) + repro.strategy.evaluate(...)",
        DeprecationWarning, stacklevel=3)


def recall_index(tables: LineTables, losses: jax.Array, bins: jax.Array,
                 costs: jax.Array) -> PolicyResult:
    """Alg. 1 — probe while X > sigma, then serve the argmin ramp."""
    _warn("recall_index")
    strat = RecallIndexStrategy(tables, support=None, costs=costs)
    return evaluate(strat, losses, aux=bins)


def norecall_threshold(losses: jax.Array, costs: jax.Array,
                       thresholds: jax.Array) -> PolicyResult:
    _warn("norecall_threshold")
    strat = ThresholdStrategy(losses.shape[1], thresholds, recall=False,
                              costs=costs)
    return evaluate(strat, losses)


def recall_threshold(losses: jax.Array, costs: jax.Array,
                     thresholds: jax.Array) -> PolicyResult:
    _warn("recall_threshold")
    strat = ThresholdStrategy(losses.shape[1], thresholds, recall=True,
                              costs=costs)
    return evaluate(strat, losses)


def norecall_patience(losses: jax.Array, costs: jax.Array,
                      preds: jax.Array, patience: int) -> PolicyResult:
    """PABEE: stop once `patience` consecutive ramps emit the same label."""
    _warn("norecall_patience")
    strat = PatienceStrategy(losses.shape[1], patience, costs=costs)
    return evaluate(strat, losses, aux=preds)


def oracle(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    """Offline optimum with recall: best prefix under full foresight."""
    _warn("oracle")
    strat = OracleStrategy(losses.shape[1], costs=costs, recall=True)
    return evaluate(strat, losses)


def oracle_norecall(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    _warn("oracle_norecall")
    strat = OracleStrategy(losses.shape[1], costs=costs, recall=False)
    return evaluate(strat, losses)


def always_last(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    _warn("always_last")
    n = losses.shape[1]
    return evaluate(FixedNodeStrategy(n, n - 1, costs=costs), losses)


def always_first(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    _warn("always_first")
    n = losses.shape[1]
    return evaluate(FixedNodeStrategy(n, 0, costs=costs), losses)
