"""Executable routing/stopping policies over loss traces.

Every policy consumes a batch of traces — ``losses`` (T, n) real-valued
per-node losses (lambda-scaled) and their binned version ``bins`` (T, n) —
and returns which node each sample served plus the exploration cost paid.
All policies are vectorized over T with a Python loop over the (static)
n nodes, so they jit cleanly and shard over the data axis in serving.

Implemented policies (§3.1, §4, §6 + classic EE baselines):
  * ``recall_index``      — the paper's Alg. 1 (optimal with-recall).
  * ``norecall_threshold``— confidence-threshold early exit
                            (DeeBERT / BranchyNet style; provably no
                            constant-factor approx, Thm 3.4).
  * ``recall_threshold``  — same stopping rule, but serves the argmin ramp
                            (ablation isolating the value of recall).
  * ``norecall_patience`` — PABEE-style: exit after `patience` consecutive
                            ramps agree on the prediction.
  * ``oracle``            — offline optimum with recall (Def. 3.2 analogue:
                            best prefix with full foresight).
  * ``oracle_norecall``   — offline optimum forced to serve the last probed.
  * ``always_last`` / ``always_first`` — static endpoints of the trade-off.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.line_dp import LineTables

__all__ = [
    "PolicyResult", "recall_index", "norecall_threshold", "recall_threshold",
    "norecall_patience", "oracle", "oracle_norecall", "always_last",
    "always_first",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PolicyResult:
    served_node: jax.Array   # (T,) int — node whose prediction is returned
    served_loss: jax.Array   # (T,) float — loss of the served node
    explore_cost: jax.Array  # (T,) float — sum of inspection costs paid
    n_probed: jax.Array      # (T,) int — number of nodes inspected

    @property
    def total(self) -> jax.Array:
        return self.served_loss + self.explore_cost

    def mean_total(self) -> jax.Array:
        return jnp.mean(self.total)


def _finalize(losses, costs, stopped_at, served, n):
    """Common bookkeeping given per-sample stop index and served node."""
    t = losses.shape[0]
    idx = jnp.arange(n)[None, :]
    probed_mask = idx <= stopped_at[:, None]
    explore_cost = jnp.sum(probed_mask * costs[None, :], axis=1)
    served_loss = jnp.take_along_axis(losses, served[:, None], axis=1)[:, 0]
    return PolicyResult(
        served_node=served,
        served_loss=served_loss,
        explore_cost=explore_cost,
        n_probed=stopped_at + 1,
    )


def recall_index(tables: LineTables, losses: jax.Array, bins: jax.Array,
                 costs: jax.Array) -> PolicyResult:
    """Alg. 1 — probe while X > sigma, then serve the argmin ramp.

    Decisions come from the precomputed if-stop table ``tables.stop``:
    O(1) gather per node per sample (the Thm 4.5 inference bound).
    """
    t, n = bins.shape
    k = tables.k
    inf_x = k + 1  # X-axis sentinel index (see line_dp.x_values)

    x_idx = jnp.full((t,), inf_x, jnp.int32)       # running-min X-axis index
    s_bin = jnp.zeros((t,), jnp.int32)             # previous node's bin
    best_node = jnp.zeros((t,), jnp.int32)
    best_loss = jnp.full((t,), jnp.inf, losses.dtype)
    stopped_at = jnp.full((t,), n - 1, jnp.int32)
    active = jnp.ones((t,), bool)

    for i in range(n):
        # stop table consulted BEFORE probing node i (node 0 row is all-
        # continue: the policy must serve something).
        stop_now = tables.stop[i, s_bin, x_idx] & (i > 0)
        newly_stopped = active & stop_now
        stopped_at = jnp.where(newly_stopped, i - 1, stopped_at)
        active = active & ~stop_now

        r, b = losses[:, i], bins[:, i]
        better = active & (r < best_loss)
        best_loss = jnp.where(better, r, best_loss)
        best_node = jnp.where(better, i, best_node)
        x_idx = jnp.where(active, jnp.minimum(x_idx, b + 1), x_idx)
        s_bin = jnp.where(active, b, s_bin)

    return _finalize(losses, costs, stopped_at, best_node, n)


def _threshold_stop(losses, thresholds):
    """First node whose loss clears its threshold (last node forced)."""
    t, n = losses.shape
    hits = losses <= thresholds[None, :]
    hits = hits.at[:, -1].set(True)
    return jnp.argmax(hits, axis=1).astype(jnp.int32)


def norecall_threshold(losses: jax.Array, costs: jax.Array,
                       thresholds: jax.Array) -> PolicyResult:
    stopped = _threshold_stop(losses, thresholds)
    return _finalize(losses, costs, stopped, stopped, losses.shape[1])


def recall_threshold(losses: jax.Array, costs: jax.Array,
                     thresholds: jax.Array) -> PolicyResult:
    stopped = _threshold_stop(losses, thresholds)
    n = losses.shape[1]
    masked = jnp.where(jnp.arange(n)[None, :] <= stopped[:, None],
                       losses, jnp.inf)
    served = jnp.argmin(masked, axis=1).astype(jnp.int32)
    return _finalize(losses, costs, stopped, served, n)


def norecall_patience(losses: jax.Array, costs: jax.Array,
                      preds: jax.Array, patience: int) -> PolicyResult:
    """PABEE: stop once `patience` consecutive ramps emit the same label."""
    t, n = preds.shape
    streak = jnp.zeros((t,), jnp.int32)
    stopped = jnp.full((t,), n - 1, jnp.int32)
    done = jnp.zeros((t,), bool)
    for i in range(1, n):
        same = preds[:, i] == preds[:, i - 1]
        streak = jnp.where(same, streak + 1, 0)
        hit = (~done) & (streak >= patience)
        stopped = jnp.where(hit, i, stopped)
        done = done | hit
    return _finalize(losses, costs, stopped, stopped, n)


def oracle(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    """Offline optimum with recall: best prefix under full foresight."""
    n = losses.shape[1]
    prefix_min = jax.lax.associative_scan(jnp.minimum, losses, axis=1)
    prefix_cost = jnp.cumsum(costs)
    totals = prefix_min + prefix_cost[None, :]
    stopped = jnp.argmin(totals, axis=1).astype(jnp.int32)
    masked = jnp.where(jnp.arange(n)[None, :] <= stopped[:, None],
                       losses, jnp.inf)
    served = jnp.argmin(masked, axis=1).astype(jnp.int32)
    return _finalize(losses, costs, stopped, served, n)


def oracle_norecall(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    prefix_cost = jnp.cumsum(costs)
    totals = losses + prefix_cost[None, :]
    stopped = jnp.argmin(totals, axis=1).astype(jnp.int32)
    return _finalize(losses, costs, stopped, stopped, losses.shape[1])


def always_last(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    t, n = losses.shape
    stopped = jnp.full((t,), n - 1, jnp.int32)
    return _finalize(losses, costs, stopped, stopped, n)


def always_first(losses: jax.Array, costs: jax.Array) -> PolicyResult:
    t, n = losses.shape
    stopped = jnp.zeros((t,), jnp.int32)
    return _finalize(losses, costs, stopped, stopped, n)
