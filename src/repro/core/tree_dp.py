"""Markovian costly exploration over directed trees / forests (§5.1, App. C).

The paper's result (Thm C.7 + C.14): the optimal policy probes, among all
*available* nodes (roots or children of probed nodes), the one with the
smallest **dynamic index**, and stops once the running min X falls below
every available index.  A node's index is the indifference point of the
subproblem "explore only subtree(v), against outside option x", i.e. the
contraction of the whole subtree into one equivalent node (Lem. C.4/C.5).

Implementation notes:
  * ``subtree_phi`` evaluates the contracted subtree's equivalent loss
    Phi_v(x | s) exactly (expectimax over the subtree);
    ``node_index`` then bisects Phi_v(x|s) = x for sigma_v(s).  Phi - x is
    non-increasing and 1-Lipschitz (Lem. B.1) so bisection is safe.
  * ``solve_forest_exact`` is the unrestricted expectimax optimum (same
    value the DP must match — Thm C.14's claim is index policy == optimal).
  * ``index_policy_value`` evaluates THE index policy exactly (expectation
    over all realizations, following the policy's choices).  The property
    tests assert it equals ``solve_forest_exact`` — a direct numerical
    verification of Thm C.14.
  * Multi-line (§C.1) is the special case of a forest whose trees are
    paths; ``forest_from_lines`` builds it.

Exactness over asymptotics: these evaluators are exponential in subtree
size (fine for serving-cascade topologies, n <= ~10); the paper's poly-time
contraction applies the same recursions bottom-up with quantized cost
support — the values computed here are the ground truth those tables
approximate.  See DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = ["Forest", "forest_from_lines", "solve_forest_exact",
           "node_index", "index_policy_value", "simulate_forest"]


@dataclasses.dataclass(frozen=True)
class Forest:
    """Discrete Markovian forest instance.

    Attributes:
      parents: parents[v] = parent id, or -1 for roots.
      root_pmfs: root id -> (K,) PMF over the support.
      trans: non-root id -> (K, K) matrix, ``Pr[R_v = y | R_parent = s]``.
      costs: (n,) per-node inspection cost.
      grid: (K,) common support values.
    """
    parents: tuple[int, ...]
    root_pmfs: dict[int, np.ndarray]
    trans: dict[int, np.ndarray]
    costs: np.ndarray
    grid: np.ndarray

    @property
    def n(self) -> int:
        return len(self.parents)

    @property
    def k(self) -> int:
        return len(self.grid)

    @functools.cached_property
    def children(self) -> tuple[tuple[int, ...], ...]:
        ch = [[] for _ in range(self.n)]
        for v, p in enumerate(self.parents):
            if p >= 0:
                ch[p].append(v)
        return tuple(tuple(c) for c in ch)

    def subtree(self, v: int) -> tuple[int, ...]:
        out, stack = [], [v]
        while stack:
            u = stack.pop()
            out.append(u)
            stack.extend(self.children[u])
        return tuple(sorted(out))

    def row(self, v: int, parent_bin: int | None) -> np.ndarray:
        """Conditional PMF of R_v given its parent's realized bin."""
        if self.parents[v] < 0:
            return self.root_pmfs[v]
        assert parent_bin is not None
        return self.trans[v][parent_bin]


def forest_from_lines(lines) -> Forest:
    """Build a forest of disjoint paths from [(p0, trans, costs), ...]."""
    parents, root_pmfs, trans_d, costs = [], {}, {}, []
    grid = None
    for (p0, tr, cs, g) in lines:
        base = len(parents)
        grid = g if grid is None else grid
        assert np.allclose(grid, g), "lines must share a support"
        for i in range(len(cs)):
            if i == 0:
                parents.append(-1)
                root_pmfs[base] = np.asarray(p0, np.float64)
            else:
                parents.append(base + i - 1)
                trans_d[base + i] = np.asarray(tr[i - 1], np.float64)
            costs.append(float(cs[i]))
    return Forest(parents=tuple(parents), root_pmfs=root_pmfs, trans=trans_d,
                  costs=np.asarray(costs, np.float64),
                  grid=np.asarray(grid, np.float64))


# ---------------------------------------------------------------------------
# Exact optimum (expectimax over the full information state).
# ---------------------------------------------------------------------------

def _expectimax(forest: Forest, allowed: frozenset[int]):
    """Return memoized V(probed: frozenset[(v, bin)], x: float) restricted
    to nodes in ``allowed``."""
    grid, k = forest.grid, forest.k

    @functools.lru_cache(maxsize=None)
    def value(probed: frozenset, x: float) -> float:
        probed_map = dict(probed)
        best = x
        for v in allowed:
            if v in probed_map:
                continue
            p = forest.parents[v]
            if p >= 0 and p not in probed_map:
                continue  # parent not yet probed
            row = forest.row(v, probed_map.get(p))
            cont = forest.costs[v] + sum(
                row[y] * value(probed | {(v, y)}, min(x, float(grid[y])))
                for y in range(k))
            best = min(best, cont)
        return best

    return value


def solve_forest_exact(forest: Forest) -> float:
    """Online-optimal expected loss (must probe at least one node)."""
    value = _expectimax(forest, frozenset(range(forest.n)))
    inf = float(forest.grid[-1] * 1e6 + 1e6)
    return value(frozenset(), inf)


# ---------------------------------------------------------------------------
# Dynamic index of a node = contraction of its subtree (Lem. C.4/C.5).
# ---------------------------------------------------------------------------

def subtree_phi(forest: Forest, v: int, x: float,
                parent_bin: int | None) -> float:
    """Equivalent loss Phi_v(x | s): optimal play restricted to subtree(v)
    with outside option x, conditioned on the parent's realized bin."""
    allowed = frozenset(forest.subtree(v))
    grid, k = forest.grid, forest.k

    @functools.lru_cache(maxsize=None)
    def value(probed: frozenset, xx: float) -> float:
        probed_map = dict(probed)
        best = xx
        for u in allowed:
            if u in probed_map:
                continue
            p = forest.parents[u]
            if u == v:
                row = forest.row(v, parent_bin)
            elif p in probed_map:
                row = forest.trans[u][probed_map[p]]
            else:
                continue
            cont = forest.costs[u] + sum(
                row[y] * value(probed | {(u, y)}, min(xx, float(grid[y])))
                for y in range(k))
            best = min(best, cont)
        return best

    return value(frozenset(), x)


def node_index(forest: Forest, v: int, parent_bin: int | None,
               tol: float = 1e-9) -> float:
    """sigma_v(s): smallest x with Phi_v(x | s) = x (Def. 4.4 generalized).

    H(x) = Phi - x is non-increasing, 1-Lipschitz, H(0) >= 0; bisect on
    [0, hi] where hi = grid[-1] (H(grid[-1]) <= 0 because stopping at the
    max support value is always weakly worse than the subtree's best)."""
    lo, hi = 0.0, float(forest.grid[-1]) + float(np.sum(forest.costs)) + 1.0
    # Ensure H(hi) <= 0.
    while subtree_phi(forest, v, hi, parent_bin) >= hi - tol:
        if subtree_phi(forest, v, hi, parent_bin) <= hi + tol:
            break
        hi *= 2
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if subtree_phi(forest, v, mid, parent_bin) < mid - tol:
            hi = mid
        else:
            lo = mid
        if hi - lo < tol:
            break
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# The index policy (Alg. 3 / Thm C.7) and its exact value.
# ---------------------------------------------------------------------------

def _policy_action(forest: Forest, probed_map: dict[int, int], x: float,
                   sigma_cache: dict) -> int | None:
    """Index policy: probe argmin-sigma frontier node, or None to stop."""
    frontier = [v for v in range(forest.n)
                if v not in probed_map
                and (forest.parents[v] < 0 or forest.parents[v] in probed_map)]
    if not frontier:
        return None
    sigmas = []
    for v in frontier:
        key = (v, probed_map.get(forest.parents[v]))
        if key not in sigma_cache:
            sigma_cache[key] = node_index(forest, v, key[1])
        sigmas.append(sigma_cache[key])
    j = int(np.argmin(sigmas))
    if x <= sigmas[j] + 1e-9:
        return None  # X at-or-below every index -> stop (ties stop)
    return frontier[j]


def index_policy_value(forest: Forest) -> float:
    """Exact expected loss of the index policy (for Thm C.14 validation)."""
    grid, k = forest.grid, forest.k
    sigma_cache: dict = {}

    @functools.lru_cache(maxsize=None)
    def value(probed: frozenset, x: float) -> float:
        probed_map = dict(probed)
        v = _policy_action(forest, probed_map, x, sigma_cache)
        if v is None:
            return x
        row = forest.row(v, probed_map.get(forest.parents[v]))
        return forest.costs[v] + sum(
            row[y] * value(probed | {(v, y)}, min(x, float(grid[y])))
            for y in range(k))

    inf = float(grid[-1] * 1e6 + 1e6)
    # Force at least one probe (policy must serve something).
    frontier = [v for v in range(forest.n) if forest.parents[v] < 0]
    assert frontier, "forest has no roots"
    return value(frozenset(), inf)


def simulate_forest(forest: Forest, bins: np.ndarray,
                    losses: np.ndarray | None = None):
    """Run the index policy on sampled realizations.

    Args:
      bins: (T, n) realized bin of every node (column v = node v).
      losses: optional (T, n) real losses; defaults to grid values.

    Returns (served_loss, explore_cost, n_probed) arrays.
    """
    grid = forest.grid
    if losses is None:
        losses = grid[bins]
    t = bins.shape[0]
    sigma_cache: dict = {}
    served = np.zeros(t)
    spent = np.zeros(t)
    nprobe = np.zeros(t, np.int64)
    for r in range(t):
        probed_map: dict[int, int] = {}
        x = float(grid[-1] * 1e6 + 1e6)
        best = np.inf
        while True:
            v = _policy_action(forest, probed_map, x, sigma_cache)
            if v is None:
                break
            spent[r] += forest.costs[v]
            nprobe[r] += 1
            probed_map[v] = int(bins[r, v])
            best = min(best, float(losses[r, v]))
            x = min(x, float(grid[bins[r, v]]))
        served[r] = best if np.isfinite(best) else float(grid[-1])
    return served, spent, nprobe
