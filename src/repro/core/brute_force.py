"""Exact expectimax oracles for small instances (test-only, pure numpy).

These compute the online-optimal expected loss by direct minimization over
ALL adaptive probe/stop policies — no index structure, no if-stop tables —
and serve as the independent ground truth that the DP solvers (line, skip,
multi-line, tree) are validated against in the property tests
(Thm 4.5 / 5.1 / 5.2 optimality claims).

Exponential in n and |V|; use with n <= 6, K <= 4.
"""

from __future__ import annotations

import functools

import numpy as np

__all__ = ["bf_line", "bf_skip", "bf_forest"]


def bf_line(p0: np.ndarray, trans: np.ndarray, costs: np.ndarray,
            grid: np.ndarray) -> float:
    """Optimal online value for the with-recall single line (Prob. 4.1)."""
    n = len(costs)
    k = len(grid)

    @functools.lru_cache(maxsize=None)
    def value(i: int, s: int, xb: int) -> float:
        # xb == k encodes X = +inf (nothing probed yet).
        stop = np.inf if xb == k else float(grid[xb])
        if i == n:
            return stop
        row = p0 if i == 0 else trans[i - 1][s]
        cont = costs[i] + sum(
            row[y] * value(i + 1, y, min(xb, y)) for y in range(k))
        return min(stop, cont)

    return value(0, 0, k)


def bf_skip(p0: np.ndarray, trans: np.ndarray, cost_edge: np.ndarray,
            grid: np.ndarray) -> float:
    """Optimal value for the transitive closure of a line (§5.2).

    ``cost_edge[i, j]`` is the cost of probing j right after i (i < j);
    row 0 is the dummy-root row, so nodes are 1-indexed into cost_edge.
    """
    n = trans.shape[0] + 1
    k = len(grid)

    # P^{(i->j)} cumulative conditionals, 0-indexed nodes.
    cum = {}
    for i in range(n):
        acc = np.eye(k)
        for j in range(i + 1, n):
            acc = acc @ trans[j - 1]
            cum[(i, j)] = acc

    @functools.lru_cache(maxsize=None)
    def value(last: int, s: int, xb: int) -> float:
        # last = -1 means at dummy root; s, xb as in bf_line.
        stop = np.inf if xb == k else float(grid[xb])
        best = stop
        for j in range(last + 1, n):
            if last < 0:
                row = p0 if j == 0 else p0 @ cum[(0, j)]
            else:
                row = trans[last][s] if j == last + 1 else cum[(last, j)][s]
            c = cost_edge[last + 1, j + 1]
            cont = c + sum(
                row[y] * value(j, y, min(xb, y)) for y in range(k))
            best = min(best, cont)
        return best

    return value(-1, 0, k)


def bf_forest(parents: list[int], root_pmfs: dict[int, np.ndarray],
              trans: dict[int, np.ndarray], costs: np.ndarray,
              grid: np.ndarray) -> float:
    """Optimal value for Markovian costly exploration over a forest (§5.1).

    Args:
      parents: parents[v] = parent node or -1 for roots.
      root_pmfs: root node -> (K,) marginal PMF.
      trans: non-root node v -> (K, K) conditional ``Pr[R_v = y | R_parent = s]``.
      costs: (n,) per-node inspection cost (edge cost folded into child).
      grid: (K,) support values.
    """
    n = len(parents)
    k = len(grid)
    children = [[] for _ in range(n)]
    roots = []
    for v, p in enumerate(parents):
        if p < 0:
            roots.append(v)
        else:
            children[p].append(v)

    @functools.lru_cache(maxsize=None)
    def value(probed: frozenset, xb: int) -> float:
        stop = np.inf if xb == k else float(grid[xb])
        probed_map = dict(probed)
        frontier = [v for v in range(n)
                    if v not in probed_map
                    and (parents[v] < 0 or parents[v] in probed_map)]
        best = stop
        for v in frontier:
            row = (root_pmfs[v] if parents[v] < 0
                   else trans[v][probed_map[parents[v]]])
            cont = costs[v] + sum(
                row[y] * value(probed | {(v, y)}, min(xb, y))
                for y in range(k))
            best = min(best, cont)
        return best

    return value(frozenset(), k)
