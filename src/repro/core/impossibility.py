"""Theorem 3.4 construction: no-recall strategies admit no constant-factor
approximation, even with n = 2 and bounded supports.

The instance (proof sketch of Thm 3.4):

    R_1 = 1/alpha^2                 w.p. 1
    R_2 = 0 (we use eps>0 to keep Assumption 2.1)   w.p. 1 - 1/alpha
        = 1/alpha                                    w.p. 1/alpha

Any no-recall algorithm earns exactly 1/alpha^2 in expectation (stop at R_1:
pay 1/alpha^2; continue: E[R_2] = 1/alpha * 1/alpha = 1/alpha^2), while the
prophet pays E[min] = (1/alpha) * (1/alpha^2) -> ratio alpha, unbounded as
alpha grows.  ``benchmarks/impossibility`` sweeps alpha and reports the
measured ratio of the BEST no-recall policy vs OPT.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Instance", "make_instance", "best_norecall_value",
           "offline_opt_value", "empirical_ratio"]


@dataclasses.dataclass(frozen=True)
class Instance:
    alpha: float
    r1: float
    r2_values: np.ndarray   # (2,)
    r2_probs: np.ndarray    # (2,)


def make_instance(alpha: float, eps: float = 0.0) -> Instance:
    a = float(alpha)
    return Instance(
        alpha=a,
        r1=1.0 / a**2,
        r2_values=np.array([eps, 1.0 / a]),
        r2_probs=np.array([1.0 - 1.0 / a, 1.0 / a]),
    )


def best_norecall_value(inst: Instance) -> float:
    """Expected loss of the best no-recall stopping rule.

    R_1 is deterministic, so the only choices are "stop at 1" (pay r1) or
    "always continue" (pay E[R_2]); randomization cannot beat the better
    pure rule.
    """
    e_r2 = float(inst.r2_values @ inst.r2_probs)
    return min(inst.r1, e_r2)


def offline_opt_value(inst: Instance) -> float:
    mins = np.minimum(inst.r1, inst.r2_values)
    return float(mins @ inst.r2_probs)


def empirical_ratio(inst: Instance, rng: np.random.Generator,
                    t: int = 200_000) -> tuple[float, float, float]:
    """Monte-Carlo check of the analytic ratio; returns
    (alg_value, opt_value, ratio)."""
    draws = rng.choice(inst.r2_values, size=t, p=inst.r2_probs)
    alg = min(inst.r1, float(np.mean(draws)))
    opt = float(np.mean(np.minimum(inst.r1, draws)))
    return alg, opt, alg / max(opt, 1e-300)
