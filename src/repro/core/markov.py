"""Markov-chain model of per-ramp losses (Problem 2.4's distributional input).

The DP consumes:
  * ``p0``    — (K,) PMF of the first node's binned loss R_1,
  * ``trans`` — (n-1, K, K) row-stochastic transition matrices,
                ``trans[i][s, y] = Pr[R_{i+2} = v_y | R_{i+1} = v_s]``.

Estimation is plain Laplace-smoothed counting over calibration traces
(T x n binned losses), which is the ``O(n |V|^2 T)`` preprocessing term in
Thm 4.5 — fitting the tables dominates, the Bellman backward pass is
``O(n |V|^2)`` matmuls.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.support import Support, build_support, quantize

__all__ = ["MarkovChain", "estimate_chain", "sample_chain", "marginals"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MarkovChain:
    """Discrete Markov chain over a common support of size K with n nodes."""

    p0: jax.Array      # (K,)
    trans: jax.Array   # (n-1, K, K), row-stochastic

    @property
    def n(self) -> int:
        return int(self.trans.shape[0]) + 1

    @property
    def k(self) -> int:
        return int(self.p0.shape[0])


def estimate_chain(bins: jax.Array, k: int, alpha: float = 0.5) -> MarkovChain:
    """Fit a MarkovChain from binned calibration traces.

    Args:
      bins: (T, n) int array of binned losses per sample per node.
      k: support size.
      alpha: Laplace smoothing pseudo-count.
    """
    bins = jnp.asarray(bins)
    t, n = bins.shape
    p0 = jnp.bincount(bins[:, 0], length=k) + alpha
    p0 = p0 / p0.sum()

    def fit_step(i):
        # counts[s, y] = #{rows with bins[:,i]==s and bins[:,i+1]==y}
        idx = bins[:, i] * k + bins[:, i + 1]
        counts = jnp.bincount(idx, length=k * k).reshape(k, k) + alpha
        return counts / counts.sum(axis=1, keepdims=True)

    trans = jnp.stack([fit_step(i) for i in range(n - 1)]) if n > 1 else \
        jnp.zeros((0, k, k), p0.dtype)
    return MarkovChain(p0=p0.astype(jnp.float32), trans=trans.astype(jnp.float32))


def estimate_from_losses(losses: np.ndarray, k: int,
                         alpha: float = 0.5) -> tuple[MarkovChain, Support]:
    """Convenience: build support + chain straight from raw loss traces."""
    support = build_support(losses, k)
    bins = quantize(support, jnp.asarray(losses))
    return estimate_chain(bins, k, alpha), support


def marginals(chain: MarkovChain) -> jax.Array:
    """(n, K) marginal PMFs p_i (Chapman-Kolmogorov forward pass)."""
    out = [chain.p0]
    p = chain.p0
    for i in range(chain.n - 1):
        p = p @ chain.trans[i]
        out.append(p)
    return jnp.stack(out)


def cumulative_transitions(chain: MarkovChain) -> jax.Array:
    """(n, n, K, K) products P^{(i->j)} for i<j (identity on diagonal).

    Used by the transitive-closure DP (§5.2): skipping from node i straight
    to node j needs the j-step-ahead conditional ``Pr[R_j | R_i]``, the
    product of intermediate transition matrices.
    Only entries with j > i are meaningful.
    """
    n, k = chain.n, chain.k
    eye = jnp.eye(k, dtype=chain.p0.dtype)
    out = np.empty((n, n), dtype=object)
    mats = [[None] * n for _ in range(n)]
    for i in range(n):
        acc = eye
        mats[i][i] = acc
        for j in range(i + 1, n):
            acc = acc @ chain.trans[j - 1]
            mats[i][j] = acc
    del out
    return jnp.stack([jnp.stack([mats[i][j] if mats[i][j] is not None else eye
                                 for j in range(n)]) for i in range(n)])


def sample_chain(chain: MarkovChain, key: jax.Array, t: int) -> jax.Array:
    """Sample (t, n) bin trajectories from the chain (for simulation tests)."""
    k0, kr = jax.random.split(key)
    first = jax.random.categorical(k0, jnp.log(chain.p0)[None, :].repeat(t, 0))

    if chain.n == 1:
        return first[:, None]

    def step(prev, inp):
        tr, kk = inp
        logits = jnp.log(tr[prev] + 1e-30)
        nxt = jax.random.categorical(kk, logits)
        return nxt, nxt

    keys = jax.random.split(kr, chain.n - 1)
    _, rest = jax.lax.scan(step, first, (chain.trans, keys))
    return jnp.concatenate([first[:, None], rest.T], axis=1)
