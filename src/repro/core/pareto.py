"""Accuracy-latency Pareto frontier sweeps (paper §6, Figs. 4-5).

Given per-ramp calibration traces of an EE workload —
  losses  (T, n): proxy loss per ramp (1 - confidence),
  correct (T, n): does ramp i's label match the backbone's,
  flops   (n,):  incremental cost of segment i (normalized so sum == 1) —
we sweep the trade-off parameter lambda (Def. D.1 latency-aware loss
``theta = lambda * l_j + (1 - lambda) * sum_k c_k``; the paper swaps
lambda's role between §1.2 and Def. D.1 — we fix lambda as the *accuracy*
weight) and, per lambda:

  1. split traces into fit/eval halves,
  2. build the support + Markov chain on the fit half,
  3. solve the line DP, and
  4. run every policy on the eval half, recording
     (error vs backbone, normalized latency).

Error = 1 - Acc where Acc is agreement with the backbone output (§6
Metrics); latency is normalized against always running the full backbone.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import policies
from repro.core.line_dp import solve_line
from repro.core.markov import estimate_chain
from repro.core.support import build_support, quantize

__all__ = ["FrontierPoint", "sweep", "pareto_filter"]


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    policy: str
    lam: float
    error: float          # 1 - agreement with backbone
    latency: float        # normalized expected latency (1.0 = full model)
    objective: float      # mean theta_lambda achieved
    mean_probed: float


def _metrics(name, lam, res, correct, n) -> FrontierPoint:
    served = np.asarray(res.served_node)
    t = served.shape[0]
    agree = correct[np.arange(t), served]
    # explore_cost carries the (1-lam) objective weight; normalized
    # latency divides it back out (flops sum to 1 => latency in (0, 1]).
    denom = max(1.0 - lam, 1e-9)
    return FrontierPoint(
        policy=name,
        lam=float(lam),
        error=float(1.0 - agree.mean()),
        latency=float(np.asarray(res.explore_cost).mean()) / denom,
        objective=float(np.asarray(res.total).mean()),
        mean_probed=float(np.asarray(res.n_probed).mean()),
    )


def sweep(losses: np.ndarray, correct: np.ndarray, flops: np.ndarray,
          lambdas, k: int = 32,
          thresholds=(0.02, 0.05, 0.1, 0.2, 0.3, 0.5)) -> list[FrontierPoint]:
    """Run the full policy comparison across the lambda grid."""
    t, n = losses.shape
    half = t // 2
    fit_l, ev_l = losses[:half], losses[half:]
    ev_c = correct[half:]
    out: list[FrontierPoint] = []
    for lam in lambdas:
        lam = float(lam)
        scaled_fit = lam * fit_l
        scaled_ev = jnp.asarray(lam * ev_l)
        costs = jnp.asarray((1.0 - lam) * flops, jnp.float32)
        support = build_support(scaled_fit, k)
        bins_fit = quantize(support, jnp.asarray(scaled_fit))
        chain = estimate_chain(bins_fit, k)
        # Guard: DP needs strictly positive costs (Assumption 2.1).
        costs = jnp.maximum(costs, 1e-6)
        tables = solve_line(chain, costs, support)
        bins_ev = quantize(support, scaled_ev)

        res = policies.recall_index(tables, scaled_ev, bins_ev, costs)
        out.append(_metrics("recall_index", lam, res, ev_c, n))
        for thr in thresholds:
            thr_vec = jnp.full((n,), lam * thr, jnp.float32)
            res = policies.norecall_threshold(scaled_ev, costs, thr_vec)
            out.append(_metrics(f"norecall_thr={thr}", lam, res, ev_c, n))
            res = policies.recall_threshold(scaled_ev, costs, thr_vec)
            out.append(_metrics(f"recall_thr={thr}", lam, res, ev_c, n))
        res = policies.oracle(scaled_ev, costs)
        out.append(_metrics("oracle", lam, res, ev_c, n))
        res = policies.always_last(scaled_ev, costs)
        out.append(_metrics("always_last", lam, res, ev_c, n))
    return out


def pareto_filter(points: list[FrontierPoint],
                  by_policy_prefix: str | None = None) -> list[FrontierPoint]:
    """Non-dominated (error, latency) subset, optionally per policy family."""
    pts = [p for p in points
           if by_policy_prefix is None or p.policy.startswith(by_policy_prefix)]
    pts = sorted(pts, key=lambda p: (p.latency, p.error))
    front: list[FrontierPoint] = []
    best_err = np.inf
    for p in pts:
        if p.error < best_err - 1e-12:
            front.append(p)
            best_err = p.error
    return front
