"""Accuracy-latency Pareto frontier sweeps (paper §6, Figs. 4-5).

Given per-ramp calibration traces of an EE workload —
  losses  (T, n): proxy loss per ramp (1 - confidence),
  correct (T, n): does ramp i's label match the backbone's,
  flops   (n,):  incremental cost of segment i (normalized so sum == 1) —
we sweep the trade-off parameter lambda (Def. D.1 latency-aware loss
``theta = lambda * l_j + (1 - lambda) * sum_k c_k``; the paper swaps
lambda's role between §1.2 and Def. D.1 — we fix lambda as the *accuracy*
weight) and, per lambda:

  1. split traces into fit/eval halves,
  2. build a `strategy.Cascade` on the fit half (support + Markov chain
     + line DP),
  3. run every strategy from the registry on the eval half through the
     single batched ``strategy.evaluate``, recording
     (error vs backbone, normalized latency).

Error = 1 - Acc where Acc is agreement with the backbone output (§6
Metrics); latency is normalized against always running the full backbone.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import strategy

__all__ = ["FrontierPoint", "sweep", "pareto_filter"]


@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    policy: str
    lam: float
    error: float          # 1 - agreement with backbone
    latency: float        # normalized expected latency (1.0 = full model)
    objective: float      # mean theta_lambda achieved
    mean_probed: float


def _metrics(name, lam, res, correct, n) -> FrontierPoint:
    served = np.asarray(res.served_node)
    t = served.shape[0]
    agree = correct[np.arange(t), served]
    # explore_cost carries the (1-lam) objective weight; normalized
    # latency divides it back out (flops sum to 1 => latency in (0, 1]).
    denom = max(1.0 - lam, 1e-9)
    return FrontierPoint(
        policy=name,
        lam=float(lam),
        error=float(1.0 - agree.mean()),
        latency=float(np.asarray(res.explore_cost).mean()) / denom,
        objective=float(np.asarray(res.total).mean()),
        mean_probed=float(np.asarray(res.n_probed).mean()),
    )


def sweep(losses: np.ndarray, correct: np.ndarray, flops: np.ndarray,
          lambdas, k: int = 32,
          thresholds=(0.02, 0.05, 0.1, 0.2, 0.3, 0.5)) -> list[FrontierPoint]:
    """Run the full strategy comparison across the lambda grid."""
    t, n = losses.shape
    half = t // 2
    fit_l, ev_l = losses[:half], losses[half:]
    ev_c = correct[half:]
    out: list[FrontierPoint] = []
    for lam in lambdas:
        lam = float(lam)
        # cascade tables live in the lambda-scaled domain; the eval half
        # is pre-scaled too, so strategies run with lam=1.0 (no rescale)
        casc = strategy.Cascade.from_traces(fit_l, (1.0 - lam) * flops,
                                            k=k, lam=lam)
        scaled_ev = jnp.asarray(lam * ev_l)

        def run(name: str, **kw):
            strat = strategy.make(name, casc, lam=1.0, **kw)
            return strategy.evaluate(strat, scaled_ev)

        out.append(_metrics("recall_index", lam, run("recall_index"),
                            ev_c, n))
        for thr in thresholds:
            out.append(_metrics(
                f"norecall_thr={thr}", lam,
                run("norecall_threshold", threshold=lam * thr), ev_c, n))
            out.append(_metrics(
                f"recall_thr={thr}", lam,
                run("recall_threshold", threshold=lam * thr), ev_c, n))
        out.append(_metrics("oracle", lam, run("oracle"), ev_c, n))
        out.append(_metrics("always_last", lam, run("always_last"),
                            ev_c, n))
    return out


def pareto_filter(points: list[FrontierPoint],
                  by_policy_prefix: str | None = None) -> list[FrontierPoint]:
    """Non-dominated (error, latency) subset, optionally per policy family."""
    pts = [p for p in points
           if by_policy_prefix is None or p.policy.startswith(by_policy_prefix)]
    pts = sorted(pts, key=lambda p: (p.latency, p.error))
    front: list[FrontierPoint] = []
    best_err = np.inf
    for p in pts:
        if p.error < best_err - 1e-12:
            front.append(p)
            best_err = p.error
    return front
