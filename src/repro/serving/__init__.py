"""repro.serving — segment-wise engines driven by `repro.strategy`,
plus the continuous-batching runtime (`repro.serving.runtime`)."""

from repro.serving.engine import (Classifier, Engine, GenerationStats,
                                  make_token_step)

__all__ = ["Engine", "Classifier", "GenerationStats", "make_token_step"]
