"""repro.serving"""
