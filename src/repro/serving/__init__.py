"""repro.serving — segment-wise engines driven by `repro.strategy`."""

from repro.serving.engine import Classifier, Engine, GenerationStats

__all__ = ["Engine", "Classifier", "GenerationStats"]
