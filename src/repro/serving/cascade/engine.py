"""`CascadeEngineStepper` — the real multi-model cascade: a ladder of
models live in ONE process, one `EngineStepper` per rung, one combined
strategy bank (DESIGN.md §10).

One Server step = one INTERLEAVED ROUND over the rungs:

  1. Rung 0 decodes every normally-walking slot (its chunked admission
     prefills ride along, §9) through a ``walk_io`` token step: the
     step returns, per lane, whether the walk is still active after
     rung 0's head (the ESCALATION SIGNAL) plus the best-served-so-far
     logits — the handoff buffer.
  2. Each deeper rung m then steps its resident lanes in the SAME
     round, resuming the handed-off walks (``resume_walk``: states +
     logits scattered in, folds starting at the rung's global node
     offset).  Dual-resident lanes whose walk already stopped still
     step for position alignment, but their folds and KV writes are
     masked — the cross-model analogue of the engine's early-exit
     holes.
  3. A walk active past the deepest rung it could run on cannot finish
     its token: the slot goes silent, its handoff (walk states + best
     logits) is stashed, and the next rung's `EscalationScheduler`
     lane + catch-up prefill are requested.  Catch-up re-prefills the
     stream through that rung's CHUNKED prefill path under its token
     budget; prefix-cache hits make a RE-escalation skip everything the
     rung retains from its previous residency — recall is a page-table
     re-pin plus a delta, never a full recompute.  Page needs are
     reserved INCREMENTALLY (`KVPool.grow`), not worst-case twice.
  4. When catch-up completes, the pending token decodes on the target
     rung from the stashed handoff and emits; under the recall policy
     both rungs then decode every round until the strategy ignores the
     deep rung for ``patience`` tokens (de-escalation frees its lane);
     under the commit policy the slot pins to the deep rung for good.

Determinism: every device program is deterministic, all host routing is
FIFO with rid tie-breaks, and each lane's stream is a function of its
own request (masked writes per rung) — token streams are bit-identical
run-to-run for a fixed seed, which the cascade tests pin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.cascade.bank import ModelBank
from repro.serving.cascade.metrics import CascadeStats
from repro.serving.cascade.router import CascadeRouter
from repro.serving.cascade.scheduler import EscalationScheduler
from repro.serving.runtime.request import Request
from repro.serving.runtime.scheduler import EngineStepper

__all__ = ["CascadeEngineStepper"]


def _slice_row(states, i: int):
    """One index's bank-state row (per-member pytrees, batch axis
    dropped)."""
    return tuple(jax.tree.map(lambda a: a[i], st) for st in states)


def _scatter_rows(dst_states, dst_lanes, src_rows):
    """Scatter per-slot state ROWS (leaves without the batch axis) into
    a stepper's batched bank states."""
    if not dst_lanes:
        return dst_states
    idx = jnp.asarray(dst_lanes, jnp.int32)
    out = []
    for k, dst in enumerate(dst_states):
        stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                               *[row[k] for row in src_rows])
        out.append(jax.tree.map(
            lambda d, s: d.at[idx].set(s.astype(d.dtype)), dst, stacked))
    return tuple(out)


class CascadeEngineStepper:
    """Real-model ladder stepper behind the standard Server loop."""

    virtual_time = False
    emits_tokens = True
    _tracer = None
    last_escalated = None  # per-slot: emitted via escalation resolution

    # observability plane (DESIGN.md §12): installing the tracer here
    # also fans it out to every rung's EngineStepper so their chunked
    # prefills (initial + catch-up) land on the same event stream
    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, t) -> None:
        self._tracer = t
        for st in self.steppers:
            st.tracer = t

    def __init__(self, bank: ModelBank, strategies: tuple, *,
                 cache_len: int, prompt_len: int, page_size: int = 16,
                 chunk: int = 8, budgets=None, pages=None,
                 policy: str = "recall", patience: int = 4,
                 paged_kernel: bool = False, jit: bool = True,
                 faults=None, governor=None):
        if any(sp.cfg is None or sp.params is None for sp in bank.specs):
            raise ValueError("CascadeEngineStepper needs real cfg+params "
                             "on every ModelSpec (sim specs drive "
                             "CascadeSimStepper)")
        for s in strategies:
            if s.n_nodes != bank.n_total:
                raise ValueError(f"strategy expects {s.n_nodes} nodes, "
                                 f"ladder has {bank.n_total}")
            if getattr(s, "persistent", False):
                raise ValueError("persistent strategies cannot hand "
                                 "walks across rungs")
            if policy == "commit" and getattr(s, "jumps", False):
                raise ValueError(
                    f"{type(s).__name__} walks a NEXT table from the "
                    "root; use --escalate-policy recall")
        self.bank = bank
        self.strategies = strategies
        # fault plane (DESIGN.md §14): scripted chaos + degrade governor
        self.faults = faults
        self.fault_now = 0.0
        self.governor = governor
        self.n_lanes = bank[0].n_lanes        # Server request slots
        self.full_depth = bank.n_total
        self.prompt_len = int(prompt_len)
        self.page_size = int(page_size)
        self.policy = policy
        self.patience = int(patience)
        self.chunk = int(chunk)
        if budgets is None:
            budgets = [self.chunk] * len(bank)
        self.budgets = [int(b) for b in budgets]
        lane_pages = -(-int(cache_len) // self.page_size)
        self.steppers: list[EngineStepper] = []
        for m, sp in enumerate(bank.specs):
            self.steppers.append(EngineStepper(
                sp.params, sp.cfg, strategies, n_lanes=sp.n_lanes,
                cache_len=cache_len, prompt_len=prompt_len, jit=jit,
                kv="paged", page_size=page_size,
                n_pages=(pages[m] if pages is not None else None),
                paged_kernel=paged_kernel,
                prefill_chunk=self.chunk, prefill_budget=self.budgets[m],
                node_offset=bank.offset(m), walk_io=True,
                resume_walk=(m > 0), max_lane_pages=lane_pages,
                model_key=sp.name))
        # rung 0's pool doubles as the Server-facing pool for reports
        self.pool = self.steppers[0].pool
        self.alloc()

    # ------------------------------------------------------------------
    # lifecycle (Server contract)
    # ------------------------------------------------------------------

    def alloc(self) -> None:
        for st in self.steppers:
            st.alloc()
        n = self.n_lanes
        self.router = CascadeRouter(self.bank, n, policy=self.policy,
                                    patience=self.patience)
        self.esc = EscalationScheduler(self.bank, chunk=self.chunk,
                                       budgets=self.budgets)
        self.lane_req: list[Request | None] = [None] * n
        # per slot: prompt + every decode INPUT token so far (the seed
        # token + emitted stream) — the catch-up prefill source
        self.history: list[list[int] | None] = [None] * n
        # slots whose catch-up landed; their pending token resumes next
        # round
        self.ready: set[int] = set()
        # catch-up admissions blocked on pages: (slot, m, lane)
        self.page_wait: list[tuple[int, int, int]] = []
        self.rung_sid = [np.zeros(sp.n_lanes, np.int32)
                         for sp in self.bank.specs]
        self.stats = CascadeStats(len(self.bank))
        self._futile_rounds = 0
        self._page_blocked = False

    def warmup(self) -> None:
        for st in self.steppers:
            st.warmup()
        self.alloc()

    def reserve(self, req: Request) -> bool:
        return self.steppers[0].reserve(req)

    def admit(self, slot: int, req: Request) -> None:
        self.steppers[0].admit(slot, req)
        self.lane_req[slot] = req
        self.history[slot] = [int(t) for t in np.asarray(req.prompt)]
        self.router.admit(slot, len(req.prompt))

    def release(self, slot: int) -> None:
        for m in self.router.release(slot):
            if m == 0:
                self.steppers[0].release(slot)
            else:
                self.steppers[m].release(self._rung_lane(slot, m))
                self.esc.release(slot, m)
        # granted-but-unresolved deep lanes are NOT in the resident set
        # (catch-up still in flight, or parked in page_wait before the
        # rung stepper ever admitted them) — free them too, or a reaped
        # slot leaks the rung's lane and its catch-up pages
        waiting = {(w[0], w[1]) for w in self.page_wait}
        for m in range(1, len(self.bank)):
            lane = self.esc.lane_of(slot, m)
            if lane is None:
                continue
            if (slot, m) not in waiting:
                self.steppers[m].release(lane)
            self.esc.release(slot, m)
        self.esc.cancel(slot)
        self.page_wait = [w for w in self.page_wait if w[0] != slot]
        self.ready.discard(slot)
        self.lane_req[slot] = None
        self.history[slot] = None

    # ------------------------------------------------------------------
    # escalation plumbing
    # ------------------------------------------------------------------

    def _remaining(self, slot: int) -> int:
        tr = self.router.slots[slot]
        return max(1, self.lane_req[slot].max_tokens - tr.emitted)

    def _admit_catchup(self, slot: int, m: int, lane: int) -> None:
        """Chunk-prefill the stream's context onto rung ``m``: the
        catch-up 'prompt' is every token the rung must hold BEFORE the
        pending token's position (the last history entry is the pending
        decode's input).  The page reservation is ONE page-quantum —
        incremental `grow` covers later decode, so an escalated stream
        never reserves its worst case twice."""
        hist = self.history[slot]
        req = Request(rid=self.lane_req[slot].rid,
                      prompt=np.asarray(hist[:-1], np.int32),
                      max_tokens=min(self.page_size,
                                     self._remaining(slot)))
        stepper = self.steppers[m]
        if not stepper.reserve(req):
            self.page_wait.append((slot, m, lane))
            return
        stepper.admit(lane, req)
        self.rung_sid[m][lane] = self.rung_sid[0][slot]
        skipped = stepper._prefilling[lane]["cursor"]
        if skipped > 0:
            # prefix-cache hit from a previous residency: the retained
            # chain re-pins instead of recomputing
            self.stats.repin_tokens += int(skipped)

    def _rung_lane(self, slot: int, m: int) -> int:
        lane = self.esc.lane_of(slot, m)
        if lane is None:
            raise ValueError(f"slot {slot} holds no rung-{m} lane")
        return lane

    # ------------------------------------------------------------------
    # the interleaved round
    # ------------------------------------------------------------------

    def step(self, occupied: np.ndarray, sid: np.ndarray):
        occupied = np.asarray(occupied, bool)
        self.rung_sid[0] = np.asarray(sid, np.int32).copy()
        n = self.n_lanes
        emit = occupied.copy()
        emitted_out = np.zeros(n, np.int32)
        served_out = np.zeros(n, np.int32)
        sb = sp = 0
        chunk_before = sum(st.chunk_stats["tokens_computed"]
                           for st in self.steppers)
        otr = self._tracer
        if otr is not None:
            self.last_escalated = np.zeros(n, bool)

        # 0. freed rungs go to FIFO waiters; page-blocked admissions
        #    retry (pages may have been released since)
        for slot, m, lane in self.esc.grants():
            self._admit_catchup(slot, m, lane)
            if otr is not None:
                otr.emit("esc_grant", rid=self.lane_req[slot].rid,
                         lane=slot, model=m)
        retry, self.page_wait = self.page_wait, []
        for slot, m, lane in retry:
            self._admit_catchup(slot, m, lane)

        # slots resuming their pending token this round vs still silent
        resume = {s for s in self.ready if occupied[s]}
        self.ready -= resume
        silent = np.zeros(n, bool)
        for slot in np.flatnonzero(occupied):
            tr = self.router.slots[slot]
            if tr is not None and tr.pending is not None \
                    and slot not in resume:
                silent[slot] = True
        # page-pressure gate BEFORE any rung runs: a dual-resident slot
        # whose deep-rung lane cannot append (and cannot grow) must skip
        # the WHOLE round — deferring after rung 0 already decoded would
        # double-advance the stream
        self._page_blocked = bool(self.page_wait)
        for slot in np.flatnonzero(occupied & ~silent):
            tr = self.router.slots[slot]
            if tr is None:
                continue
            for m in sorted(tr.resident):
                if m == 0:
                    continue
                lane = self._rung_lane(slot, m)
                pool = self.steppers[m].pool
                if not pool.can_append(lane) and \
                        not pool.grow(lane, self.page_size):
                    silent[slot] = True
                    self._page_blocked = True
                    if slot in resume:
                        resume.discard(slot)
                        self.ready.add(slot)   # retry next round
                    break
        emit &= ~silent

        # 1. rung 0: every normally-walking slot (floored slots skip
        #    it; chunked admissions ride along inside the step)
        occ0 = occupied & ~silent
        for slot in np.flatnonzero(occ0):
            if slot in resume or self.router.floor(slot) > 0:
                occ0[slot] = False
        pre0 = set(self.steppers[0]._prefilling)
        tok0, served0, sb0, sp0, dec0, (wa0, best0) = \
            self.steppers[0].step(occ0, self.rung_sid[0])
        sb += sb0
        sp += sp0
        self.stats.probes[0] += sp0
        emit &= ~(occ0 & ~dec0)                # still prefilling: silent
        for lane in pre0 - set(self.steppers[0]._prefilling):
            # initial prefill finished: the fused chunk seeded the
            # stream's first token — it is the NEXT round's input
            self.history[lane].append(int(tok0[lane]))

        # 2. deeper rungs in ladder order.  Book-keeping per slot:
        #    walk_wa   — is the walk still active past its last rung,
        #    state_loc — (rung, index) where its walk states live,
        #    src_best  — its best-logits handoff row (device),
        #    probed    — rungs whose folds it ran this token.
        walk_wa = {int(s): bool(wa0[s]) for s in np.flatnonzero(dec0)}
        state_loc = {s: (0, s) for s in walk_wa}
        src_best = {s: best0[s] for s in walk_wa}
        probed = {s: [0] for s in walk_wa}
        final_tok = {s: int(tok0[s]) for s in walk_wa}
        final_served = {s: int(served0[s]) for s in walk_wa}
        for m in range(1, len(self.bank)):
            stepper = self.steppers[m]
            run: list[tuple[int, int, str]] = []   # (slot, lane, src)
            for slot in np.flatnonzero(occupied):
                tr = self.router.slots[slot]
                if tr is None:
                    continue
                if slot in resume and max(tr.pending["targets"]) == m:
                    run.append((slot, self._rung_lane(slot, m), "stash"))
                elif tr.pending is None and m in tr.resident:
                    if tr.floor > 0:
                        if self.bank.model_of(tr.floor) == m:
                            # committed here: fresh walk starts at this
                            # rung every token
                            run.append((slot, self._rung_lane(slot, m),
                                        "fresh"))
                    elif dec0[slot]:
                        # dual-resident: step for position alignment
                        # even when the walk stopped earlier (masked
                        # folds, §10 holes)
                        run.append((slot, self._rung_lane(slot, m),
                                    "cont"))
            if not run and not stepper._prefilling:
                continue
            occ_m = np.zeros(stepper.n_lanes, bool)
            wa_m = np.zeros(stepper.n_lanes, bool)
            dst_lanes, rows, best_rows, deferred = [], [], [], []
            for slot, lane, src in run:
                if not stepper.pool.can_append(lane) and \
                        not stepper.pool.grow(lane, self.page_size):
                    # page pressure: defer the slot, never fail it
                    # mid-stream.  Only stash/fresh slots reach here —
                    # dual "cont" slots were gated before rung 0 ran —
                    # so no partial rung work exists to corrupt; a
                    # resuming slot retries next round.
                    deferred.append(slot)
                    self._page_blocked = True
                    if src == "stash":
                        self.ready.add(slot)
                    continue
                occ_m[lane] = True
                if src == "cont":
                    wa_m[lane] = walk_wa.get(slot, False)
                    if wa_m[lane]:
                        loc_m, loc_i = state_loc[slot]
                        best_rows.append(src_best[slot])
                        dst_lanes.append(lane)
                        rows.append(_slice_row(
                            self.steppers[loc_m].states, loc_i))
                    else:
                        # position-alignment step: resident, unprobed
                        self.stats.sync_writes[m] += 1
                elif src == "stash":
                    h = self.router.pending_handoff(slot)
                    wa_m[lane] = True
                    best_rows.append(h["best"])
                    dst_lanes.append(lane)
                    rows.append(h["states"])
                else:                                   # fresh (floored)
                    wa_m[lane] = True
                    best_rows.append(jnp.zeros((stepper.cfg.vocab,),
                                               jnp.float32))
                    dst_lanes.append(lane)
                    rows.append(tuple(
                        jax.tree.map(lambda a: a[0], s.init(1))
                        for s in self.strategies))
            for slot in deferred:
                emit[slot] = False
            best_m = jnp.zeros((stepper.n_lanes, stepper.cfg.vocab),
                               jnp.float32)
            if dst_lanes:
                best_m = best_m.at[jnp.asarray(dst_lanes, jnp.int32)] \
                    .set(jnp.stack(best_rows))
            stepper.states = _scatter_rows(stepper.states, dst_lanes,
                                           rows)
            pre_m = set(stepper._prefilling)
            tok_m, served_m, sb_m, sp_m, dec_m, (wa_out, best_out) = \
                stepper.step(occ_m, self.rung_sid[m],
                             walk=(jnp.asarray(wa_m), best_m))
            sb += sb_m
            sp += sp_m
            self.stats.probes[m] += sp_m
            for lane in pre_m - set(stepper._prefilling):
                # catch-up landed: the pending walk resumes NEXT round;
                # its decode input is the token the source rung already
                # consumed, not the chunk's own head argmax
                slot = self.esc.slot_of(m, lane)
                if slot is None:
                    continue
                stepper.set_lane_token(lane, self.history[slot][-1])
                self.ready.add(slot)
            for slot, lane, src in run:
                if slot in deferred:
                    continue
                if src == "stash":
                    probed[slot] = sorted(set(
                        self.router.pending_handoff(slot)["models"]
                        + [m]))
                if bool(wa_m[lane]):
                    if src == "cont":
                        probed[slot].append(m)
                    elif src == "fresh":
                        probed[slot] = [m]
                    final_tok[slot] = int(tok_m[lane])
                    final_served[slot] = int(served_m[lane])
                    walk_wa[slot] = bool(wa_out[lane])
                    state_loc[slot] = (m, lane)
                    src_best[slot] = best_out[lane]

        # 3. emission resolution per slot (token overrides collected
        #    per rung and applied in one scatter each)
        tok_override: list[dict[int, int]] = [dict()
                                              for _ in self.bank.specs]
        for slot in np.flatnonzero(emit):
            slot = int(slot)
            tr = self.router.slots[slot]
            if tr is None or slot not in final_tok:
                emit[slot] = False
                continue
            lp = len(self.lane_req[slot].prompt)
            if slot in resume:
                if otr is not None:
                    for m in tr.pending["targets"]:
                        otr.emit("esc_resolve",
                                 rid=self.lane_req[slot].rid,
                                 lane=slot, model=m)
                    self.last_escalated[slot] = True
                for m in self.router.finish_escalation(slot, lp):
                    if m == 0:
                        self.steppers[0].release(slot)
                    else:
                        self.steppers[m].release(self._rung_lane(slot, m))
                        self.esc.release(slot, m)
                if self.policy == "commit":
                    self.stats.commits += 1
            if walk_wa.get(slot, False):
                targets = self._next_targets(slot, probed[slot])
                if targets:
                    # the token needs a rung it is not resident on:
                    # stash the handoff, request the lane, go silent
                    emit[slot] = False
                    loc_m, loc_i = state_loc[slot]
                    self.router.begin_escalation(slot, targets, {
                        "best": src_best[slot],
                        "states": _slice_row(
                            self.steppers[loc_m].states, loc_i),
                        "models": probed[slot],
                    })
                    self.stats.escalations += len(targets)
                    for m in targets:
                        if otr is not None:
                            otr.emit("escalate",
                                     rid=self.lane_req[slot].rid,
                                     lane=slot, model=m)
                        lane = self.esc.request(slot, m)
                        if lane is not None:
                            self._admit_catchup(slot, m, lane)
                            if otr is not None:
                                otr.emit("esc_grant",
                                         rid=self.lane_req[slot].rid,
                                         lane=slot, model=m)
                        elif otr is not None:
                            otr.emit("esc_wait",
                                     rid=self.lane_req[slot].rid,
                                     lane=slot, model=m)
                    continue
            token = final_tok[slot]
            served = final_served[slot]
            emitted_out[slot] = token
            served_out[slot] = served
            self.history[slot].append(token)
            sm = self.bank.model_of(served)
            deepest = max(probed[slot])
            self.stats.on_served(sm, deepest)
            if otr is not None and deepest > sm:
                otr.emit("recall", rid=self.lane_req[slot].rid,
                         lane=slot, model=sm, node=served,
                         deepest=deepest)
            for m in self.router.resident(slot):
                lane = slot if m == 0 else self._rung_lane(slot, m)
                tok_override[m][lane] = token
            for m in self.router.note_emit(slot, probed[slot], served,
                                           lp):
                # recall-policy de-escalation: pages back to the rung's
                # pool, the chain stays warm in its prefix cache — the
                # next escalation re-pins instead of recomputing
                self.steppers[m].release(self._rung_lane(slot, m))
                self.esc.release(slot, m)
                self.stats.deescalations += 1
                if otr is not None:
                    otr.emit("deescalate", rid=self.lane_req[slot].rid,
                             lane=slot, model=m)

        for m, over in enumerate(tok_override):
            if over:
                lanes_m = jnp.asarray(sorted(over), jnp.int32)
                vals = jnp.asarray([over[ln] for ln in sorted(over)],
                                   jnp.int32)
                self.steppers[m].tok = \
                    self.steppers[m].tok.at[lanes_m].set(vals)

        # wedge guard: a round that emitted nothing and prefilled
        # nothing cannot free pages or lanes either (only emissions
        # release resources), so if page-blocked work exists the serve
        # can never progress — raise instead of spinning the Server
        # loop forever.  Deterministic, so 3 futile rounds == forever.
        chunk_after = sum(st.chunk_stats["tokens_computed"]
                          for st in self.steppers)
        progressed = bool(emit.any()) or chunk_after > chunk_before
        if not progressed and occupied.any():
            self._futile_rounds += 1
            if self._futile_rounds >= 3 and self._page_blocked:
                from repro.serving.kvpool import PoolExhausted
                blocked = sorted({(s, m) for s, m, _ in self.page_wait})
                raise PoolExhausted(
                    f"cascade wedged: page-blocked escalation work "
                    f"(waiting admissions {blocked}) and no lane can "
                    "emit to free pages — a deeper rung's pool is too "
                    "small for this stream shape; raise its pages / "
                    "cache_len")
        else:
            self._futile_rounds = 0
        return emitted_out, served_out, int(sb), int(sp), emit

    # ------------------------------------------------------------------

    def _next_targets(self, slot: int, probed_models) -> list[int]:
        """The walk is active past the deepest rung it ran: the next
        ladder rung is the escalation target (rung-by-rung; a still-
        deeper need surfaces after that rung's own step).  With a
        `DegradeGovernor` attached, a denied escalation returns no
        targets — the slot then serves the walk's resident-depth
        answer through the normal emit path (the same legal serve the
        last rung uses), instead of parking past its deadline."""
        deepest = max(probed_models)
        if deepest + 1 >= len(self.bank):
            return []        # past the last head: nothing deeper exists
        targets = self.router.escalation_targets(slot, [deepest + 1])
        if targets and self.governor is not None:
            req = self.lane_req[slot]
            need = max(0, len(self.history[slot]) - 1)
            cost = sum(need * self.bank[m].prefill_tok_time
                       for m in targets)
            stalled = self.faults is not None and any(
                self.faults.stall_active(m, self.fault_now)
                for m in targets)
            if not self.governor.allow_escalation(
                    now=self.fault_now, deadline=req.deadline,
                    catchup_cost=cost, stalled=stalled):
                return []
        return targets

    def cascade_stats(self) -> dict:
        # deeper rungs only ever chunk-prefill catch-ups, so their chunk
        # counters ARE the escalation catch-up compute
        for m in range(1, len(self.bank)):
            self.stats.catchup_tokens[m] = \
                self.steppers[m].chunk_stats["tokens_computed"]
        out = self.stats.as_dict()
        out["models"] = [s.name for s in self.bank.specs]
        out["peak_lanes"] = {f"m{m}": v
                             for m, v in self.esc.peak_in_use.items()}
        out["pools"] = {sp.name: st.pool.stats()
                        for sp, st in zip(self.bank.specs, self.steppers)}
        out["chunks"] = {sp.name: dict(st.chunk_stats)
                        for sp, st in zip(self.bank.specs, self.steppers)}
        if self.governor is not None:
            out.update(self.governor.stats())
        return out
