"""`CascadeSimStepper` — virtual-clock multi-model cascade serving
(DESIGN.md §10).

The decision layer is EXACT: each emitted token's node walk over the
combined ladder line is the same ``bank_observe``/``bank_serve`` fold
`strategy.evaluate` runs offline on that token's trace row, so
per-request decisions are independent of lane placement, escalation
timing, and arrival order by construction (the dual-model
decision-parity test pins this).  What the simulation ADDS is the
runtime: which models are resident, what escalation catch-up costs,
which steps a token can actually emit in, and what the virtual clock
charges — the knobs (`ModelSpec.seg_time` / ``prefill_tok_time`` per
model) that let the cascade-vs-monolith sweep reproduce the paper's
recall-vs-no-recall frontier without any model params.

Cost model per step (one device, serial across models, piggyback
roofline per model exactly like the single-model sim):

    cost = overhead + sum_m max(seg_time_m * probes_m / lanes_m,
                                prefill_tok_time_m * catchup_m)

Probes are charged on the step they physically run: an escalating
token's source-model probes at walk time, its target-model probes when
the catch-up finishes and the pending token resolves.  Tokens and
served losses are attributed to the model that SERVED them
(`metrics.CascadeStats`), and an escalating slot is occupied-but-silent
until its pending token emits, so TTFT reflects real emission time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.cascade.bank import ModelBank
from repro.serving.cascade.metrics import CascadeStats
from repro.serving.cascade.router import CascadeRouter
from repro.serving.cascade.scheduler import EscalationScheduler
from repro.serving.engine import bank_observe, bank_serve
from repro.serving.runtime.request import Request
from repro.strategy.base import dynamic_arrays, with_arrays

__all__ = ["CascadeSimStepper", "make_cascade_decide"]

_ROW_PRIME = 9973   # same (rid, token) -> row mapping as SimStepper


def _check_strategies(strategies, n_total: int, policy: str):
    for s in strategies:
        if s.n_nodes != n_total:
            raise ValueError(
                f"strategy expects {s.n_nodes} nodes, the cascade ladder "
                f"has {n_total}")
        if getattr(s, "needs_aux", False):
            raise ValueError(
                f"{type(s).__name__} consumes the aux prediction channel; "
                "simulation replays losses only")
        if policy == "commit" and getattr(s, "jumps", False):
            raise ValueError(
                f"{type(s).__name__} walks a NEXT table from the root; "
                "the commit policy pins walks to a floor mid-line, which "
                "the table was not solved for — use --escalate-policy "
                "recall (or a threshold/index strategy)")


def make_cascade_decide(bank: ModelBank, strategies: tuple):
    """Build the jitted combined-ladder walk.

    ``decide(arrays, losses (B, n_total), occupied (B,), sid (B,),
    floor (B,))`` returns ``(served (B,), probes (M, B) i32,
    depth (M,) i32, deepest (B,) i32)``: the served global node,
    per-model per-lane node-probe counts, per-model launched-node
    counts, and each lane's deepest PROBED node (-1 when nothing was
    observed — the regret meter's recall-forgone attribution).  ``arrays``
    carries each bank slot's dynamic decision arrays as traced
    arguments — the control plane's hot-swap point: publishing new
    same-shaped tables hits the jit cache.  ``floor`` gates the walk —
    nodes below a lane's floor are neither observed nor charged, but
    the lane stays eligible to start at the floor (the commit policy's
    pinned walk); floor 0 reproduces `strategy.evaluate` exactly.
    """
    n_models = len(bank)

    def probed_of(states, sid):
        out = states[0].n_probed
        for k in range(1, len(strategies)):
            out = jnp.where(sid == k, states[k].n_probed, out)
        return out

    def decide(arrays, losses, occupied, sid, floor):
        live = tuple(with_arrays(s, a)
                     for s, a in zip(strategies, arrays))
        b = losses.shape[0]
        states = tuple(s.init(b) for s in live)
        active = occupied
        np_before = jnp.zeros((b,), jnp.int32)
        # per-lane deepest probed node, folded from per-node n_probed
        # deltas — costs no extra strategy calls
        deepest = jnp.full((b,), -1, jnp.int32)
        np_lane = jnp.zeros((b,), jnp.int32)
        probes, depth = [], []
        node = 0
        for m in range(n_models):
            d = jnp.zeros((), jnp.int32)
            for _ in range(bank[m].n_nodes):
                obs = active & (node >= floor)
                d = d + obs.any().astype(jnp.int32)
                states, cont = bank_observe(live, states, node,
                                            losses[:, node], None, obs,
                                            sid)
                np_lane_now = probed_of(states, sid)
                deepest = jnp.where(np_lane_now > np_lane, node, deepest)
                np_lane = np_lane_now
                # below its floor a lane passes through un-observed
                active = jnp.where(node >= floor, cont, active)
                node += 1
            np_now = probed_of(states, sid)
            probes.append(np_now - np_before)
            np_before = np_now
            depth.append(d)
        served = bank_serve(live, states, sid)
        return served, jnp.stack(probes), jnp.stack(depth), deepest

    return jax.jit(decide)


class CascadeSimStepper:
    """Model-free multi-model stepper behind the standard Server loop."""

    virtual_time = True
    emits_tokens = False
    # observability plane (DESIGN.md §12): the server installs the
    # tracer; `last_loss`/`last_escalated` feed its token events with
    # the served-node loss and the escalated flag for attribution
    tracer = None
    last_loss = None
    last_escalated = None
    last_deepest = None     # per-lane deepest PROBED node (-1 = silent)
    # fault plane (DESIGN.md §14): the server stamps its virtual clock
    # here each iteration when a FaultPlan rides the stepper
    fault_now = 0.0

    def __init__(self, bank: ModelBank, strategies: tuple, trace_bank, *,
                 overhead: float = 0.25, policy: str = "recall",
                 patience: int = 4, chunk: int = 16, budgets=None,
                 pool=None, faults=None, governor=None):
        # optional rung-0 paged-KV admission gate (DESIGN.md §13): the
        # same host-side `KVPool` bookkeeping the single-model sim can
        # carry — the soak harness shrinks it to put the cascade under
        # genuine page pressure while the invariant ledger audits it
        self.pool = pool
        # fault plane: scripted chaos windows + the degrade governor
        # that turns deadline pressure into demotion (DESIGN.md §14)
        self.faults = faults
        self.governor = governor
        self.bank = bank
        self.strategies = strategies
        self.traces = np.asarray(trace_bank, np.float32)
        if self.traces.shape[1] != bank.n_total:
            raise ValueError(f"trace bank has {self.traces.shape[1]} "
                             f"node columns, ladder has {bank.n_total}")
        _check_strategies(strategies, bank.n_total, policy)
        self.n_lanes = bank[0].n_lanes        # Server request slots
        self.full_depth = bank.n_total
        self.overhead = float(overhead)
        self.policy = policy
        self.patience = int(patience)
        self.chunk = int(chunk)
        self.budgets = budgets
        self._bank_arrays = tuple(dynamic_arrays(s) for s in strategies)
        self.bank_source = None    # control-plane hot-swap override
        self.row_tap = None        # observed-outcome tap (Recalibrator)
        self._decide = make_cascade_decide(bank, strategies)
        self.alloc()

    def bank_arrays(self) -> tuple:
        if self.bank_source is not None:
            return self.bank_source.bank_arrays()
        return self._bank_arrays

    def decide_cache_size(self) -> int:
        fn = getattr(self._decide, "_cache_size", None)
        return int(fn()) if fn is not None else -1

    def apply_gear(self, gear) -> None:
        """Host-side gear knobs: escalate patience, per-model catch-up
        budgets, per-rung lane caps.  All step-boundary swaps — granted
        residencies and in-flight escalations are never revoked."""
        spec = getattr(gear, "spec", gear)
        patience = getattr(spec, "patience", None)
        if patience is not None:
            self.router.set_patience(patience)
            self.patience = int(patience)
        budgets = getattr(spec, "esc_budgets", None)
        if budgets is not None:
            self.esc.set_budgets(budgets)
            self.budgets = list(budgets)
        lane_split = getattr(spec, "lane_split", None)
        if lane_split is not None:
            self.esc.set_lane_caps(lane_split)

    # ------------------------------------------------------------------

    def alloc(self) -> None:
        if self.pool is not None:
            self.pool.reset()
        n = self.n_lanes
        self.lane_req: list[Request | None] = [None] * n
        self.lane_tidx = np.zeros(n, np.int64)
        self.prefill0 = np.zeros(n, np.int64)
        self.router = CascadeRouter(self.bank, n, policy=self.policy,
                                    patience=self.patience)
        self.esc = EscalationScheduler(self.bank, chunk=self.chunk,
                                       budgets=self.budgets)
        # slot -> {model: catch-up tokens remaining} (granted lanes only)
        self.catchup: dict[int, dict[int, int]] = {}
        # slot -> {model: the catch-up's full length} (planner buckets)
        self.catchup_total: dict[int, dict[int, int]] = {}
        self.stats = CascadeStats(len(self.bank))
        self._stall_seen: set = set()   # (model, window-start) emitted

    def warmup(self) -> None:
        self._decide(self.bank_arrays(),
                     jnp.zeros((self.n_lanes, self.bank.n_total),
                               jnp.float32),
                     jnp.zeros((self.n_lanes,), bool),
                     jnp.zeros((self.n_lanes,), jnp.int32),
                     jnp.zeros((self.n_lanes,), jnp.int32))
        self.alloc()

    def reserve(self, req: Request) -> bool:
        if self.pool is None:
            return True
        return self.pool.reserve(req.prompt, req.max_tokens)

    def admit(self, slot: int, req: Request) -> None:
        self.lane_req[slot] = req
        self.lane_tidx[slot] = 0
        lp = len(req.prompt)
        if self.pool is not None:
            self.pool.admit(slot, req.prompt, req.max_tokens)
        self.prefill0[slot] = lp
        self.router.admit(slot, lp)

    def release(self, slot: int) -> None:
        if self.pool is not None:
            self.pool.release(slot)
        self.router.release(slot)
        # free EVERY granted deep lane, resident or not: a reaped slot
        # may hold lanes granted to escalation targets that never
        # became resident (catch-up unfinished) — the router's resident
        # set alone would leak those (the fault plane's lane audit
        # caught exactly this)
        for m in range(1, len(self.bank)):
            if self.esc.lane_of(slot, m) is not None:
                self.esc.release(slot, m)
        self.esc.cancel(slot)
        self.catchup.pop(slot, None)
        self.catchup_total.pop(slot, None)
        self.lane_req[slot] = None
        self.prefill0[slot] = 0

    # ------------------------------------------------------------------

    def _row(self, req: Request, tidx: int) -> np.ndarray:
        return self.traces[(req.rid * _ROW_PRIME + tidx)
                           % len(self.traces)]

    def _start_catchup(self, slot: int, m: int) -> None:
        lp = len(self.lane_req[slot].prompt)
        need = self.router.catchup_need(slot, m, lp)
        credit = self.router.stream_pos(slot, lp) - need
        if credit > 0:
            # retained context made the re-escalation a re-pin: these
            # tokens are NOT recomputed
            self.stats.repin_tokens += credit
        self.catchup.setdefault(slot, {})[m] = need
        # the planner buckets by the catch-up's FULL length (what the
        # engine's per-rung ChunkPlanner sees), not the moving remainder
        self.catchup_total.setdefault(slot, {})[m] = max(need, 1)

    def _escalation_ready(self, slot: int) -> bool:
        tr = self.router.slots[slot]
        if tr is None or tr.pending is None:
            return False
        cu = self.catchup.get(slot, {})
        return all(m in cu and cu[m] == 0 for m in tr.pending["targets"])

    def _demoted_node(self, slot: int, probed, resident, probes,
                      losses, floor: int) -> int:
        """Denied escalation: the best (lowest-loss) node the walk
        actually observed on a RESIDENT rung this token.  The walk
        stops early on the node line instead of crossing to the target
        model — a legal T-Tamer stop, paid for with recall."""
        cand = []
        for m in probed:
            if m not in resident:
                continue
            start = max(self.bank.offset(m), floor)
            cand.extend(range(start, start + int(probes[m, slot])))
        if not cand:
            # degenerate (no observed resident probes): the floor node
            return int(floor)
        return min(cand, key=lambda n: float(losses[slot, n]))

    def _note_stall(self, model: int) -> None:
        """Emit one `rung_stall` span per scripted window edge."""
        win = self.faults.stall_window(model, self.fault_now)
        if win is None or (model, win[0]) in self._stall_seen:
            return
        self._stall_seen.add((model, win[0]))
        if self.tracer is not None:
            self.tracer.emit("rung_stall", model=model,
                             t0=round(win[0], 9), until=round(win[1], 9))

    def _stalled_models(self) -> set:
        if self.faults is None:
            return set()
        out = set()
        for m in range(len(self.bank)):
            if self.faults.stall_active(m, self.fault_now):
                out.add(m)
                self._note_stall(m)
        return out

    def step(self, occupied: np.ndarray, sid: np.ndarray):
        """Returns ``(emitted, served, seg_batch, seg_policy, cost,
        emit_mask)`` — the SimStepper contract; ``emitted`` carries the
        served global node (sim tokens have no content)."""
        occupied = np.asarray(occupied, bool)
        emit = occupied.copy()
        served_out = np.zeros(self.n_lanes, np.int32)
        m_count = len(self.bank)
        probes_paid = np.zeros(m_count, np.int64)
        chunk_cost = np.zeros(m_count, np.float64)
        seg_batch = 0
        otr = self.tracer
        if otr is not None:
            self.last_loss = np.full(self.n_lanes, np.nan)
            self.last_escalated = np.zeros(self.n_lanes, bool)
            self.last_deepest = np.full(self.n_lanes, -1)
        # fault plane: rungs frozen by a scripted stall window do no
        # work this step — no grants, no prefill, no catch-up, no
        # decode on their lanes.  The clock still advances (cost >=
        # overhead), so a finite window always passes.
        stalled = self._stalled_models()

        # 0. lanes freed since last step go to FIFO waiters (waiters on
        #    a stalled rung hold their FIFO position)
        for slot, m, _lane in self.esc.grants(skip=stalled):
            self._start_catchup(slot, m)
            if otr is not None:
                otr.emit("esc_grant", rid=self.lane_req[slot].rid,
                         lane=slot, model=m)

        # 1. initial model-0 admission prefill (chunked, budgeted)
        prefilling = occupied & (self.prefill0 > 0)
        emit &= ~prefilling
        if prefilling.any() and 0 not in stalled:
            widths = self.esc.plan_catchup(0, {
                int(s): (int(self.prefill0[s]),
                         len(self.lane_req[s].prompt))
                for s in np.flatnonzero(prefilling)})
            for slot, w in widths.items():
                self.prefill0[slot] -= w
                chunk_cost[0] += w * self.bank[0].prefill_tok_time
                if otr is not None:
                    otr.emit("prefill_chunk",
                            rid=self.lane_req[slot].rid, lane=slot,
                            model=0, width=int(w),
                            left=int(self.prefill0[slot]))

        # 2. escalation catch-up chunks, per target model, budgeted
        for m in range(1, m_count):
            if m in stalled:
                continue
            lanes = {slot: (cu[m], self.catchup_total[slot][m])
                     for slot, cu in self.catchup.items()
                     if occupied[slot] and cu.get(m, 0) > 0}
            for slot, w in self.esc.plan_catchup(m, lanes).items():
                self.catchup[slot][m] -= w
                chunk_cost[m] += w * self.bank[m].prefill_tok_time
                self.stats.catchup_tokens[m] += w
                if otr is not None:
                    otr.emit("prefill_chunk",
                            rid=self.lane_req[slot].rid, lane=slot,
                            model=m, width=int(w),
                            left=int(self.catchup[slot][m]))

        # 3. escalations whose every target is granted + caught up:
        #    the pending token resolves and emits NOW, paying the
        #    target-model probes stashed in its handoff
        resolved = set()
        for slot in range(self.n_lanes):
            pend = (occupied[slot]
                    and self.router.slots[slot] is not None
                    and self.router.slots[slot].pending is not None)
            # a ready escalation whose target rung is stalled cannot
            # resolve this step — it stays silent until the window ends
            target_stalled = pend and stalled and any(
                m in stalled
                for m in self.router.slots[slot].pending["targets"])
            if (not occupied[slot] or not self._escalation_ready(slot)
                    or target_stalled):
                if pend:
                    emit[slot] = False      # escalating: silent
                continue
            tr = self.router.slots[slot]
            handoff = tr.pending["handoff"]
            targets = list(tr.pending["targets"])
            lp = len(self.lane_req[slot].prompt)
            for m in self.router.finish_escalation(slot, lp):
                if m >= 1:
                    self.esc.release(slot, m)
            if self.policy == "commit":
                self.stats.commits += 1
            for m in targets:
                # the walk already counted these nodes in seg_batch at
                # trigger time; only the probe COST lands here
                probes_paid[m] += int(handoff["probes"][m])
            served = int(handoff["served"])
            served_out[slot] = served
            emit[slot] = True
            resolved.add(slot)
            sm = self.bank.model_of(served)
            deepest = max(handoff["probed_models"])
            self.stats.on_served(sm, deepest, loss=handoff["loss"])
            if otr is not None:
                rid = self.lane_req[slot].rid
                for m in targets:
                    otr.emit("esc_resolve", rid=rid, lane=slot, model=m)
                if deepest > sm:
                    otr.emit("recall", rid=rid, lane=slot, model=sm,
                             node=served, deepest=deepest)
                self.last_loss[slot] = handoff["loss"]
                self.last_escalated[slot] = True
                self.last_deepest[slot] = int(
                    handoff.get("deepest_node", -1))
            for m in self.router.note_emit(slot,
                                           handoff["probed_models"],
                                           served, lp):
                self.esc.release(slot, m)
                self.stats.deescalations += 1
                if otr is not None:
                    otr.emit("deescalate", rid=self.lane_req[slot].rid,
                             lane=slot, model=m)
            for m in targets:
                self.catchup.get(slot, {}).pop(m, None)
                self.catchup_total.get(slot, {}).pop(m, None)

        # 4. the walk for every normally decoding slot (one batched,
        #    jitted fold over the combined ladder)
        decode = [s for s in np.flatnonzero(emit) if s not in resolved]
        if stalled and decode:
            # a slot whose resident rung is frozen decodes nothing —
            # its row is not consumed, so the decision stream is
            # untouched by where the stall landed
            frozen = [s for s in decode
                      if set(self.router.resident(s)) & stalled]
            for s in frozen:
                emit[s] = False
            decode = [s for s in decode if s not in frozen]
        if decode:
            losses = np.zeros((self.n_lanes, self.bank.n_total),
                              np.float32)
            floor = np.zeros(self.n_lanes, np.int32)
            for slot in decode:
                losses[slot] = self._row(self.lane_req[slot],
                                         int(self.lane_tidx[slot]))
                floor[slot] = self.router.floor(slot)
            mask = np.zeros(self.n_lanes, bool)
            mask[decode] = True
            served, probes, depth, deepest_arr = jax.device_get(
                self._decide(
                    self.bank_arrays(), jnp.asarray(losses),
                    jnp.asarray(mask), jnp.asarray(sid, jnp.int32),
                    jnp.asarray(floor)))
            seg_batch += int(depth.sum())
            if self.row_tap is not None:
                self.row_tap(losses[decode], np.asarray(served)[decode])
            for slot in decode:
                self.lane_tidx[slot] += 1
                lp = len(self.lane_req[slot].prompt)
                probed = [m for m in range(m_count)
                          if int(probes[m, slot]) > 0]
                targets = self.router.escalation_targets(slot, probed)
                resident = set(self.router.resident(slot))
                for m in probed:
                    if m in resident:
                        probes_paid[m] += int(probes[m, slot])
                denied = False
                if targets and self.governor is not None:
                    # degrade governor (DESIGN.md §14): deny the
                    # escalation when the targets' catch-up prefill
                    # cannot fit the request's remaining deadline
                    # budget, or when a target rung is frozen by a
                    # stall window — the slot serves its best shallow
                    # (recalled) answer instead of parking
                    req = self.lane_req[slot]
                    cost = sum(
                        self.router.catchup_need(slot, m, lp)
                        * self.bank[m].prefill_tok_time
                        for m in targets)
                    denied = not self.governor.allow_escalation(
                        now=self.fault_now, deadline=req.deadline,
                        catchup_cost=cost,
                        stalled=any(m in stalled for m in targets))
                if targets and not denied:
                    # the token cannot finish on the resident rungs:
                    # stash the handoff, request deeper lanes, go silent
                    emit[slot] = False
                    self.router.begin_escalation(slot, targets, {
                        "served": int(served[slot]),
                        "probes": np.asarray(probes[:, slot]),
                        "probed_models": probed,
                        "loss": float(losses[slot, int(served[slot])]),
                        "deepest_node": int(deepest_arr[slot]),
                    })
                    self.stats.escalations += len(targets)
                    for m in targets:
                        if otr is not None:
                            otr.emit("escalate",
                                    rid=self.lane_req[slot].rid,
                                    lane=slot, model=m)
                        if self.esc.request(slot, m) is not None:
                            self._start_catchup(slot, m)
                            if otr is not None:
                                otr.emit("esc_grant",
                                        rid=self.lane_req[slot].rid,
                                        lane=slot, model=m)
                        elif otr is not None:
                            otr.emit("esc_wait",
                                    rid=self.lane_req[slot].rid,
                                    lane=slot, model=m)
                else:
                    sv = int(served[slot])
                    if denied:
                        # demotion: serve the best node the walk
                        # actually OBSERVED on a resident rung — a
                        # legal earlier stop on the node line (recall),
                        # not a fabricated answer
                        sv = self._demoted_node(slot, probed, resident,
                                                probes, losses,
                                                int(floor[slot]))
                        served_out[slot] = sv
                        deepest = max((m for m in probed
                                       if m in resident), default=0)
                    else:
                        served_out[slot] = sv
                        deepest = max(probed) if probed else 0
                    sm = self.bank.model_of(sv)
                    self.stats.on_served(sm, deepest,
                                         loss=float(losses[slot, sv]))
                    if otr is not None:
                        self.last_loss[slot] = float(losses[slot, sv])
                        self.last_deepest[slot] = int(deepest_arr[slot])
                        if denied:
                            otr.emit("recall",
                                    rid=self.lane_req[slot].rid,
                                    lane=slot, model=sm, node=sv,
                                    deepest=deepest, denied=True)
                        elif deepest > sm:
                            otr.emit("recall",
                                    rid=self.lane_req[slot].rid,
                                    lane=slot, model=sm, node=sv,
                                    deepest=deepest)
                    for m in self.router.note_emit(slot, probed, sv, lp):
                        self.esc.release(slot, m)
                        self.stats.deescalations += 1
                        if otr is not None:
                            otr.emit("deescalate",
                                    rid=self.lane_req[slot].rid,
                                    lane=slot, model=m)

        if self.pool is not None and emit.any():
            # rung-0 paged bookkeeping per emitted token (fresh tail
            # pages from the reserved budget, COW on shared tails)
            self.pool.prepare_step(emit)
            self.pool.note_written(emit)

        # 5. the virtual clock: serial across models, piggyback
        #    roofline within each (catch-up hides under decode)
        cost = self.overhead
        for m in range(m_count):
            self.stats.probes[m] += int(probes_paid[m])
            decode_cost = self.bank[m].seg_time * float(probes_paid[m]) \
                / max(self.bank[m].n_lanes, 1)
            cost += max(decode_cost, float(chunk_cost[m]))
        seg_policy = int(probes_paid.sum())
        return (served_out, served_out, int(seg_batch), int(seg_policy),
                cost, emit)

    def cascade_stats(self) -> dict:
        out = self.stats.as_dict()
        out["models"] = [s.name for s in self.bank.specs]
        out["peak_lanes"] = {f"m{m}": v
                             for m, v in self.esc.peak_in_use.items()}
        if self.governor is not None:
            out.update(self.governor.stats())
        return out
