"""`CascadeRouter` — per-request routing state for the model ladder
(DESIGN.md §10).

The STRATEGY decides which nodes a token probes; the router turns those
decisions into residency: which models hold live context (lane + KV
pages) for each request slot, when a request ESCALATES onto a deeper
model (catch-up prefill required before the pending token can emit),
when a recall-style strategy's retreat DE-ESCALATES it back off, and —
under the ``commit`` policy — when the request abandons its source model
for good.

Everything here is plain host bookkeeping shared by the simulation and
real-engine cascade steppers, so the escalation state machine is
unit-testable with no device code at all.

Policies (``--escalate-policy``):

  * ``recall`` — rung 0 stays resident for the request's whole life;
    deeper rungs join at escalation and leave after ``patience``
    consecutive emitted tokens whose walks never probed them.  While a
    deeper rung is resident, serving an earlier rung's node (the
    strategy's argmin recall) costs nothing extra — and because a
    released rung's pages stay warm in its model's prefix cache, a
    later RE-escalation's catch-up prefill skips straight past the
    shared prefix: recall is a page-table re-pin plus a delta catch-up,
    never a full recompute.
  * ``commit`` — the no-recall discipline: the first escalation is
    final.  When the pending token resolves, the request commits to the
    deepest model it probed (walk floor pinned to that model's first
    node), and every shallower rung's residency is released.
"""

from __future__ import annotations

import dataclasses

from repro.serving.cascade.bank import ModelBank

__all__ = ["CascadeRouter", "SlotTrack"]

POLICIES = ("recall", "commit")


@dataclasses.dataclass
class SlotTrack:
    """Routing state of one request slot."""

    resident: set                  # model ids with live lane + context
    floor: int = 0                 # first GLOBAL node the walk may probe
    emitted: int = 0               # tokens emitted so far
    # model -> positions of this stream present in the model's context
    # (holes included: positions advance even for unprobed tokens)
    synced: dict = dataclasses.field(default_factory=dict)
    # model -> positions REGISTERED in the model's shareable prefix
    # (the chain its catch-up committed; decode appendage is lane-
    # private and dies with the lane)
    registered: dict = dataclasses.field(default_factory=dict)
    # model -> positions still warm in the model's prefix cache after a
    # de-escalation released its lane (the re-pin credit)
    retained: dict = dataclasses.field(default_factory=dict)
    # model(>0) -> consecutive emitted tokens whose walk skipped it
    idle_streak: dict = dataclasses.field(default_factory=dict)
    # escalation in flight: {"targets": [m..], "handoff": stepper data}
    pending: dict | None = None


class CascadeRouter:
    """Residency + escalation policy over a `ModelBank` ladder."""

    def __init__(self, bank: ModelBank, n_slots: int, *,
                 policy: str = "recall", patience: int = 4):
        if policy not in POLICIES:
            raise ValueError(f"unknown escalate policy {policy!r}; "
                             f"choose from {POLICIES}")
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.bank = bank
        self.n_slots = int(n_slots)
        self.policy = policy
        self.patience = int(patience)
        self.slots: list[SlotTrack | None] = [None] * self.n_slots

    def set_patience(self, patience: int) -> None:
        """Gear knob (control plane): retune the de-escalation window
        mid-serve.  Takes effect from the NEXT emitted token — existing
        idle streaks keep their counts and are judged against the new
        window, so a swap can only move future de-escalations, never
        retroactively drop a resident rung."""
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.patience = int(patience)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def admit(self, slot: int, prompt_len: int) -> SlotTrack:
        if self.slots[slot] is not None:
            raise ValueError(f"slot {slot} already routed")
        tr = SlotTrack(resident={0}, synced={0: int(prompt_len)},
                       registered={0: int(prompt_len)})
        self.slots[slot] = tr
        return tr

    def release(self, slot: int) -> list[int]:
        """Request finished: returns the models whose lanes must be
        freed (every resident model)."""
        tr = self._track(slot)
        self.slots[slot] = None
        return sorted(tr.resident)

    def _track(self, slot: int) -> SlotTrack:
        tr = self.slots[slot]
        if tr is None:
            raise ValueError(f"slot {slot} is not routed")
        return tr

    # ------------------------------------------------------------------
    # queries the steppers drive the state machine with
    # ------------------------------------------------------------------

    def floor(self, slot: int) -> int:
        return self._track(slot).floor

    def resident(self, slot: int) -> list[int]:
        return sorted(self._track(slot).resident)

    def stream_pos(self, slot: int, prompt_len: int) -> int:
        """Context positions a fully synced model holds before the NEXT
        (pending) token decodes: the prompt plus one written position
        per emitted token."""
        return int(prompt_len) + self._track(slot).emitted

    def escalation_targets(self, slot: int, probed_models) -> list[int]:
        """Which of the walk's probed models need a NEW residency —
        the escalation the pending token blocks on."""
        tr = self._track(slot)
        return sorted(m for m in probed_models if m not in tr.resident)

    def catchup_need(self, slot: int, m: int, prompt_len: int) -> int:
        """Catch-up prefill tokens model ``m`` needs before the pending
        token can decode there: the stream's positions BEFORE the
        pending token, minus whatever the model retains from an earlier
        residency (released pages kept warm by its prefix cache — this
        is the quantity that makes re-escalation a delta, not a full
        recompute)."""
        tr = self._track(slot)
        need = self.stream_pos(slot, prompt_len)
        return max(0, need - tr.retained.get(m, 0))

    # ------------------------------------------------------------------
    # transitions
    # ------------------------------------------------------------------

    def begin_escalation(self, slot: int, targets, handoff) -> None:
        tr = self._track(slot)
        if tr.pending is not None:
            raise ValueError(f"slot {slot} already escalating")
        targets = sorted(targets)
        bad = [m for m in targets if m in tr.resident]
        if bad:
            raise ValueError(f"slot {slot}: models {bad} already resident")
        tr.pending = {"targets": targets, "handoff": handoff}

    def pending_handoff(self, slot: int):
        tr = self._track(slot)
        return None if tr.pending is None else tr.pending["handoff"]

    def finish_escalation(self, slot: int, prompt_len: int) -> list[int]:
        """Catch-up complete on every target: the targets become
        resident (synced through the pending token's position).  Under
        the ``commit`` policy this is also the commit point — the walk
        floor moves to the deepest target's first node and every
        shallower residency is released; returns the models to free."""
        tr = self._track(slot)
        if tr.pending is None:
            raise ValueError(f"slot {slot} has no escalation in flight")
        targets = tr.pending["targets"]
        pos = self.stream_pos(slot, prompt_len)
        for m in targets:
            tr.resident.add(m)
            tr.synced[m] = pos
            # the catch-up chain is what the rung's prefix cache keeps
            # shareable (engine: KVPool.commit_prefix) — decode appends
            # after this point are lane-private
            tr.registered[m] = pos
            tr.retained.pop(m, None)
            tr.idle_streak[m] = 0
        tr.pending = None
        if self.policy != "commit":
            return []
        deepest = max(targets)
        tr.floor = self.bank.offset(deepest)
        drop = sorted(m for m in tr.resident if m < deepest)
        for m in drop:
            self._release_model(tr, m)
        return drop

    def note_emit(self, slot: int, probed_models, served_node: int,
                  prompt_len: int) -> list[int]:
        """Account one emitted token; returns the models the recall
        policy DE-ESCALATES (idle past the patience window)."""
        tr = self._track(slot)
        tr.emitted += 1
        pos = self.stream_pos(slot, prompt_len)
        drop = []
        for m in sorted(tr.resident):
            tr.synced[m] = pos
            if m == 0 or self.policy == "commit":
                continue
            if m in probed_models:
                tr.idle_streak[m] = 0
            else:
                tr.idle_streak[m] = tr.idle_streak.get(m, 0) + 1
                if tr.idle_streak[m] >= self.patience:
                    drop.append(m)
        for m in drop:
            self._release_model(tr, m)
        return drop

    def _release_model(self, tr: SlotTrack, m: int) -> None:
        tr.resident.discard(m)
        # the model's prefix cache keeps the REGISTERED chain warm (not
        # the lane-private decode tail), so a re-escalation catches up
        # only the delta past it (engine: real LRU entries; sim: this
        # counter models the same credit)
        tr.retained[m] = tr.registered.get(m, 0)
        tr.idle_streak.pop(m, None)
        tr.synced.pop(m, None)
        tr.registered.pop(m, None)
