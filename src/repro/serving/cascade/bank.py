"""`ModelBank` — the ladder of models one cascade server hosts
(DESIGN.md §10).

A multi-model cascade concatenates each model's T-Tamer nodes (ramps +
final head) into ONE global node line, in escalation order: model 0's
nodes come first, model 1's after, and so on.  A strategy built over the
combined `Cascade` (``boundaries`` = nodes per model, edge costs from
``solve_skip(mode="cascade")``) then decides per token which nodes to
probe — and therefore which MODELS to consult — with no cascade-specific
strategy code at all.

The bank is pure bookkeeping: per-model specs (configs + params for real
serving, virtual cost parameters for simulation) plus the node-offset
arithmetic every other cascade component leans on.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ModelSpec", "ModelBank"]


@dataclasses.dataclass
class ModelSpec:
    """One ladder rung.

    Real serving fills ``cfg``/``params`` (``n_nodes`` is then derived
    and must match ``cfg.n_ramps + 1``); simulation fills the virtual
    cost knobs instead.  ``n_lanes`` is the rung's decode width — rung 0
    is the admission width (one Server slot per rung-0 lane), deeper
    rungs are the escalation capacity.
    """

    name: str
    n_nodes: int
    n_lanes: int = 1
    cfg: object = None             # ModelConfig (real serving)
    params: object = None
    # simulation cost model (virtual units)
    seg_time: float = 1.0          # one node-probe on this model
    prefill_tok_time: float = 0.0  # one prompt/catch-up token


class ModelBank:
    """The ladder: specs in escalation order + node-offset arithmetic."""

    def __init__(self, specs):
        self.specs = tuple(specs)
        if not self.specs:
            raise ValueError("a cascade needs at least one model")
        names = [s.name for s in self.specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names {names}")
        for s in self.specs:
            if s.n_nodes < 1 or s.n_lanes < 1:
                raise ValueError(f"model {s.name!r}: n_nodes and n_lanes "
                                 "must be >= 1")
            if s.cfg is not None and s.cfg.n_ramps + 1 != s.n_nodes:
                raise ValueError(
                    f"model {s.name!r}: n_nodes={s.n_nodes} != "
                    f"cfg ramps+head={s.cfg.n_ramps + 1}")
        vocabs = {s.cfg.vocab for s in self.specs if s.cfg is not None}
        if len(vocabs) > 1:
            raise ValueError(
                f"cascade models must share tokenization (one vocab); "
                f"got {sorted(vocabs)} — escalation re-prefills the same "
                "token ids on the target model")
        self._offsets = []
        off = 0
        for s in self.specs:
            self._offsets.append(off)
            off += s.n_nodes
        self.n_total = off

    def __len__(self) -> int:
        return len(self.specs)

    def __getitem__(self, m: int) -> ModelSpec:
        return self.specs[m]

    @property
    def boundaries(self) -> tuple:
        return tuple(s.n_nodes for s in self.specs)

    def offset(self, m: int) -> int:
        """Global id of model ``m``'s first node."""
        return self._offsets[m]

    def node_range(self, m: int) -> tuple[int, int]:
        return self._offsets[m], self._offsets[m] + self.specs[m].n_nodes

    def model_of(self, node: int) -> int:
        """Which ladder model owns global node ``node``."""
        for m in range(len(self.specs) - 1, -1, -1):
            if node >= self._offsets[m]:
                return m
        raise ValueError(f"negative node {node}")
