"""Cross-model cascade metrics (DESIGN.md §10).

`RuntimeMetrics` keeps its single-model view (the cascade's combined
node line is its ``full_depth``); this module adds the MODEL dimension:
per-model tokens served / node probes / catch-up tokens, escalation and
recall event counts, and the served-loss accumulator the
cascade-vs-monolith Pareto sweeps report (simulation mode knows the
served node's trace loss exactly).

Satellite fix ledger: tokens and segment probes are attributed to the
model that actually SERVED / RAN them — an escalated token that recalls
a small-model node counts as small-model service even though the large
model was consulted — and TTFT comes from the actual emission step
(escalating lanes are occupied but silent, exactly like chunked-prefill
lanes).
"""

from __future__ import annotations

__all__ = ["CascadeStats"]


class CascadeStats:
    """Per-model counters + escalation events for one serve run."""

    def __init__(self, n_models: int):
        self.n_models = int(n_models)
        self.tokens_served = [0] * self.n_models   # by SERVING model
        self.probes = [0] * self.n_models          # node probes run
        self.catchup_tokens = [0] * self.n_models  # escalation prefill
        self.sync_writes = [0] * self.n_models     # resident, unprobed
        self.escalations = 0      # residency added to a deeper model
        self.deescalations = 0    # recall-policy release of a rung
        self.commits = 0          # commit-policy point of no return
        self.recalls = 0          # token served by a shallower model
                                  # than the deepest it probed
        self.repin_tokens = 0     # catch-up tokens SKIPPED via retained
                                  # context (the re-pin, not recompute)
        self.served_loss_sum = 0.0
        self.served_loss_n = 0

    def on_served(self, model: int, deepest_probed: int,
                  loss: float | None = None) -> None:
        self.tokens_served[model] += 1
        if deepest_probed > model:
            self.recalls += 1
        if loss is not None:
            self.served_loss_sum += float(loss)
            self.served_loss_n += 1

    @property
    def mean_served_loss(self) -> float | None:
        if not self.served_loss_n:
            return None
        return self.served_loss_sum / self.served_loss_n

    def as_dict(self) -> dict:
        return {
            "n_models": self.n_models,
            "tokens_served": list(self.tokens_served),
            "probes": list(self.probes),
            "catchup_tokens": list(self.catchup_tokens),
            "sync_writes": list(self.sync_writes),
            "escalations": self.escalations,
            "deescalations": self.deescalations,
            "commits": self.commits,
            "recalls": self.recalls,
            "repin_tokens": self.repin_tokens,
            "mean_served_loss": self.mean_served_loss,
        }
