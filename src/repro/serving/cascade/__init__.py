"""repro.serving.cascade — multi-model cascade serving (DESIGN.md §10).

A ladder of 2+ models in ONE server process, routed per token as a
T-Tamer multi-stage decision process over the CONCATENATED node line:

  * `bank.ModelBank` — the ladder: per-model configs/params (real) or
    virtual cost knobs (sim), node-offset arithmetic, per-model lanes.
  * `router.CascadeRouter` — residency state machine: escalation onto
    deeper models, recall-policy de-escalation, commit-policy floors.
  * `scheduler.EscalationScheduler` — deeper-rung lane pools + per-model
    catch-up token budgets (escalation bursts cannot starve rung 0).
  * `sim.CascadeSimStepper` — virtual-clock stepper (CI, bench sweeps).
  * `engine.CascadeEngineStepper` — the real thing: one `EngineStepper`
    per rung over one combined strategy bank, walks handed off across
    models through the engine's escalation handoff buffers, catch-up
    prefill through the PR-4 chunked path, recall as a prefix-cache
    re-pin.

Both steppers drive the standard `serving.runtime.Server` loop
unchanged — a cascade is just a stepper whose "lane" is a request slot
that may span several models.
"""

from repro.serving.cascade.bank import ModelBank, ModelSpec
from repro.serving.cascade.engine import CascadeEngineStepper
from repro.serving.cascade.metrics import CascadeStats
from repro.serving.cascade.router import CascadeRouter
from repro.serving.cascade.scheduler import EscalationScheduler
from repro.serving.cascade.sim import CascadeSimStepper

__all__ = [
    "ModelSpec", "ModelBank", "CascadeRouter", "EscalationScheduler",
    "CascadeStats", "CascadeSimStepper", "CascadeEngineStepper",
]
