"""`EscalationScheduler` — lanes and token budgets for the deeper rungs
(DESIGN.md §10).

Rung 0's lanes are the Server's request slots (admission capacity);
every deeper model's lanes are an ESCALATION pool this scheduler owns.
An escalating request asks for a lane on its target model; when none is
free it waits in a deterministic FIFO (trigger order, request id
tie-break) while its source-model lane idles silently — requests are
never dropped and never bounce.

The second resource is per-model TOKEN BUDGETS for catch-up prefill:
each rung's catch-up chunks are planned by a per-model `ChunkPlanner`
(the PR-4 fairness machinery, one planner per model), so a burst of
escalations is throttled to its budget per step instead of flooding the
device queue — the small model's decode lanes keep decoding through an
escalation storm.  In engine mode the per-model `EngineStepper` owns the
physical planner; this scheduler carries the budget configuration and
plans the virtual-clock catch-ups for the simulation stepper.
"""

from __future__ import annotations

import collections

from repro.serving.cascade.bank import ModelBank
from repro.serving.runtime.scheduler import ChunkPlanner

__all__ = ["EscalationScheduler"]


class EscalationScheduler:
    """Deeper-rung lane pools + catch-up chunk budgets."""

    def __init__(self, bank: ModelBank, *, chunk: int = 16,
                 budgets=None):
        """``budgets``: per-model catch-up token budget per step (list
        aligned with the bank; entry 0 is the admission-prefill budget).
        Defaults to one ``chunk`` per model."""
        self.bank = bank
        self.chunk = int(chunk)
        if budgets is None:
            budgets = [self.chunk] * len(bank)
        budgets = [int(b) for b in budgets]
        if len(budgets) != len(bank):
            raise ValueError(f"{len(budgets)} budgets for {len(bank)} "
                             "models")
        self.budgets = budgets
        self.planners = [ChunkPlanner(self.chunk, b) for b in budgets]
        # gear-parameterized lane split: per-rung caps on concurrently
        # granted escalation lanes (<= the rung's physical lanes; shapes
        # never change, a cap only throttles grants)
        self.lane_caps = {m: bank[m].n_lanes for m in range(1, len(bank))}
        # deeper rungs: free-lane stacks (ascending pop for determinism)
        self._free = {m: list(range(bank[m].n_lanes - 1, -1, -1))
                      for m in range(1, len(bank))}
        # (slot, model) waiters in trigger order
        self._wait: collections.deque[tuple[int, int]] = \
            collections.deque()
        self._lane_of: dict[tuple[int, int], int] = {}
        self.peak_in_use = {m: 0 for m in range(1, len(bank))}

    # ------------------------------------------------------------------
    # gear knobs (control plane)
    # ------------------------------------------------------------------

    def set_budgets(self, budgets) -> None:
        """Swap the per-model catch-up token budgets between steps."""
        budgets = [int(b) for b in budgets]
        if len(budgets) != len(self.bank):
            raise ValueError(f"{len(budgets)} budgets for "
                             f"{len(self.bank)} models")
        self.budgets = budgets
        for planner, b in zip(self.planners, budgets):
            if b < 1:
                raise ValueError("budget must be >= 1")
            planner.budget = b

    def set_lane_caps(self, caps) -> None:
        """Swap the per-rung escalation lane caps (rungs 1..M-1).
        Already-granted lanes are never revoked — a tighter cap only
        throttles FUTURE grants, so in-flight escalations finish on the
        residency they were granted."""
        caps = [int(c) for c in caps]
        if len(caps) != len(self.bank) - 1:
            raise ValueError(f"{len(caps)} caps for {len(self.bank) - 1} "
                             "escalation rungs")
        for m, c in zip(range(1, len(self.bank)), caps):
            if not 1 <= c <= self.bank[m].n_lanes:
                raise ValueError(
                    f"rung {m} cap {c} outside [1, "
                    f"{self.bank[m].n_lanes}] physical lanes")
            self.lane_caps[m] = c

    def _can_grant(self, m: int) -> bool:
        return bool(self._free[m]) and \
            self.lanes_in_use(m) < self.lane_caps[m]

    # ------------------------------------------------------------------
    # lanes
    # ------------------------------------------------------------------

    def lanes_in_use(self, m: int) -> int:
        return self.bank[m].n_lanes - len(self._free[m])

    def lane_of(self, slot: int, m: int) -> int | None:
        return self._lane_of.get((slot, m))

    def slot_of(self, m: int, lane: int) -> int | None:
        """Reverse lookup: which slot holds rung ``m``'s ``lane``."""
        for (slot, mm), ln in self._lane_of.items():
            if mm == m and ln == lane:
                return slot
        return None

    def request(self, slot: int, m: int) -> int | None:
        """Ask for a lane on rung ``m``; None queues the slot (FIFO)."""
        if m < 1 or m >= len(self.bank):
            raise ValueError(f"rung {m} has no escalation pool")
        if (slot, m) in self._lane_of:
            raise ValueError(f"slot {slot} already holds a lane on "
                             f"model {m}")
        if self._can_grant(m) and not any(w[1] == m for w in self._wait):
            return self._grant(slot, m)
        self._wait.append((slot, m))
        return None

    def _grant(self, slot: int, m: int) -> int:
        lane = self._free[m].pop()
        self._lane_of[(slot, m)] = lane
        self.peak_in_use[m] = max(self.peak_in_use[m],
                                  self.lanes_in_use(m))
        return lane

    def grants(self, skip=()) -> list[tuple[int, int, int]]:
        """Serve waiters whose rung has a free lane now; returns
        ``(slot, model, lane)`` in FIFO order.  Waiters on a rung in
        ``skip`` (e.g. one frozen by a fault-plan stall window) stay
        queued in place — their FIFO position survives the window."""
        out = []
        still = collections.deque()
        while self._wait:
            slot, m = self._wait.popleft()
            if m not in skip and self._can_grant(m):
                out.append((slot, m, self._grant(slot, m)))
            else:
                still.append((slot, m))
        self._wait = still
        return out

    def release(self, slot: int, m: int) -> int:
        """Return the slot's rung-``m`` lane to the pool."""
        lane = self._lane_of.pop((slot, m))
        self._free[m].append(lane)
        self._free[m].sort(reverse=True)   # keep ascending-pop order
        return lane

    def cancel(self, slot: int) -> None:
        """Drop the slot's waiters (request finished or aborted)."""
        self._wait = collections.deque(
            w for w in self._wait if w[0] != slot)

    # ------------------------------------------------------------------
    # catch-up token budgets (virtual-clock planning; engine steppers
    # plan through their own per-model ChunkPlanner built from the same
    # budgets)
    # ------------------------------------------------------------------

    def plan_catchup(self, m: int, lanes: dict) -> dict:
        """Budgeted catch-up widths for rung ``m`` this step —
        ``lanes``: slot -> (remaining, total) like `ChunkPlanner.plan`."""
        if not lanes:
            return {}
        return self.planners[m].plan(lanes)
