"""Synthetic open-loop traffic generators (DESIGN.md §7).

Three arrival processes cover the serving regimes the scheduler must
survive:

  * ``poisson``  — memoryless steady load (the queueing-theory default).
  * ``bursty``   — ON/OFF modulated Poisson: silence, then bursts at a
    multiple of the mean rate (tests lane recycling under backlog).
  * ``diurnal``  — a sin^2 ramp from zero up to the peak rate and back
    (tests admission under slowly drifting load).

Every generator is seeded and fully deterministic: the same
``(name, rate, duration, seed)`` produces byte-identical requests, and
each request's prompt / token budget derive from its own draw order, so
workloads replay exactly across runs and schedulers.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.runtime.request import Request

__all__ = ["WorkloadSpec", "make_workload", "available_workloads"]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Shared knobs for all generators."""

    rate: float                    # mean arrivals/sec (diurnal: peak)
    duration: float                # arrival window [0, duration)
    prompt_len: int = 32           # fixed prompt bucket (static shapes)
    vocab: int = 512
    max_tokens: tuple = (4, 32)    # inclusive uniform decode budget
    seed: int = 0
    lam: float | None = None       # stamped on every request
    strategy: str | None = None    # stamped on every request

    def __post_init__(self):
        if not self.rate > 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if not self.duration > 0:
            raise ValueError(f"duration must be > 0, got {self.duration}")
        lo, hi = self.max_tokens
        if not 1 <= lo <= hi:
            raise ValueError(f"bad max_tokens range {self.max_tokens}")


def _finish(arrivals: np.ndarray, spec: WorkloadSpec,
            rng: np.random.Generator) -> list[Request]:
    lo, hi = spec.max_tokens
    reqs = []
    for rid, t in enumerate(np.sort(arrivals)):
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, spec.vocab, size=(spec.prompt_len,),
                                dtype=np.int32),
            max_tokens=int(rng.integers(lo, hi + 1)),
            arrival=float(t),
            lam=spec.lam,
            strategy=spec.strategy,
        ))
    return reqs


def _poisson_arrivals(rate: float, t0: float, t1: float,
                      rng: np.random.Generator) -> list[float]:
    out, t = [], t0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= t1:
            return out
        out.append(t)


def poisson(spec: WorkloadSpec) -> list[Request]:
    """Homogeneous Poisson arrivals at ``spec.rate``."""
    rng = np.random.default_rng(spec.seed)
    arrivals = np.asarray(
        _poisson_arrivals(spec.rate, 0.0, spec.duration, rng))
    return _finish(arrivals, spec, rng)


def bursty(spec: WorkloadSpec, *, on: float = 1.0,
           off: float = 3.0) -> list[Request]:
    """ON/OFF traffic: Poisson bursts during ``on``-second windows
    separated by ``off`` seconds of silence; the ON rate is scaled so the
    long-run mean is still ``spec.rate``."""
    rng = np.random.default_rng(spec.seed)
    rate_on = spec.rate * (on + off) / on
    arrivals, t = [], 0.0
    while t < spec.duration:
        arrivals += _poisson_arrivals(rate_on, t,
                                      min(t + on, spec.duration), rng)
        t += on + off
    return _finish(np.asarray(arrivals), spec, rng)


def diurnal(spec: WorkloadSpec) -> list[Request]:
    """Inhomogeneous Poisson with rate(t) = peak * sin^2(pi t / T) —
    a zero→peak→zero ramp over the window (thinning construction)."""
    rng = np.random.default_rng(spec.seed)
    cand = np.asarray(
        _poisson_arrivals(spec.rate, 0.0, spec.duration, rng))
    accept = rng.random(cand.shape) \
        < np.sin(np.pi * cand / spec.duration) ** 2
    return _finish(cand[accept], spec, rng)


_WORKLOADS = {"poisson": poisson, "bursty": bursty, "diurnal": diurnal}


def available_workloads() -> tuple:
    return tuple(sorted(_WORKLOADS))


def make_workload(name: str, spec: WorkloadSpec, **kwargs) -> list[Request]:
    """Build the named arrival process from a `WorkloadSpec`."""
    try:
        gen = _WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; available: "
                       f"{', '.join(available_workloads())}") from None
    return gen(spec, **kwargs)
