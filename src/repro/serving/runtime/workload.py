"""Synthetic open-loop traffic generators (DESIGN.md §7).

Three arrival processes cover the serving regimes the scheduler must
survive:

  * ``poisson``  — memoryless steady load (the queueing-theory default).
  * ``bursty``   — ON/OFF modulated Poisson: silence, then bursts at a
    multiple of the mean rate (tests lane recycling under backlog).
  * ``diurnal``  — a sin^2 ramp from zero up to the peak rate and back
    (tests admission under slowly drifting load).

Every generator is seeded and fully deterministic: the same
``(name, rate, duration, seed)`` produces byte-identical requests, and
each request's prompt / token budget derive from its own draw order, so
workloads replay exactly across runs and schedulers.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serving.runtime.request import Request

__all__ = ["WorkloadSpec", "make_workload", "available_workloads",
           "inflection_times"]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Shared knobs for all generators."""

    rate: float                    # mean arrivals/sec (diurnal: peak)
    duration: float                # arrival window [0, duration)
    prompt_len: int = 32           # fixed prompt bucket (static shapes)
    vocab: int = 512
    max_tokens: tuple = (4, 32)    # inclusive uniform decode budget
    seed: int = 0
    lam: float | None = None       # stamped on every request
    strategy: str | None = None    # stamped on every request

    def __post_init__(self):
        if not self.rate > 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if not self.duration > 0:
            raise ValueError(f"duration must be > 0, got {self.duration}")
        lo, hi = self.max_tokens
        if not 1 <= lo <= hi:
            raise ValueError(f"bad max_tokens range {self.max_tokens}")


def _finish(arrivals: np.ndarray, spec: WorkloadSpec,
            rng: np.random.Generator) -> list[Request]:
    lo, hi = spec.max_tokens
    reqs = []
    for rid, t in enumerate(np.sort(arrivals)):
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(0, spec.vocab, size=(spec.prompt_len,),
                                dtype=np.int32),
            max_tokens=int(rng.integers(lo, hi + 1)),
            arrival=float(t),
            lam=spec.lam,
            strategy=spec.strategy,
        ))
    return reqs


def _poisson_arrivals(rate: float, t0: float, t1: float,
                      rng: np.random.Generator) -> list[float]:
    out, t = [], t0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= t1:
            return out
        out.append(t)


def poisson(spec: WorkloadSpec) -> list[Request]:
    """Homogeneous Poisson arrivals at ``spec.rate``."""
    rng = np.random.default_rng(spec.seed)
    arrivals = np.asarray(
        _poisson_arrivals(spec.rate, 0.0, spec.duration, rng))
    return _finish(arrivals, spec, rng)


def bursty(spec: WorkloadSpec, *, on: float = 1.0,
           off: float = 3.0) -> list[Request]:
    """ON/OFF traffic: Poisson bursts during ``on``-second windows
    separated by ``off`` seconds of silence; the ON rate is scaled so the
    long-run mean is still ``spec.rate``."""
    rng = np.random.default_rng(spec.seed)
    rate_on = spec.rate * (on + off) / on
    arrivals, t = [], 0.0
    while t < spec.duration:
        arrivals += _poisson_arrivals(rate_on, t,
                                      min(t + on, spec.duration), rng)
        t += on + off
    return _finish(np.asarray(arrivals), spec, rng)


def diurnal(spec: WorkloadSpec, *, period: float | None = None,
            phase: float = 0.0, amplitude: float = 1.0) -> list[Request]:
    """Inhomogeneous Poisson with
    ``rate(t) = peak * amplitude * sin^2(pi (t - phase) / period)``
    (thinning construction).  The defaults — one period spanning the
    window, zero phase, full amplitude — reproduce the classic
    zero→peak→zero ramp bit-for-bit; shorter periods stack several
    day/night cycles into one serve, which is what the adaptive-control
    tests ride.
    """
    if period is None:
        period = spec.duration
    if not period > 0:
        raise ValueError(f"period must be > 0, got {period}")
    if not 0.0 < amplitude <= 1.0:
        raise ValueError(f"amplitude must be in (0, 1], got {amplitude}")
    rng = np.random.default_rng(spec.seed)
    cand = np.asarray(
        _poisson_arrivals(spec.rate, 0.0, spec.duration, rng))
    accept = rng.random(cand.shape) < amplitude * \
        np.sin(np.pi * (cand - phase) / period) ** 2
    return _finish(cand[accept], spec, rng)


def inflection_times(spec: WorkloadSpec, *, period: float | None = None,
                     phase: float = 0.0, amplitude: float = 1.0,
                     threshold: float = 0.5) -> list[tuple[float, str]]:
    """Analytic crossings of the diurnal rate curve with
    ``threshold * spec.rate`` inside ``[0, duration)``.

    Returns ``[(t, "rising" | "falling"), ...]`` sorted by time — the
    exact instants a load-indexed controller with that gear threshold
    SHOULD switch, so tests can assert observed gear switches land at
    known traffic inflections.  With ``threshold = 0.5 * amplitude``'s
    midpoint the crossing sits where ``|d rate/dt|`` is maximal (the
    sin^2 curve is steepest at half its peak), which is the "steepest
    traffic inflection" the adaptive smoke gate measures at.  An empty
    list means the curve never reaches the threshold.
    """
    if period is None:
        period = spec.duration
    peak = spec.rate * amplitude
    if not 0.0 < threshold:
        raise ValueError(f"threshold must be > 0, got {threshold}")
    level = threshold * spec.rate / peak   # sin^2 value at the crossing
    if level >= 1.0:
        return []
    a = float(np.arcsin(np.sqrt(level)))   # in [0, pi/2)
    out = []
    # sin^2(u) crosses `level` rising at u = k*pi + a and falling at
    # u = k*pi + (pi - a); map u back through t = phase + period * u / pi
    k = int(np.floor(-phase / period)) - 1
    while True:
        base = phase + k * period
        if base >= spec.duration:
            break
        rising = base + period * a / np.pi
        falling = base + period * (np.pi - a) / np.pi
        for t, kind in ((rising, "rising"), (falling, "falling")):
            if 0.0 <= t < spec.duration:
                out.append((float(t), kind))
        k += 1
    return sorted(out)


_WORKLOADS = {"poisson": poisson, "bursty": bursty, "diurnal": diurnal}


def available_workloads() -> tuple:
    return tuple(sorted(_WORKLOADS))


def make_workload(name: str, spec: WorkloadSpec, **kwargs) -> list[Request]:
    """Build the named arrival process from a `WorkloadSpec`."""
    try:
        gen = _WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; available: "
                       f"{', '.join(available_workloads())}") from None
    return gen(spec, **kwargs)
