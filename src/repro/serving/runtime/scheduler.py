"""Lane scheduling: fixed-width slots, immediate recycling, static
shapes (DESIGN.md §7; paged KV §8; chunked prefill §9).

Three layers:

  * `LaneScheduler` — the pure allocator.  `n_lanes` slots; a lane is
    recycled the moment its request finishes (or its stream hits EOS);
    admission pops the `RequestQueue` into free lanes, optionally gated
    by a ``can_admit`` callback (the paged-KV stepper's page-budget
    reservation: when the pool can't cover a request's worst case, the
    request STAYS QUEUED — head-of-line, deterministic — instead of
    being dropped).  All bookkeeping is host-side numpy, so the device
    batch keeps one static shape and occupancy is just a mask.

  * `EngineStepper` — the device-state surgery for the REAL model.  It
    owns the batched decode caches / current tokens / positions / the
    carried strategy-bank states, admits one request by prefilling it
    at batch 1 and pytree-scattering the results into the lane slot, and
    steps all lanes through the shared `serving.engine.make_token_step`
    program (carry_state mode).  ``kv="paged"`` swaps the per-lane ring
    caches for the `serving.kvpool` page pool: admission scatters the
    prefill KV into allocated pages (shared-prefix tokens skip straight
    to the sink — their pages already hold the bytes), each token step
    first executes the pool's host-planned page ops (fresh-page position
    resets, copy-on-write splits) and then decodes against per-lane page
    tables.  A recycled lane's strategy state is sliced back to
    fresh-init at admission via `strategy.init_lane`; per-token
    strategies are additionally re-sliced at every token boundary inside
    the step, while ``persistent = True`` strategies carry state across
    a request's tokens and rely on the admission reset alone — either
    way, state from a previous occupant can never leak into the next
    request.  ``prefill_chunk=N`` replaces the batch-1 admission
    prefill with CHUNKED prefill co-scheduled with decode (§9): admit
    only allocates pages and registers a cursor; each `step` then runs
    decode AND a planner-budgeted prefill chunk in one fused program.

  * `ChunkPlanner` — the per-step token budget for those chunks, split
    fairly across prompt-length buckets (long prompts can't starve
    short ones); shared with the sim stepper so sweeps exercise the
    served discipline.

Per-lane masked cache writes inside the token step make each lane's
output stream a function of its own request only, so the scheduler's
admission order cannot change what any request generates
(tests/serving/test_runtime.py pins this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.attention import PagedKV, PrefillChunk
from repro.serving.engine import make_token_step
from repro.serving.runtime.request import Request, RequestQueue
from repro.strategy.base import init_lane

__all__ = ["LaneScheduler", "ChunkPlanner", "EngineStepper"]


class LaneScheduler:
    """Fixed-width lane allocator with immediate recycling."""

    def __init__(self, n_lanes: int):
        if n_lanes < 1:
            raise ValueError("need at least one lane")
        self.n_lanes = int(n_lanes)
        self.lane_req: list[Request | None] = [None] * self.n_lanes
        self.remaining = np.zeros(self.n_lanes, np.int64)
        self.sid = np.zeros(self.n_lanes, np.int32)

    def occupied_mask(self) -> np.ndarray:
        return np.asarray([r is not None for r in self.lane_req])

    def busy(self) -> bool:
        return any(r is not None for r in self.lane_req)

    def free_lanes(self) -> list[int]:
        return [i for i, r in enumerate(self.lane_req) if r is None]

    def admit(self, queue: RequestQueue, sid_of, *,
              static_batching: bool = False,
              can_admit=None) -> list[tuple[int, Request]]:
        """Pop queued requests into free lanes; returns assignments.

        ``static_batching=True`` reproduces the fixed-batch
        `Engine.generate` discipline (the bench baseline): a new batch
        is admitted only once EVERY lane is free, so stragglers idle the
        whole width.

        ``can_admit(req)`` gates (and RESERVES resources for) each pop —
        the paged-KV page budget.  A False verdict stops admission at
        the queue head: the request waits, later arrivals wait behind it
        (deterministic head-of-line order; no starvation, no drops).
        """
        if static_batching and self.busy():
            return []
        out = []
        for lane in self.free_lanes():
            if not len(queue):
                break
            if can_admit is not None and not can_admit(queue.peek()):
                break
            req = queue.pop()
            self.lane_req[lane] = req
            self.remaining[lane] = req.max_tokens
            self.sid[lane] = sid_of(req)
            out.append((lane, req))
        return out

    def consume_token(self, lane: int) -> bool:
        """Account one emitted token; True when the budget is exhausted."""
        self.remaining[lane] -= 1
        return bool(self.remaining[lane] <= 0)

    def release(self, lane: int) -> Request:
        req = self.lane_req[lane]
        if req is None:
            raise ValueError(f"lane {lane} is already free")
        self.lane_req[lane] = None
        self.remaining[lane] = 0
        self.sid[lane] = 0
        return req


class ChunkPlanner:
    """Per-step prefill-chunk planning under a token budget with
    prompt-length-bucketed fairness (DESIGN.md §9).

    Each step, at most ``budget`` prompt tokens are spread over the
    lanes currently mid-prefill, every lane capped at ``chunk`` tokens
    (the device chunk width).  Lanes are grouped into power-of-two
    prompt-length BUCKETS (in units of ``chunk``) and the budget is
    split evenly across the nonempty buckets — a lane prefilling a
    4096-token prompt can take at most its bucket's share, so freshly
    admitted short prompts always find budget and reach their first
    token in O(1) steps instead of queueing behind the long prefill
    (and vice versa: the long prompt keeps its share no matter how many
    shorts arrive, so neither side starves).  Within a bucket a
    rotating round-robin pointer decides who goes first; the
    budget-split remainder rotates across buckets.  Unused share flows
    to the next bucket, then tops up any lane still under its cap —
    the budget is never wasted while work remains.

    Used by both the real `EngineStepper` and the virtual-clock
    `SimStepper`, so the sim sweeps exercise the exact admission
    discipline the engine serves with.
    """

    def __init__(self, chunk: int, budget: int | None = None):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        self.chunk = int(chunk)
        self.budget = int(budget) if budget is not None else self.chunk
        if self.budget < 1:
            raise ValueError("budget must be >= 1")
        self._rr = 0

    def bucket(self, prompt_len: int) -> int:
        """Power-of-two bucket index: 0 for prompts up to one chunk,
        then doubling (chunk, 2*chunk] -> 1, (2c, 4c] -> 2, ..."""
        return max(0, -(-int(prompt_len) // self.chunk) - 1).bit_length()

    def plan(self, lanes: dict) -> dict:
        """``lanes``: lane -> (remaining_tokens, prompt_len).  Returns
        lane -> tokens to prefill this step (each in [1, chunk], total
        <= budget)."""
        if not lanes:
            return {}
        buckets: dict[int, list[int]] = {}
        for lane in sorted(lanes):
            buckets.setdefault(self.bucket(lanes[lane][1]), []).append(lane)
        keys = sorted(buckets)
        base, rem = divmod(self.budget, len(keys))
        rem_at = self._rr % len(keys)

        def rotated(seq):
            off = self._rr % len(seq)
            return seq[off:] + seq[:off]

        out: dict[int, int] = {}
        leftover = 0
        for i, bk in enumerate(keys):
            share = base + (rem if i == rem_at else 0) + leftover
            for lane in rotated(buckets[bk]):
                w = min(self.chunk, lanes[lane][0], share)
                if w > 0:
                    out[lane] = w
                    share -= w
            leftover = share
        if leftover > 0:       # top-up pass: no budget left stranded
            for lane in rotated(sorted(lanes)):
                got = out.get(lane, 0)
                add = min(self.chunk - got, lanes[lane][0] - got, leftover)
                if add > 0:
                    out[lane] = got + add
                    leftover -= add
                if leftover == 0:
                    break
        self._rr += 1
        return out


def _materialize_cache(spec, key=None):
    """Zero-filled decode cache from a `models.model.cache_specs` tree
    (attention ``pos`` buffers start at -1 == empty slot)."""
    if isinstance(spec, dict):
        return {k: _materialize_cache(v, k) for k, v in spec.items()}
    shape, dtype = spec
    if key == "pos":
        return jnp.full(shape, -1, dtype)
    return jnp.zeros(shape, dtype)


class EngineStepper:
    """Real-model lane state: batched caches + the shared token step."""

    virtual_time = False
    emits_tokens = True    # `emitted` really is token ids (EOS applies)
    # observability plane (DESIGN.md §12): installed by the server when
    # tracing is on; every producer guards on `is not None`
    tracer = None

    def __init__(self, params, cfg, strategies: tuple, *, n_lanes: int,
                 cache_len: int, prompt_len: int, jit: bool = True,
                 kv: str = "ring", page_size: int = 16,
                 n_pages: int | None = None, paged_kernel: bool = False,
                 prefill_chunk: int | None = None,
                 prefill_budget: int | None = None,
                 node_offset: int = 0, walk_io: bool = False,
                 resume_walk: bool = False,
                 max_lane_pages: int | None = None,
                 model_key: str | None = None):
        if kv not in ("ring", "paged"):
            raise ValueError(f"unknown kv mode {kv!r} (ring|paged)")
        prefill_chunk = prefill_chunk or None      # 0 == disabled
        if prefill_chunk is not None:
            if kv != "paged":
                raise ValueError("chunked prefill needs --kv paged "
                                 "(chunks commit into the page pool)")
            for seg in cfg.segments:
                if seg.block.mixer != "attn" \
                        or seg.block.attn.mla is not None:
                    raise ValueError(
                        "chunked prefill currently supports GQA "
                        "attention segments only (SSM state is "
                        "sequential over the prompt; MLA chunking is a "
                        "ROADMAP item) — drop --prefill-chunk for "
                        f"mixer {seg.block.mixer!r}")
        self.params = params
        self.cfg = cfg
        self.strategies = strategies
        self.n_lanes = int(n_lanes)
        self.cache_len = int(cache_len)
        self.prompt_len = int(prompt_len)
        self.full_depth = len(cfg.segments)
        self.kv = kv
        self.prefill_chunk = None if prefill_chunk is None \
            else int(prefill_chunk)
        self.planner = None if prefill_chunk is None else ChunkPlanner(
            self.prefill_chunk, prefill_budget)
        self.walk_io = bool(walk_io)
        self._step = make_token_step(params, cfg, strategies, jit=jit,
                                     donate=False, carry_state=True,
                                     paged=(kv == "paged"),
                                     paged_kernel=paged_kernel,
                                     prefill_slots=self.prefill_chunk or 0,
                                     node_offset=node_offset,
                                     walk_io=walk_io,
                                     resume_walk=resume_walk)
        if kv == "paged":
            from repro.serving.kvpool import KVPool
            lane_pages = -(-self.cache_len // page_size)
            self.pool = KVPool(n_lanes=self.n_lanes, page_size=page_size,
                               lane_pages=lane_pages, n_pages=n_pages,
                               max_lane_pages=max_lane_pages,
                               model_key=model_key)
            admit_fn = self._make_paged_admit()
            self._prep = jax.jit(self._paged_prep) if jit \
                else self._paged_prep
            self._reset = jax.jit(self._reset_pages) if jit \
                else self._reset_pages
        else:
            self.pool = None

            def admit_fn(caches, tok, pos, prompt, lane):
                logits, pc, _, npos = M.prefill(params, cfg,
                                                {"tokens": prompt},
                                                cache_len)
                t0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]

                def scatter(full, one):
                    return full.at[:, lane].set(one[:, 0].astype(full.dtype))

                caches = jax.tree.map(scatter, caches, pc)
                return (caches, tok.at[lane].set(t0),
                        pos.at[lane].set(npos[0].astype(jnp.int32)))

        self._admit = jax.jit(admit_fn) if jit else admit_fn
        self.alloc()

    # ---- paged device programs ----------------------------------------

    def _make_paged_admit(self):
        params, cfg, prompt_len = self.params, self.cfg, self.prompt_len

        def admit_fn(caches, tok, pos, prompt, lane, dest_page, dest_slot,
                     pos_vals, new_pages):
            # prefill at cache_len == prompt_len: the ring layout is the
            # identity (slot t <- position t), so the per-token page
            # scatter below reads positions straight through
            logits, pc, _, npos = M.prefill(params, cfg,
                                            {"tokens": prompt}, prompt_len)
            t0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
            out = []
            for si in range(len(cfg.segments)):
                seg_c = dict(caches[si])
                if "attn" in seg_c:
                    attn = dict(seg_c["attn"])
                    # gate stale bytes of freshly allocated pages
                    # (garbage-page padding makes this idempotent)
                    attn["pos"] = attn["pos"].at[:, new_pages].set(-1)
                    for name, pool_leaf in attn.items():
                        if name == "pos":
                            attn["pos"] = attn["pos"].at[
                                :, dest_page, dest_slot].set(pos_vals)
                        else:
                            attn[name] = pool_leaf.at[
                                :, dest_page, dest_slot].set(
                                    pc[si]["attn"][name][:, 0].astype(
                                        pool_leaf.dtype))
                    seg_c["attn"] = attn
                if "ssm" in seg_c:
                    seg_c["ssm"] = jax.tree.map(
                        lambda full, one: full.at[:, lane].set(
                            one[:, 0].astype(full.dtype)),
                        seg_c["ssm"], pc[si]["ssm"])
                out.append(seg_c)
            return (out, tok.at[lane].set(t0),
                    pos.at[lane].set(npos[0].astype(jnp.int32)))

        return admit_fn

    @staticmethod
    def _reset_pages(caches, pages):
        """Gate the stale bytes of freshly allocated pages before a
        chunked admission starts writing into them: pos[:, pages] = -1
        across every attention layer.  ``pages`` is garbage-padded
        (the sink's positions are -1 by construction, so re-resetting
        it is a no-op)."""
        out = []
        for seg_c in caches:
            seg_c = dict(seg_c)
            if "attn" in seg_c:
                attn = dict(seg_c["attn"])
                attn["pos"] = attn["pos"].at[:, pages].set(-1)
                seg_c["attn"] = attn
            out.append(seg_c)
        return out

    @staticmethod
    def _paged_prep(caches, fresh, cow_src, cow_dst):
        """Pre-step page ops: COW page copies (src -> dst across every
        attention layer — page ids are global) and fresh-page position
        resets.  Idle entries are garbage-page pairs (0 -> 0), which
        copy the sink onto itself."""
        out = []
        for seg_c in caches:
            seg_c = dict(seg_c)
            if "attn" in seg_c:
                attn = {name: leaf.at[:, cow_dst].set(leaf[:, cow_src])
                        for name, leaf in seg_c["attn"].items()}
                attn["pos"] = attn["pos"].at[:, fresh].set(-1)
                seg_c["attn"] = attn
            out.append(seg_c)
        return out

    # ---- lane state ----------------------------------------------------

    def alloc(self) -> None:
        """(Re)build empty lane state: zero caches, fresh bank states."""
        if self.pool is not None:
            self.pool.reset()
            specs = M.paged_cache_specs(self.cfg, self.n_lanes,
                                        self.pool.n_pages,
                                        self.pool.page_size)
        else:
            specs = M.cache_specs(self.cfg, self.n_lanes, self.cache_len)
        self.caches = [_materialize_cache(s) for s in specs]
        self.tok = jnp.zeros((self.n_lanes,), jnp.int32)
        self.pos = jnp.zeros((self.n_lanes,), jnp.int32)
        self.states = tuple(s.init(self.n_lanes) for s in self.strategies)
        # chunked-prefill lane state: lane -> {prompt, plan, cursor, lp}
        self._prefilling = {}
        self._idle_chunk = None
        self.chunk_stats = {"tokens_computed": 0, "tokens_skipped": 0,
                            "chunk_steps": 0, "prefills": 0}

    def reserve(self, req: Request) -> bool:
        """Admission gate (the scheduler's ``can_admit``): reserve the
        request's worst-case page need.  Ring mode has nothing to
        reserve — lane availability is the only constraint."""
        if self.pool is None:
            return True
        return self.pool.reserve(req.prompt, req.max_tokens)

    def release(self, lane: int) -> None:
        """Return the lane's pages to the pool (prefix-cache refs keep
        shared prompt pages warm).  Ring lanes have nothing to return.
        A lane reaped mid-chunked-prefill (fault plane) also drops its
        prefill cursor — otherwise the freed lane would keep receiving
        chunk plans."""
        self._prefilling.pop(lane, None)
        if self.pool is not None:
            self.pool.release(lane)

    def admit(self, lane: int, req: Request) -> None:
        """Admit the request into ``lane``.

        Stop-the-world mode: prefill at batch 1 and scatter the result
        into the lane slot (stalls every decode lane for the whole
        prompt).  Chunked mode (``prefill_chunk``): allocate the
        prompt's pages NOW, but defer the compute — the prompt is fed
        through the fused token step ``prefill_chunk`` tokens at a
        time, co-scheduled with decode, and prefix-cache hits skip
        their already-cached chunks entirely.  Chunked admission also
        lifts the fixed prompt bucket: any prompt that fits the lane's
        page capacity is admissible (chunks are the static shape, not
        the prompt)."""
        if self.prefill_chunk is not None:
            plan = self.pool.admit(lane, req.prompt, req.max_tokens,
                                   register_prefix=False)
            self.caches = self._reset(self.caches,
                                      jnp.asarray(plan.new_pages))
            lp = int(req.prompt.shape[0])
            # full prefix hit still recomputes the final token: the
            # first-token logits need the last position's hidden state
            cursor = min(plan.n_shared_tokens, lp - 1)
            self.chunk_stats["tokens_skipped"] += cursor
            self.chunk_stats["prefills"] += 1
            self._prefilling[lane] = {
                "prompt": np.asarray(req.prompt, np.int32),
                "plan": plan, "cursor": cursor, "lp": lp,
                "rid": req.rid}
            self.states = tuple(
                init_lane(s, st, lane)
                for s, st in zip(self.strategies, self.states))
            return
        if req.prompt.shape[0] != self.prompt_len:
            raise ValueError(
                f"request {req.rid}: prompt length {req.prompt.shape[0]} "
                f"!= stepper bucket {self.prompt_len} (static shapes)")
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        if self.pool is not None:
            plan = self.pool.admit(lane, req.prompt, req.max_tokens)
            self.caches, self.tok, self.pos = self._admit(
                self.caches, self.tok, self.pos, prompt, jnp.int32(lane),
                jnp.asarray(plan.dest_page), jnp.asarray(plan.dest_slot),
                jnp.asarray(plan.pos_vals), jnp.asarray(plan.new_pages))
        else:
            self.caches, self.tok, self.pos = self._admit(
                self.caches, self.tok, self.pos, prompt,
                jnp.int32(lane))
        # pytree-sliced per-lane reset: the recycled lane starts from
        # fresh strategy state no matter what its predecessor observed
        self.states = tuple(init_lane(s, st, lane)
                            for s, st in zip(self.strategies, self.states))

    def set_lane_token(self, lane: int, token: int) -> None:
        """Override a lane's next input token — the cascade router uses
        this after an escalation catch-up prefill: the finishing chunk
        seeds its own head argmax, but the escalated stream's next input
        is the token the SOURCE model already emitted."""
        self.tok = self.tok.at[lane].set(jnp.int32(token))

    def warmup(self) -> None:
        """Compile the admit + prep + step programs off the serving
        clock."""
        dummy = Request(rid=-1, prompt=np.zeros(self.prompt_len, np.int32),
                        max_tokens=1)
        if not self.reserve(dummy):
            from repro.serving.kvpool import PoolExhausted
            raise PoolExhausted(
                f"kv pool of {self.pool.n_pages} pages x "
                f"{self.pool.page_size} tokens cannot fit even one "
                f"{self.prompt_len}-token request — raise --pages or "
                "--page-size")
        self.admit(0, dummy)
        occ = np.zeros((self.n_lanes,), bool)
        occ[0] = True
        if self.pool is not None:
            # compile the page-ops program too (an all-garbage plan is a
            # no-op: it copies the sink onto itself)
            idle = jnp.zeros((self.n_lanes,), jnp.int32)
            self.caches = self._prep(self.caches, idle, idle, idle)
        sid0 = np.zeros((self.n_lanes,), np.int32)
        # chunked mode: drive the dummy's whole prefill through the
        # fused step (compiles the chunk-active branch), then decode
        # once (compiles the chunk-idle + decode branch)
        for _ in range(2 * self.prompt_len + 2):
            if not self._prefilling:
                break
            self.step(occ, sid0)
        self.step(occ, sid0)
        self.alloc()

    def _build_chunk(self, widths: dict):
        """Turn the planner's lane -> width map into the device
        `PrefillChunk` (all-idle when nothing is prefilling: position
        -1 rows, garbage destinations — the step's lax.cond skips the
        sweep).  Advances the per-lane cursors and returns the lanes
        whose prompt finishes with this chunk."""
        n, c = self.n_lanes, self.prefill_chunk
        if not widths:
            if self._idle_chunk is None:
                zi = jnp.zeros((n, c), jnp.int32)
                zb = jnp.zeros((n,), bool)
                z1 = jnp.zeros((n,), jnp.int32)
                self._idle_chunk = PrefillChunk(
                    tok=zi, pos=jnp.full((n, c), -1, jnp.int32),
                    dest_page=zi, dest_slot=zi, start=z1, last_idx=z1,
                    emit=zb, active=zb)
            return self._idle_chunk, []
        tok = np.zeros((n, c), np.int32)
        pos = np.full((n, c), -1, np.int32)
        dp = np.zeros((n, c), np.int32)     # 0 == the garbage sink
        ds = np.zeros((n, c), np.int32)
        start = np.zeros(n, np.int32)
        last = np.zeros(n, np.int32)
        emit = np.zeros(n, bool)
        act = np.zeros(n, bool)
        finished = []
        for lane, w in widths.items():
            st = self._prefilling[lane]
            cur = st["cursor"]
            sl = slice(cur, cur + w)
            tok[lane, :w] = st["prompt"][sl]
            pos[lane, :w] = np.arange(cur, cur + w, dtype=np.int32)
            dp[lane, :w] = st["plan"].dest_page[sl]
            ds[lane, :w] = st["plan"].dest_slot[sl]
            start[lane] = cur
            last[lane] = w - 1
            act[lane] = True
            st["cursor"] = cur + w
            if st["cursor"] == st["lp"]:
                emit[lane] = True
                finished.append(lane)
            self.chunk_stats["tokens_computed"] += w
            if self.tracer is not None:
                self.tracer.emit(
                    "prefill_chunk", lane=int(lane),
                    rid=int(st.get("rid", -1)), width=int(w),
                    left=int(st["lp"] - st["cursor"]))
        self.chunk_stats["chunk_steps"] += 1
        chunk = PrefillChunk(
            tok=jnp.asarray(tok), pos=jnp.asarray(pos),
            dest_page=jnp.asarray(dp), dest_slot=jnp.asarray(ds),
            start=jnp.asarray(start), last_idx=jnp.asarray(last),
            emit=jnp.asarray(emit), active=jnp.asarray(act))
        return chunk, finished

    def step(self, occupied: np.ndarray, sid: np.ndarray, walk=None):
        """One fused step: a decode token for every occupied DECODING
        lane and — in chunked mode — a budgeted prefill chunk for the
        admitting lanes, in one device program.

        Returns host-side ``(emitted (B,), served (B,), seg_batch,
        seg_policy, emit_mask (B,) bool)`` — a single device sync for
        the whole step.  ``emit_mask`` marks the lanes whose ``emitted``
        entry is a real token (lanes mid-prefill emit nothing).

        ``walk_io`` steppers (the cascade's per-model rungs) also take
        an optional ``walk`` handoff pair ``(active (B,) bool,
        best_logits (B, vocab) f32)`` — omitted, every occupied lane
        starts a fresh walk — and return an extra trailing element
        ``(walk_active (B,) bool host, best_logits device)``: the
        escalation handoff the cascade router stashes for the next
        ladder model.
        """
        occ_np = np.asarray(occupied, bool)
        decode = occ_np.copy()
        widths: dict = {}
        if self.prefill_chunk is not None and self._prefilling:
            for lane in self._prefilling:
                decode[lane] = False
            widths = self.planner.plan({
                lane: (st["lp"] - st["cursor"], st["lp"])
                for lane, st in self._prefilling.items()})
        occ = jnp.asarray(decode, bool)
        sid_d = jnp.asarray(sid, jnp.int32)
        if self.walk_io and walk is None:
            walk = (jnp.ones((self.n_lanes,), bool),
                    jnp.zeros((self.n_lanes, self.cfg.vocab), jnp.float32))
        finished: list = []
        if self.pool is not None:
            plan = self.pool.prepare_step(decode)
            if plan.fresh.any() or plan.cow_dst.any():
                # page ops only when the plan has any (steady-state
                # mid-page decode skips the dispatch + pool rewrite)
                self.caches = self._prep(self.caches,
                                         jnp.asarray(plan.fresh),
                                         jnp.asarray(plan.cow_src),
                                         jnp.asarray(plan.cow_dst))
            kv = PagedKV(page_table=jnp.asarray(self.pool.table),
                         write_page=jnp.asarray(plan.write_page),
                         write_slot=jnp.asarray(plan.write_slot))
            args = (self.tok, self.caches, self.pos, occ, sid_d, kv,
                    self.states)
            if self.prefill_chunk is not None:
                chunk, finished = self._build_chunk(widths)
                args = args + (chunk,)
            elif self.walk_io:
                args = args + (None,)
            if self.walk_io:
                args = args + (walk,)
            out = self._step(*args)
            self.pool.note_written(decode)
        else:
            args = (self.tok, self.caches, self.pos, occ, sid_d, None,
                    self.states)
            if self.walk_io:
                args = args + (None, walk)
            out = self._step(*args)
        if self.walk_io:
            tok, self.caches, served, sb, sp, self.states, walk_out = out
        else:
            tok, self.caches, served, sb, sp, self.states = out
        self.tok = tok
        self.pos = self.pos + occ.astype(jnp.int32)
        if finished:
            # the final chunk seeded tok[lane] with the first token
            # (inside the fused step); point the lane past its prompt
            # and make its pages shareable now that every byte exists
            lanes = jnp.asarray(finished, jnp.int32)
            lps = jnp.asarray(
                [self._prefilling[ln]["lp"] for ln in finished], jnp.int32)
            self.pos = self.pos.at[lanes].set(lps)
            for lane in finished:
                st = self._prefilling.pop(lane)
                self.pool.commit_prefix(lane, st["prompt"])
        if self.walk_io:
            tok_h, served_h, sb_h, sp_h, wa_h = jax.device_get(
                (tok, served, sb, sp, walk_out[0]))
            return (tok_h, served_h, int(sb_h), int(sp_h), decode,
                    (wa_h, walk_out[1]))
        tok_h, served_h, sb_h, sp_h = jax.device_get((tok, served, sb, sp))
        return tok_h, served_h, int(sb_h), int(sp_h), decode
