"""Lane scheduling: fixed-width slots, immediate recycling, static
shapes (DESIGN.md §7).

Two layers:

  * `LaneScheduler` — the pure allocator.  `n_lanes` slots; a lane is
    recycled the moment its request finishes (or its stream hits EOS);
    admission pops the `RequestQueue` into free lanes.  All bookkeeping
    is host-side numpy, so the device batch keeps one static shape and
    occupancy is just a mask.

  * `EngineStepper` — the device-state surgery for the REAL model.  It
    owns the batched decode caches / current tokens / positions / the
    carried strategy-bank states, admits one request by prefilling it
    at batch 1 and pytree-scattering the results into the lane slot, and
    steps all lanes through the shared `serving.engine.make_token_step`
    program (carry_state mode).  A recycled lane's strategy state is
    sliced back to fresh-init at admission via `strategy.init_lane`;
    per-token strategies are additionally re-sliced at every token
    boundary inside the step, while ``persistent = True`` strategies
    carry state across a request's tokens and rely on the admission
    reset alone — either way, state from a previous occupant can never
    leak into the next request.

Per-lane masked cache writes inside the token step make each lane's
output stream a function of its own request only, so the scheduler's
admission order cannot change what any request generates
(tests/serving/test_runtime.py pins this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serving.engine import make_token_step
from repro.serving.runtime.request import Request, RequestQueue
from repro.strategy.base import init_lane

__all__ = ["LaneScheduler", "EngineStepper"]


class LaneScheduler:
    """Fixed-width lane allocator with immediate recycling."""

    def __init__(self, n_lanes: int):
        if n_lanes < 1:
            raise ValueError("need at least one lane")
        self.n_lanes = int(n_lanes)
        self.lane_req: list[Request | None] = [None] * self.n_lanes
        self.remaining = np.zeros(self.n_lanes, np.int64)
        self.sid = np.zeros(self.n_lanes, np.int32)

    def occupied_mask(self) -> np.ndarray:
        return np.asarray([r is not None for r in self.lane_req])

    def busy(self) -> bool:
        return any(r is not None for r in self.lane_req)

    def free_lanes(self) -> list[int]:
        return [i for i, r in enumerate(self.lane_req) if r is None]

    def admit(self, queue: RequestQueue, sid_of, *,
              static_batching: bool = False) -> list[tuple[int, Request]]:
        """Pop queued requests into free lanes; returns assignments.

        ``static_batching=True`` reproduces the fixed-batch
        `Engine.generate` discipline (the bench baseline): a new batch
        is admitted only once EVERY lane is free, so stragglers idle the
        whole width.
        """
        if static_batching and self.busy():
            return []
        out = []
        for lane in self.free_lanes():
            if not len(queue):
                break
            req = queue.pop()
            self.lane_req[lane] = req
            self.remaining[lane] = req.max_tokens
            self.sid[lane] = sid_of(req)
            out.append((lane, req))
        return out

    def consume_token(self, lane: int) -> bool:
        """Account one emitted token; True when the budget is exhausted."""
        self.remaining[lane] -= 1
        return bool(self.remaining[lane] <= 0)

    def release(self, lane: int) -> Request:
        req = self.lane_req[lane]
        if req is None:
            raise ValueError(f"lane {lane} is already free")
        self.lane_req[lane] = None
        self.remaining[lane] = 0
        self.sid[lane] = 0
        return req


def _materialize_cache(spec, key=None):
    """Zero-filled decode cache from a `models.model.cache_specs` tree
    (attention ``pos`` buffers start at -1 == empty slot)."""
    if isinstance(spec, dict):
        return {k: _materialize_cache(v, k) for k, v in spec.items()}
    shape, dtype = spec
    if key == "pos":
        return jnp.full(shape, -1, dtype)
    return jnp.zeros(shape, dtype)


class EngineStepper:
    """Real-model lane state: batched caches + the shared token step."""

    virtual_time = False
    emits_tokens = True    # `emitted` really is token ids (EOS applies)

    def __init__(self, params, cfg, strategies: tuple, *, n_lanes: int,
                 cache_len: int, prompt_len: int, jit: bool = True):
        self.params = params
        self.cfg = cfg
        self.strategies = strategies
        self.n_lanes = int(n_lanes)
        self.cache_len = int(cache_len)
        self.prompt_len = int(prompt_len)
        self.full_depth = len(cfg.segments)
        self._step = make_token_step(params, cfg, strategies, jit=jit,
                                     donate=False, carry_state=True)

        def admit_fn(caches, tok, pos, prompt, lane):
            logits, pc, _, npos = M.prefill(params, cfg,
                                            {"tokens": prompt}, cache_len)
            t0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]

            def scatter(full, one):
                return full.at[:, lane].set(one[:, 0].astype(full.dtype))

            caches = jax.tree.map(scatter, caches, pc)
            return (caches, tok.at[lane].set(t0),
                    pos.at[lane].set(npos[0].astype(jnp.int32)))

        self._admit = jax.jit(admit_fn) if jit else admit_fn
        self.alloc()

    def alloc(self) -> None:
        """(Re)build empty lane state: zero caches, fresh bank states."""
        specs = M.cache_specs(self.cfg, self.n_lanes, self.cache_len)
        self.caches = [_materialize_cache(s) for s in specs]
        self.tok = jnp.zeros((self.n_lanes,), jnp.int32)
        self.pos = jnp.zeros((self.n_lanes,), jnp.int32)
        self.states = tuple(s.init(self.n_lanes) for s in self.strategies)

    def admit(self, lane: int, req: Request) -> None:
        """Prefill the request at batch 1 and scatter it into ``lane``."""
        if req.prompt.shape[0] != self.prompt_len:
            raise ValueError(
                f"request {req.rid}: prompt length {req.prompt.shape[0]} "
                f"!= stepper bucket {self.prompt_len} (static shapes)")
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        self.caches, self.tok, self.pos = self._admit(
            self.caches, self.tok, self.pos, prompt,
            jnp.int32(lane))
        # pytree-sliced per-lane reset: the recycled lane starts from
        # fresh strategy state no matter what its predecessor observed
        self.states = tuple(init_lane(s, st, lane)
                            for s, st in zip(self.strategies, self.states))

    def warmup(self) -> None:
        """Compile the admit + step programs off the serving clock."""
        dummy = Request(rid=-1, prompt=np.zeros(self.prompt_len, np.int32),
                        max_tokens=1)
        self.admit(0, dummy)
        occ = np.zeros((self.n_lanes,), bool)
        occ[0] = True
        self.step(occ, np.zeros((self.n_lanes,), np.int32))
        self.alloc()

    def step(self, occupied: np.ndarray, sid: np.ndarray):
        """One decode token for every occupied lane.

        Returns host-side ``(emitted (B,), served (B,), seg_batch,
        seg_policy)`` — a single device sync for the whole token.
        """
        occ = jnp.asarray(occupied, bool)
        tok, self.caches, served, sb, sp, self.states = self._step(
            self.tok, self.caches, self.pos, occ,
            jnp.asarray(sid, jnp.int32), self.states)
        self.tok = tok
        self.pos = self.pos + occ.astype(jnp.int32)
        tok_h, served_h, sb_h, sp_h = jax.device_get((tok, served, sb, sp))
        return tok_h, served_h, int(sb_h), int(sp_h)
