"""The continuous-batching serve loop + model-free simulation
(DESIGN.md §7).

Data flow per iteration:

    workload arrivals -> RequestQueue -> LaneScheduler.admit
        -> stepper.admit (prefill + lane scatter | sim cursor)
        -> stepper.step  (one token for every occupied lane)
        -> metrics.on_token / lane recycling on completion

`Server` drives either stepper behind one loop:

  * `EngineStepper` (scheduler.py) — the real model; time is wall time.
  * `SimStepper` (here) — model-free: each lane's token replays a row of
    per-node losses (calibration traces or synthetic) through the SAME
    strategy bank the engine would consult, and a virtual clock prices
    each step.  CI exercises queueing, admission, recycling, and metric
    plumbing in milliseconds with no model params at all.

The sim cost model prices a step as ``overhead + seg_time * work``
where work is the launched depth (``cost="batch"``, what the masked
batch engine pays) or the mean per-lane probes (``cost="lane"``, what a
lane-granular dispatch would pay — the accounting split DESIGN.md §3
describes).  Strategy quality only turns into throughput under the lane
model, which is exactly the regime the bench sweep reports.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import bank_observe, bank_serve
from repro.serving.runtime.metrics import RuntimeMetrics
from repro.serving.runtime.request import Request, RequestQueue
from repro.serving.runtime.scheduler import LaneScheduler
from repro.strategy.base import dynamic_arrays, with_arrays

__all__ = ["Server", "SimStepper", "build_bank", "cascade_factory"]

_ROW_PRIME = 9973  # deterministic per-(rid, token) trace-row assignment


def build_bank(requests, make_strategy, default: tuple):
    """Resolve the distinct per-request ``(strategy, lam)`` pairs into a
    static strategy bank.

    Returns ``(strategies, sid_of)`` — the tuple the token step closes
    over (its size is fixed at trace time) and the lane->member resolver
    the scheduler stamps on each admission.  ``make_strategy(name, lam)``
    builds one member; ``default`` fills a request's missing fields.
    """
    def key_of(req):
        return (req.strategy or default[0],
                req.lam if req.lam is not None else default[1])

    keys: list = []
    for req in sorted(requests, key=lambda r: r.rid):
        k = key_of(req)
        if k not in keys:
            keys.append(k)
    if not keys:
        keys = [default]
    strategies = tuple(make_strategy(name, lam) for name, lam in keys)
    index = {k: i for i, k in enumerate(keys)}
    return strategies, lambda req: index[key_of(req)]


def cascade_factory(cascade):
    """The standard ``make_strategy`` for `build_bank`: registry dispatch
    against one calibrated cascade, with ``lam=None`` meaning the
    cascade's own lambda.  Callers with per-family CLI knobs (the
    launcher's thresholds/patience) wrap their own factory instead."""
    from repro import strategy as _strategy

    def mk(name, lam):
        if lam is None:
            return _strategy.make(name, cascade)
        return _strategy.make(name, cascade, lam=lam)

    return mk


class SimStepper:
    """Model-free stepper: replays loss traces through the strategy bank.

    ``trace_bank`` is a ``(T, n_nodes)`` array of per-node losses (e.g.
    `core.traces.ee_like_traces` or a cascade's calibration traces);
    request ``rid``'s token ``t`` deterministically reads row
    ``(rid * 9973 + t) % T``, so a request's decisions are independent
    of lane placement and arrival order by construction.

    Prefill cost model (DESIGN.md §9): ``prefill_tok_time`` prices one
    prompt token.  By default admission is STOP-THE-WORLD — the whole
    prompt's cost lands on the virtual clock as a SERIAL stall before
    the next step, exactly like the engine's batch-1 prefill program
    blocking the device queue.  With ``prefill_chunk`` set, admission
    is CHUNKED instead: the same `ChunkPlanner` the real engine uses
    spreads up to ``prefill_budget`` prompt tokens per step across
    admitting lanes, and the fused step is priced at the PIGGYBACK
    ROOFLINE ``max(decode cost, chunk cost)`` — single-token decode is
    memory-bound while the prefill chunk is compute-bound, so the
    co-scheduled chunk hides under the decode step's bandwidth time
    until it grows past it (the Sarathi observation; the budget knob
    is exactly the lever that keeps it hidden).  Lanes emit their
    first token on the step after their prefill completes.  Token
    DECISIONS are (rid, t)-keyed either way, so the two admission
    modes produce bit-identical streams by construction — only the
    clock moves.
    """

    virtual_time = True
    emits_tokens = False   # `emitted` carries served nodes, not token ids
    # observability plane (DESIGN.md §12): the server installs a
    # `SpanTracer` here when one is attached; every producer guards on
    # `is not None`, so an untraced serve pays nothing
    tracer = None
    last_loss = None       # per-lane served-node loss of the last step
    last_deepest = None    # per-lane deepest PROBED node (-1 = silent)
    # fault plane (DESIGN.md §14): the server stamps its clock here
    # each iteration when a FaultPlan is attached
    fault_now = 0.0

    def __init__(self, strategies: tuple, trace_bank, *, n_lanes: int,
                 seg_time: float = 1.0, overhead: float = 0.25,
                 cost: str = "lane", prefill_tok_time: float = 0.0,
                 prefill_chunk: int | None = None,
                 prefill_budget: int | None = None, pool=None,
                 faults=None):
        if cost not in ("lane", "batch"):
            raise ValueError(f"unknown cost model {cost!r}")
        from repro.serving.runtime.scheduler import ChunkPlanner
        # optional paged-KV admission gate (DESIGN.md §13): a real
        # `KVPool` doing its full host-side bookkeeping — reservation,
        # prefix sharing, per-token page growth and COW — with no device
        # arrays behind it.  The soak harness shrinks this pool to
        # manufacture genuine page pressure the invariant ledger audits.
        self.pool = pool
        self.faults = faults
        self.prefill_tok_time = float(prefill_tok_time)
        prefill_chunk = prefill_chunk or None      # 0 == disabled
        self.prefill_chunk = None if prefill_chunk is None \
            else int(prefill_chunk)
        self.planner = None if prefill_chunk is None else ChunkPlanner(
            self.prefill_chunk, prefill_budget)
        self.strategies = strategies
        self.bank = np.asarray(trace_bank, np.float32)
        self.n_nodes = self.bank.shape[1]
        self.full_depth = self.n_nodes
        self.n_lanes = int(n_lanes)
        self.seg_time = float(seg_time)
        self.overhead = float(overhead)
        self.cost = cost
        for s in strategies:
            if s.n_nodes != self.n_nodes:
                raise ValueError(
                    f"strategy expects {s.n_nodes} nodes, trace bank has "
                    f"{self.n_nodes}")
            if getattr(s, "needs_aux", False):
                raise ValueError(
                    f"{type(s).__name__} consumes the aux prediction "
                    "channel; simulation mode replays losses only — "
                    "serve it through the real EngineStepper instead")

        # hot-swap point (DESIGN.md §11): the decision program takes the
        # bank's dynamic arrays as a traced ARGUMENT, so publishing new
        # same-shaped tables (a `BankSwap`) hits the jit cache — never a
        # retrace, never a dropped lane.  ``bank_source`` is the control
        # plane's override; without one the baked arrays are passed.
        self._bank_arrays = tuple(dynamic_arrays(s) for s in strategies)
        self.bank_source = None
        # host tap for observed (loss-row, served-node) outcomes — the
        # Recalibrator's input stream; None = disabled, zero overhead
        self.row_tap = None

        def decide(arrays, losses, occupied, sid):
            live = tuple(with_arrays(s, a)
                         for s, a in zip(strategies, arrays))
            b = losses.shape[0]
            states = tuple(s.init(b) for s in live)
            active = occupied
            depth = jnp.zeros((), jnp.int32)
            policy = jnp.zeros((), jnp.int32)
            # per-lane deepest PROBED node — folded from the per-node
            # n_probed deltas, so it costs no extra strategy calls; the
            # regret meter's recall-forgone attribution reads it off
            # each token event
            deepest = jnp.full((b,), -1, jnp.int32)
            np_prev = jnp.zeros((b,), jnp.int32)

            def probed_of(states):
                out = states[0].n_probed
                for k in range(1, len(live)):
                    out = jnp.where(sid == k, states[k].n_probed, out)
                return out

            for node in range(self.n_nodes):
                depth = depth + active.any().astype(jnp.int32)
                policy = policy + active.sum(dtype=jnp.int32)
                states, active = bank_observe(
                    live, states, node, losses[:, node], None,
                    active, sid)
                np_now = probed_of(states)
                deepest = jnp.where(np_now > np_prev, node, deepest)
                np_prev = np_now
            return bank_serve(live, states, sid), depth, policy, deepest

        self._decide = jax.jit(decide)
        self.alloc()

    def bank_arrays(self) -> tuple:
        """The per-slot dynamic arrays the next step will decide with."""
        if self.bank_source is not None:
            return self.bank_source.bank_arrays()
        return self._bank_arrays

    def decide_cache_size(self) -> int:
        """Jit-cache entries of the decision program — the hot-swap
        safety tests assert this stays at 1 across swaps/publishes."""
        fn = getattr(self._decide, "_cache_size", None)
        return int(fn()) if fn is not None else -1

    def apply_gear(self, gear) -> None:
        """Host-side gear knobs outside the strategy tables: the
        chunked-prefill budget.  Routing (which slot new admissions
        use) and tables (recalibration) swap through ``bank_source``."""
        budget = getattr(getattr(gear, "spec", gear),
                         "prefill_budget", None)
        if budget is not None and self.planner is not None:
            self.planner.budget = int(budget)

    def alloc(self) -> None:
        self.lane_req: list[Request | None] = [None] * self.n_lanes
        self.lane_tidx = np.zeros(self.n_lanes, np.int64)
        self.lane_prefill = np.zeros(self.n_lanes, np.int64)
        self._stall = 0.0          # stop-the-world prefill debt
        # served-loss accumulator: the sim knows the served node's trace
        # loss exactly, which is the quality axis the cascade-vs-
        # monolith Pareto sweep compares on
        self.served_loss_sum = 0.0
        self.served_loss_n = 0
        self._stall_seen: set = set()   # (model, window-start) emitted
        if self.pool is not None:
            self.pool.reset()

    def _note_stall(self, model: int) -> None:
        """Emit one `rung_stall` span per scripted window edge."""
        win = self.faults.stall_window(model, self.fault_now)
        if win is None or (model, win[0]) in self._stall_seen:
            return
        self._stall_seen.add((model, win[0]))
        if self.tracer is not None:
            self.tracer.emit("rung_stall", model=model,
                             t0=round(win[0], 9), until=round(win[1], 9))

    def reserve(self, req: Request) -> bool:
        """Admission gate: with a pool attached, reserve the request's
        worst-case page need (or leave it queued); gate-free otherwise."""
        if self.pool is None:
            return True
        return self.pool.reserve(req.prompt, req.max_tokens)

    def release(self, lane: int) -> None:
        self.lane_prefill[lane] = 0     # reaped mid-prefill: drop debt
        if self.pool is not None:
            self.pool.release(lane)

    def admit(self, lane: int, req: Request) -> None:
        self.lane_req[lane] = req
        self.lane_tidx[lane] = 0
        lp = len(req.prompt)
        if self.pool is not None:
            self.pool.admit(lane, req.prompt, req.max_tokens)
        if self.prefill_chunk is not None:
            self.lane_prefill[lane] = lp
        elif self.prefill_tok_time > 0.0:
            # stop-the-world: the whole prompt stalls the next step
            self._stall += lp * self.prefill_tok_time

    def warmup(self) -> None:
        """Compile the decision program (virtual time is unaffected)."""
        self._decide(self.bank_arrays(),
                     jnp.zeros((self.n_lanes, self.n_nodes), jnp.float32),
                     jnp.zeros((self.n_lanes,), bool),
                     jnp.zeros((self.n_lanes,), jnp.int32))
        self.alloc()

    def _row(self, req: Request, tidx: int) -> np.ndarray:
        return self.bank[(req.rid * _ROW_PRIME + tidx) % len(self.bank)]

    def step(self, occupied: np.ndarray, sid: np.ndarray):
        """Returns ``(emitted, served, seg_batch, seg_policy, cost,
        emit_mask)`` — lanes mid-prefill are occupied but emit nothing
        and consume no trace row."""
        occupied = np.asarray(occupied, bool)
        if (self.faults is not None
                and self.faults.stall_active(0, self.fault_now)):
            # the single sim rung is frozen: no rows consumed, no
            # tokens, no prefill progress — only the clock moves, so a
            # finite window always passes (liveness)
            self._note_stall(0)
            if self.tracer is not None:
                self.last_loss = np.full(self.n_lanes, np.nan)
                self.last_deepest = np.full(self.n_lanes, -1)
            served = np.zeros(self.n_lanes, np.int64)
            return (served, served, 0, 0, self.overhead,
                    np.zeros(self.n_lanes, bool))
        emit = occupied.copy()
        stall = self._stall                 # stop-the-world: serial
        self._stall = 0.0
        chunk_cost = 0.0                    # chunked: piggybacked
        if self.prefill_chunk is not None:
            prefilling = occupied & (self.lane_prefill > 0)
            emit &= ~prefilling
            if prefilling.any():
                widths = self.planner.plan({
                    int(lane): (int(self.lane_prefill[lane]),
                                len(self.lane_req[lane].prompt))
                    for lane in np.flatnonzero(prefilling)})
                for lane, w in widths.items():
                    self.lane_prefill[lane] -= w
                    chunk_cost += w * self.prefill_tok_time
                    if self.tracer is not None:
                        self.tracer.emit(
                            "prefill_chunk", lane=lane,
                            rid=self.lane_req[lane].rid, width=int(w),
                            left=int(self.lane_prefill[lane]))
        if self.pool is not None and emit.any():
            # real paged bookkeeping per decode token: fresh tail pages
            # from the reserved budget, COW splits on shared tails —
            # the reservation guarantees these can never fail mid-stream
            self.pool.prepare_step(emit)
            self.pool.note_written(emit)
        losses = np.zeros((self.n_lanes, self.n_nodes), np.float32)
        for lane in np.flatnonzero(emit):
            losses[lane] = self._row(self.lane_req[lane],
                                     int(self.lane_tidx[lane]))
            self.lane_tidx[lane] += 1
        served, depth, policy, deepest = jax.device_get(self._decide(
            self.bank_arrays(), jnp.asarray(losses),
            jnp.asarray(emit, bool), jnp.asarray(sid, jnp.int32)))
        for lane in np.flatnonzero(emit):
            self.served_loss_sum += float(losses[lane, served[lane]])
            self.served_loss_n += 1
        if self.tracer is not None:
            # per-lane served-node loss, picked up by the server's token
            # events for decision attribution (NaN = no emission)
            served_np = np.asarray(served)
            self.last_loss = np.where(
                emit, losses[np.arange(self.n_lanes),
                             np.clip(served_np, 0, self.n_nodes - 1)],
                np.nan)
            self.last_deepest = np.where(emit, np.asarray(deepest), -1)
        if self.row_tap is not None and emit.any():
            idx = np.flatnonzero(emit)
            self.row_tap(losses[idx], np.asarray(served)[idx])
        work = (policy / self.n_lanes) if self.cost == "lane" else depth
        # piggyback roofline: the compute-bound chunk hides under the
        # memory-bound decode sweep; the serial stop-the-world stall
        # cannot (it is its own batch-1 program on the device queue)
        cost = self.overhead + max(self.seg_time * float(work),
                                   chunk_cost) + stall
        # sim tokens have no content; the served node stands in
        return served, served, int(depth), int(policy), cost, emit

    @property
    def mean_served_loss(self) -> float | None:
        if not self.served_loss_n:
            return None
        return self.served_loss_sum / self.served_loss_n


class Server:
    """Open-loop continuous-batching server over any stepper."""

    def __init__(self, stepper, scheduler: LaneScheduler, sid_of, *,
                 order: str = "fifo", slo: float | None = None,
                 static_batching: bool = False, eos: int | None = None,
                 controller=None, obs=None,
                 enforce_deadlines: bool = False):
        self.stepper = stepper
        self.scheduler = scheduler
        self.sid_of = sid_of
        self.order = order
        self.slo = slo
        self.static_batching = static_batching
        self.eos = eos
        # fault plane (DESIGN.md §14): deadlines double as EDF ordering
        # hints, so reaping on expiry is opt-in — `cancel_at` (a client
        # hang-up) is always enforced when present
        self.enforce_deadlines = bool(enforce_deadlines)
        # observability plane (DESIGN.md §12): an `Observability` bundle
        # — tracer + optional flight recorder.  The server binds its own
        # clock to the tracer (virtual in sim mode, so traces are
        # exactly deterministic) and installs it on the stepper and
        # controller; None means zero overhead everywhere.
        self.obs = obs
        # adaptive control plane (DESIGN.md §11): begin() binds it to
        # the metrics + stepper, on_arrivals feeds the load signal,
        # on_step_end is the step-boundary decision point — the ONLY
        # instant a gear swap can land, which is what makes swaps
        # atomic with respect to in-flight token steps
        self.controller = controller
        self._vt = 0.0
        self._t0 = 0.0

    # ---- clock ---------------------------------------------------------
    def _now(self) -> float:
        if self.stepper.virtual_time:
            return self._vt
        return time.perf_counter() - self._t0

    def _advance_to(self, t: float) -> None:
        if self.stepper.virtual_time:
            self._vt = max(self._vt, t)
        else:
            gap = t - self._now()
            if gap > 0:
                time.sleep(gap)

    # ---- fault plane ---------------------------------------------------
    def _reap_status(self, req, now: float) -> str | None:
        """Terminal status a live request has earned by ``now``, or
        None.  Cancellation wins ties — a hung-up client's deadline is
        moot."""
        if req.cancel_at is not None and req.cancel_at <= now:
            return "cancelled"
        if (self.enforce_deadlines and req.deadline is not None
                and req.deadline <= now):
            return "timed_out"
        return None

    def _reap(self, queue, metrics, tracer, release, now: float) -> None:
        """Sweep cancelled / expired requests out of the queue and off
        their lanes between steps.  Lane teardown runs release-first so
        the span events land on an already-clean pool — the ledger's
        `cancel_releases_pages` probe reads pool state at the event."""
        sched = self.scheduler
        for req in queue.reap(
                lambda r: self._reap_status(r, now) is not None):
            status = self._reap_status(req, now)
            metrics.on_reap(req, now, status)
            if tracer is not None:
                kind = ("cancel" if status == "cancelled"
                        else "deadline_miss")
                tracer.emit(kind, rid=req.rid)
        for lane in np.flatnonzero(sched.occupied_mask()):
            req = sched.lane_req[lane]
            status = self._reap_status(req, now)
            if status is None:
                continue
            if release is not None:
                release(int(lane))  # KV pages + escalation lanes freed
            sched.release(int(lane))
            metrics.on_reap(req, now, status)
            if tracer is not None:
                kind = ("cancel" if status == "cancelled"
                        else "deadline_miss")
                tracer.emit(kind, rid=req.rid, lane=int(lane))

    def _fault_wake(self, queue, faults, reaping: bool,
                    now: float) -> float | None:
        """Earliest future instant at which the fault plane changes the
        picture for a queue that cannot admit right now: a queued
        request's reap time, or a scripted stall/squeeze boundary."""
        wake = None
        if reaping:
            for r in queue.requests():
                for t in (r.cancel_at,
                          r.deadline if self.enforce_deadlines else None):
                    if t is not None and t > now and (wake is None
                                                      or t < wake):
                        wake = t
        if faults is not None:
            nc = faults.next_change(now)
            if nc is not None and (wake is None or nc < wake):
                wake = nc
        return wake

    # ---- the loop ------------------------------------------------------
    def serve(self, requests, warmup: bool = True) -> RuntimeMetrics:
        """Run the full open-loop session: admit every request at its
        arrival time, decode until all streams drain, return metrics.

        ``warmup`` compiles the stepper's device programs before the
        serving clock starts, so wall-clock latency percentiles measure
        serving, not XLA compilation.
        """
        sched = self.scheduler
        stepper = self.stepper
        if warmup:
            stepper.warmup()
        else:
            stepper.alloc()
        metrics = RuntimeMetrics(stepper.full_depth, sched.n_lanes)
        if self.controller is not None:
            self.controller.begin(metrics, stepper)
        tracer = self.obs.tracer if self.obs is not None else None
        if tracer is not None:
            tracer.bind_clock(self._now)
            stepper.tracer = tracer
            if self.controller is not None:
                self.controller.tracer = tracer
            flight = self.obs.flight
            if flight is not None:
                if flight.slo is None:
                    flight.slo = self.slo
                flight.bind(tracer,
                            snapshot_fn=lambda: metrics.summary(self.slo))
            ledger = getattr(self.obs, "ledger", None)
            if ledger is not None:
                ledger.bind(tracer, pool=getattr(stepper, "pool", None))
            regret = getattr(self.obs, "regret", None)
            if regret is not None:
                # pure listener, same discipline as the ledger: the
                # meter pulls the stepper's trace bank for its exact
                # oracle but never emits or syncs anything itself
                regret.bind(tracer, stepper=stepper, flight=flight,
                            controller=self.controller)
        deadline_of = None
        if self.order == "edf" and self.slo is not None:
            deadline_of = lambda r: r.arrival + self.slo  # noqa: E731
        queue = RequestQueue(self.order, deadline_of=deadline_of)
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        # fault plane (DESIGN.md §14): the stepper may carry a FaultPlan
        # whose serve-borne windows (rung stalls, page squeezes) are
        # read off the virtual clock each iteration; request-borne
        # faults ride the requests themselves
        faults = getattr(stepper, "faults", None)
        # the degrade governor reads the clock too: its deadline-budget
        # check needs `now` even when no FaultPlan is attached
        clocked = (faults is not None
                   or getattr(stepper, "governor", None) is not None)
        reaping = self.enforce_deadlines or any(
            r.cancel_at is not None for r in pending)
        self._vt = 0.0
        self._t0 = time.perf_counter()
        metrics.t_start = self._now()

        # paged-KV steppers gate admission on their free-page budget
        # (reserve-at-pop); a blocked request waits at the queue head
        gate = getattr(stepper, "reserve", None)
        release = getattr(stepper, "release", None)
        if gate is not None and tracer is not None:
            def gate(req, _inner=gate):
                ok = _inner(req)
                if not ok:
                    tracer.emit("page_blocked", rid=req.rid)
                return ok

        while pending or len(queue) or sched.busy():
            now = self._now()
            if clocked:
                stepper.fault_now = now
            if faults is not None:
                pool = getattr(stepper, "pool", None)
                if pool is not None and hasattr(pool, "set_squeeze"):
                    pool.set_squeeze(faults.squeeze_pages(now))
            pushed = []
            while pending and pending[0].arrival <= now:
                req = pending.pop(0)
                queue.push(req)
                pushed.append(req.arrival)
                if tracer is not None:
                    # self-contained for replay (obs/replay.py): the
                    # queued event carries everything needed to rebuild
                    # the request — prompt bytes included, since paged
                    # admission and prefix sharing key on content
                    extra = {"plen": len(req.prompt),
                             "ntok": int(req.max_tokens),
                             "prompt": np.asarray(
                                 req.prompt, np.uint32).tobytes().hex()}
                    if req.strategy is not None:
                        extra["strategy"] = req.strategy
                    if req.lam is not None:
                        extra["lam"] = float(req.lam)
                    if req.deadline is not None:
                        extra["deadline"] = float(req.deadline)
                    if req.cancel_at is not None:
                        extra["cancel_at"] = float(req.cancel_at)
                    tracer.emit("queued", t=req.arrival, rid=req.rid,
                                **extra)
            if self.controller is not None and pushed:
                self.controller.on_arrivals(pushed)
            if reaping:
                self._reap(queue, metrics, tracer, release, now)
            for lane, req in sched.admit(
                    queue, self.sid_of,
                    static_batching=self.static_batching,
                    can_admit=gate):
                stepper.admit(lane, req)
                metrics.on_admit(req, self._now())
                if tracer is not None:
                    tracer.emit("admitted", rid=req.rid, lane=lane,
                                sid=int(sched.sid[lane]))
            if not sched.busy():
                if not pending:
                    # nothing running, nothing arriving — but the queue
                    # may still hold page-blocked requests; one more
                    # admit pass runs next iteration after lanes/pages
                    # freed (len(queue) keeps the loop alive).  Guard
                    # against a request that can NEVER be admitted —
                    # unless the fault plane will change the picture (a
                    # queued request about to be reaped, a squeeze or
                    # stall window about to end): then jump there.
                    if len(queue):
                        wake = self._fault_wake(queue, faults, reaping,
                                                now)
                        if wake is not None and wake > now:
                            self._advance_to(wake)
                            continue
                        raise RuntimeError(
                            "admission deadlock: queued requests but no "
                            "lane busy and no pending arrivals")
                    break
                # every lane idle and nothing admissible: jump (sim) or
                # sleep (real) to the next arrival
                self._advance_to(pending[0].arrival)
                continue

            occupied = sched.occupied_mask()
            out = stepper.step(occupied, sched.sid)
            if stepper.virtual_time:
                emitted, served, sb, sp, cost, emit = out
                self._vt += cost
            else:
                emitted, served, sb, sp, emit = out
            tnow = self._now()
            # emit marks lanes whose entry is a real token this step;
            # lanes mid-(chunked-)prefill are occupied but still silent
            metrics.on_step(sb, sp, int(np.asarray(emit).sum()))
            for lane in np.flatnonzero(emit):
                req = sched.lane_req[lane]
                metrics.on_token(req.rid, int(served[lane]), tnow,
                                 token=int(emitted[lane]))
                if tracer is not None:
                    extra = {}
                    rec = metrics.records[req.rid]
                    if rec.n_tokens == 1 and rec.ttft is not None:
                        extra["ttft"] = round(rec.ttft, 9)
                    ll = getattr(stepper, "last_loss", None)
                    if ll is not None and not np.isnan(ll[lane]):
                        extra["loss"] = round(float(ll[lane]), 6)
                    le = getattr(stepper, "last_escalated", None)
                    if le is not None and le[lane]:
                        extra["esc"] = True
                    ld = getattr(stepper, "last_deepest", None)
                    if ld is not None and ld[lane] >= 0:
                        extra["deepest"] = int(ld[lane])
                    if getattr(stepper, "emits_tokens", True):
                        extra["tok"] = int(emitted[lane])
                    tracer.emit("token", rid=req.rid, lane=int(lane),
                                node=int(served[lane]),
                                sid=int(sched.sid[lane]), **extra)
                done = sched.consume_token(lane)
                if (not done and self.eos is not None
                        and getattr(stepper, "emits_tokens", True)
                        and int(emitted[lane]) == self.eos):
                    done = True  # stream early-exit: recycle immediately
                if done:
                    metrics.on_finish(req.rid, tnow)
                    if release is not None:
                        release(lane)   # paged KV: pages back to the pool
                    sched.release(lane)
                    if tracer is not None:
                        tracer.emit("finish", rid=req.rid, lane=int(lane))
            if tracer is not None:
                data = {"queue": len(queue)}
                pool = getattr(stepper, "pool", None)
                if pool is not None:
                    data["pages_in_use"] = int(pool.pages_in_use)
                tracer.emit("counter", **data)
            if self.controller is not None:
                # step boundary: the device program for this step has
                # fully retired, no lane is mid-token — the one atomic
                # instant a gear swap / table publish may land
                self.controller.on_step_end(self._now(), len(queue))

        metrics.t_end = self._now()
        if self.obs is not None:
            if getattr(self.obs, "ledger", None) is not None:
                self.obs.ledger.finalize(self._now())
            if getattr(self.obs, "regret", None) is not None:
                self.obs.regret.finalize(self._now())
        return metrics
