"""repro.serving.runtime — continuous-batching serving on top of the
Strategy engine (DESIGN.md §7).

The runtime turns the one-shot `Engine` into an open-loop server:
streaming `Request`s queue up (`request.py`), a fixed-width lane
scheduler admits them into the batched decode step — gated, in paged-KV
mode (`repro.serving.kvpool`, DESIGN.md §8), by the pool's free-page
budget — and recycles a lane the moment its request completes
(`scheduler.py`), synthetic traffic
generators drive it (`workload.py`), and serving metrics — throughput,
token-latency percentiles, TTFT, goodput under an SLO, segments saved —
come out as JSON (`metrics.py`).  `server.py` ties the loop together
and adds a model-free simulation mode that replays calibration traces
through the same scheduler, so CI exercises admission logic in
milliseconds.
"""

from repro.serving.runtime.metrics import RuntimeMetrics
from repro.serving.runtime.request import Request, RequestQueue
from repro.serving.runtime.scheduler import (ChunkPlanner, EngineStepper,
                                             LaneScheduler)
from repro.serving.runtime.server import (Server, SimStepper, build_bank,
                                          cascade_factory)
from repro.serving.runtime.workload import (available_workloads,
                                            make_workload)

__all__ = [
    "Request", "RequestQueue", "LaneScheduler", "ChunkPlanner",
    "EngineStepper", "Server", "SimStepper", "RuntimeMetrics",
    "build_bank", "cascade_factory", "make_workload",
    "available_workloads",
]
