"""Serving metrics for the continuous-batching runtime (DESIGN.md §7).

Definitions (all timestamps come from the server's clock — wall seconds
in engine mode, virtual units in simulation mode):

  * **TTFT** — first emitted token minus ARRIVAL (queue wait included;
    that is the quantity admission policy actually moves).
  * **token latency** — inter-token gap between consecutive emissions of
    one request; p50/p95/p99 are over all gaps of all requests.
  * **throughput** — emitted tokens (and completed requests) per unit
    time over the serve window.
  * **goodput** — emitted tokens/sec counting only requests that met the
    SLO (``ttft <= slo``); the difference to raw throughput is work the
    server did without serving anyone acceptably.
  * **segments saved** — both of the engine's accountings, in one unit
    each: *batch*-level (segment launches skipped because every lane had
    exited) and *lane*-level (per-lane probes skipped — what a
    lane-granular dispatch would save), both relative to full depth.

`summary()` returns a plain dict; `to_json()` dumps summary + per-request
records, which is what the bench trajectory and the CI artifact store.
"""

from __future__ import annotations

import collections
import dataclasses
import json

import numpy as np

__all__ = ["RequestRecord", "RuntimeMetrics", "SlidingWindow"]


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival: float
    admitted: float | None = None
    first_token: float | None = None
    finished: float | None = None
    n_tokens: int = 0
    served_depth_sum: int = 0       # sum over tokens of served node idx
    strategy: str | None = None
    tokens: list = dataclasses.field(default_factory=list)  # emitted ids
    status: str = "active"          # -> completed | cancelled | timed_out
    deadline: float | None = None   # absolute deadline, if any
    ended: float | None = None      # terminal timestamp (any status)
    _last_token: float | None = None

    @property
    def ttft(self) -> float | None:
        return None if self.first_token is None \
            else self.first_token - self.arrival

    @property
    def e2e(self) -> float | None:
        return None if self.finished is None \
            else self.finished - self.arrival

    def as_dict(self) -> dict:
        return {
            "rid": self.rid, "arrival": self.arrival,
            "admitted": self.admitted, "first_token": self.first_token,
            "finished": self.finished, "n_tokens": self.n_tokens,
            "ttft": self.ttft, "e2e": self.e2e,
            "mean_served_node": (self.served_depth_sum / self.n_tokens
                                 if self.n_tokens else None),
            "strategy": self.strategy,
            "status": self.status,
            "deadline": self.deadline,
            "tokens": list(self.tokens),
        }


def _pct(vals, qs=(50, 95, 99)) -> dict:
    if not len(vals):
        return {f"p{q}": None for q in qs}
    arr = np.asarray(vals, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class SlidingWindow:
    """Bounded time-indexed sample ring for streaming percentiles.

    Holds ``(t, value)`` pairs; reads prune everything older than the
    trailing ``span``, and the deque's ``maxlen`` caps memory no matter
    how long the serve runs — the unbounded-growth fix the control
    plane's telemetry needs.  Semantics are EXPLICIT at the edges:

      * empty window  -> ``percentiles`` returns all-None, ``values``
        returns ``[]`` (callers must not read a rate out of nothing);
      * one sample    -> every percentile IS that sample (no
        interpolation against phantom data).
    """

    def __init__(self, span: float, maxlen: int = 4096):
        if not span > 0:
            raise ValueError(f"window span must be > 0, got {span}")
        self.span = float(span)
        self._buf: collections.deque = collections.deque(
            maxlen=int(maxlen))

    def __len__(self) -> int:
        return len(self._buf)

    def push(self, t: float, value) -> None:
        self._buf.append((float(t), value))

    def prune(self, now: float) -> None:
        lo = float(now) - self.span
        while self._buf and self._buf[0][0] < lo:
            self._buf.popleft()

    def items(self, now: float) -> list:
        self.prune(now)
        return list(self._buf)

    def values(self, now: float) -> list:
        return [v for _, v in self.items(now)]

    def percentiles(self, now: float, qs=(50, 95, 99)) -> dict:
        vals = self.values(now)
        if not vals:
            return {f"p{q}": None for q in qs}
        if len(vals) == 1:
            v = float(vals[0])
            return {f"p{q}": v for q in qs}
        return _pct(vals, qs)


class RuntimeMetrics:
    """Accumulates per-request + per-step records during a serve run."""

    def __init__(self, full_depth: int, n_lanes: int,
                 window: float | None = None, window_samples: int = 4096):
        self.full_depth = int(full_depth)   # segments (sim: nodes)/token
        self.n_lanes = int(n_lanes)
        self.records: dict[int, RequestRecord] = {}
        self.itl: list[float] = []          # inter-token gaps
        self.steps = 0
        self.seg_batch = 0                  # launched segment count
        self.seg_policy = 0                 # per-lane probed count
        self.lane_steps = 0                 # occupied lane-tokens
        self.t_start: float = 0.0
        self.t_end: float = 0.0
        self.window: float | None = None
        self._win_ttft: SlidingWindow | None = None
        self._win_itl: SlidingWindow | None = None
        self._win_tok: SlidingWindow | None = None
        if window is not None:
            self.enable_window(window, window_samples)

    def enable_window(self, span: float,
                      window_samples: int = 4096) -> None:
        """Turn on bounded sliding-window accounting (streaming mode).

        Besides the window rings, this BOUNDS the global inter-token-gap
        buffer: a streaming serve can run indefinitely, so ``summary``'s
        token-latency percentiles then cover the most recent samples
        only instead of growing without limit.
        """
        self.window = float(span)
        self._win_ttft = SlidingWindow(span, window_samples)
        self._win_itl = SlidingWindow(span, window_samples)
        # value = (rid, served_node): goodput needs the owning request
        self._win_tok = SlidingWindow(span, window_samples)
        bound = 16 * int(window_samples)
        self.itl = collections.deque(self.itl, maxlen=bound)

    # ------------------------------------------------------------------
    # event hooks (called by the server loop)
    # ------------------------------------------------------------------

    def on_admit(self, req, now: float) -> None:
        self.records[req.rid] = RequestRecord(
            rid=req.rid, arrival=req.arrival, admitted=now,
            strategy=req.strategy, deadline=req.deadline)

    def on_step(self, seg_batch: int, seg_policy: int,
                n_occupied: int) -> None:
        self.steps += 1
        self.seg_batch += int(seg_batch)
        self.seg_policy += int(seg_policy)
        self.lane_steps += int(n_occupied)

    def on_token(self, rid: int, served_node: int, now: float,
                 token: int | None = None) -> None:
        rec = self.records[rid]
        if rec.first_token is None:
            rec.first_token = now
            if self._win_ttft is not None:
                self._win_ttft.push(now, now - rec.arrival)
        else:
            self.itl.append(now - rec._last_token)
            if self._win_itl is not None:
                self._win_itl.push(now, now - rec._last_token)
        rec._last_token = now
        rec.n_tokens += 1
        rec.served_depth_sum += int(served_node)
        if self._win_tok is not None:
            self._win_tok.push(now, (rid, int(served_node)))
        if token is not None:
            rec.tokens.append(int(token))

    def on_finish(self, rid: int, now: float) -> None:
        rec = self.records[rid]
        rec.finished = now
        rec.ended = now
        rec.status = "completed"

    def on_reap(self, req, now: float, status: str) -> None:
        """Terminal accounting for a cancelled / timed-out request.

        ``finished`` stays None — a reaped request never completes, so
        it can never enter the goodput numerator or distort TTFT
        percentiles — but the partial-token work it consumed remains in
        its record (and in throughput), which is exactly the gap the
        lossmap's ``cancelled`` cause accounts for.  Queue-reaped
        requests that were never admitted get a record here."""
        if status not in ("cancelled", "timed_out"):
            raise ValueError(f"unknown terminal status {status!r}")
        rec = self.records.get(req.rid)
        if rec is None:
            rec = RequestRecord(
                rid=req.rid, arrival=req.arrival,
                strategy=req.strategy, deadline=req.deadline)
            self.records[req.rid] = rec
        rec.ended = now
        rec.status = status

    # ------------------------------------------------------------------
    # aggregation
    # ------------------------------------------------------------------

    def summary(self, slo: float | None = None) -> dict:
        recs = list(self.records.values())
        done = [r for r in recs if r.finished is not None]
        cancelled = [r for r in recs if r.status == "cancelled"]
        timed_out = [r for r in recs if r.status == "timed_out"]
        duration = max(self.t_end - self.t_start, 1e-9)
        tokens = sum(r.n_tokens for r in recs)
        # TTFT percentiles over non-reaped records only: a request
        # cancelled mid-queue-wait has no first token, and one reaped
        # just after its first token would drag the percentiles toward
        # the reap schedule rather than the scheduler's behavior.
        ttfts = [r.ttft for r in recs
                 if r.ttft is not None and r.status not in
                 ("cancelled", "timed_out")]
        e2es = [r.e2e for r in done]
        # deadline slack: deadline minus terminal time for every
        # terminal record carrying a deadline (negative == missed)
        slack = [r.deadline - r.ended for r in recs
                 if r.deadline is not None and r.ended is not None]

        met_slo = None
        goodput = None
        if slo is not None:
            ok = [r for r in done
                  if r.ttft is not None and r.ttft <= slo]
            met_slo = len(ok) / max(len(done), 1)
            goodput = sum(r.n_tokens for r in ok) / duration

        full_b = self.steps * self.full_depth
        full_l = self.lane_steps * self.full_depth
        return {
            "duration": duration,
            "requests": len(recs),
            "completed": len(done),
            "cancelled": len(cancelled),
            "timed_out": len(timed_out),
            "deadline_slack": (_pct(slack) if slack else None),
            "tokens": tokens,
            "throughput_tok_s": tokens / duration,
            "throughput_req_s": len(done) / duration,
            "ttft": _pct(ttfts),
            "token_latency": _pct(self.itl),
            "e2e_latency": _pct(e2es, qs=(50, 95)),
            "slo": slo,
            "slo_attainment": met_slo,
            "goodput_tok_s": goodput,
            "steps": self.steps,
            "segments_saved_batch": (1.0 - self.seg_batch / full_b
                                     if full_b else None),
            "segments_saved_lane": (1.0 - self.seg_policy / full_l
                                    if full_l else None),
            "mean_served_node": (sum(r.served_depth_sum for r in recs)
                                 / tokens if tokens else None),
        }

    def window_summary(self, now: float, slo: float | None = None) -> dict:
        """Trailing-window estimates over the bounded rings.

        Explicit edge semantics: an EMPTY window reports zero
        throughput/goodput, ``samples == 0``, all-None percentiles and
        a None mean served node — never NaNs, never stale data.  The
        per-window ``goodput_tok_s`` counts window tokens whose owning
        request's TTFT met the SLO — the quantity the control plane's
        gear selection watches.
        """
        if self._win_tok is None:
            raise RuntimeError("sliding window disabled — pass window= "
                               "to RuntimeMetrics or call enable_window")
        toks = self._win_tok.values(now)
        span = min(self.window, max(float(now) - self.t_start, 1e-9))
        goodput = None
        if slo is not None:
            ok = 0
            for rid, _node in toks:
                ttft = self.records[rid].ttft
                if ttft is not None and ttft <= slo:
                    ok += 1
            goodput = ok / span
        return {
            "now": float(now),
            "window": self.window,
            "samples": len(toks),
            "throughput_tok_s": len(toks) / span,
            "goodput_tok_s": goodput,
            "mean_served_node": (sum(n for _, n in toks) / len(toks)
                                 if toks else None),
            "ttft": self._win_ttft.percentiles(now),
            "token_latency": self._win_itl.percentiles(now),
        }

    def to_json(self, path: str, slo: float | None = None,
                extra: dict | None = None,
                max_records: int | None = 4096) -> dict:
        """Write summary + per-request records; returns the payload.

        ``max_records`` bounds the per-request section so hours-long
        soak runs cannot grow the artifact without bound: the MOST
        RECENT records (by arrival) are kept and the drop is counted
        in ``requests_dropped``.  ``max_records=None`` keeps all.
        """
        recs = sorted(self.records.values(), key=lambda r: r.arrival)
        dropped = 0
        if max_records is not None and len(recs) > max_records:
            dropped = len(recs) - int(max_records)
            recs = recs[dropped:]
        payload = {
            "summary": self.summary(slo),
            "requests": [r.as_dict() for r in recs],
            "requests_dropped": dropped,
        }
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return payload
