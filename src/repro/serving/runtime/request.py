"""Requests and the admission queue (DESIGN.md §7).

A `Request` is one generation job: a fixed-length prompt bucket, a token
budget, and — the T-Tamer knob the runtime exposes PER REQUEST rather
than per process — an optional strategy name / lambda override that the
scheduler maps onto a member of its strategy bank.

`RequestQueue` orders admission: ``"fifo"`` by arrival time, ``"edf"``
earliest-deadline-first (requests without a deadline sort last).  Both
orderings are fully deterministic — ties break on the request id — which
is what the admission-order-invariance tests lean on.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

__all__ = ["Request", "RequestQueue"]


@dataclasses.dataclass
class Request:
    """One streaming generation request."""

    rid: int                       # unique id (also the determinism seed)
    prompt: np.ndarray             # (prompt_len,) int32 token bucket
    max_tokens: int                # decode-token budget
    arrival: float = 0.0           # seconds (sim: virtual units) from t=0
    lam: float | None = None       # per-request trade-off (None: server's)
    strategy: str | None = None    # registry name (None: server default)
    deadline: float | None = None  # absolute deadline for EDF ordering
    cancel_at: float | None = None  # client hang-up time (fault plane)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got {self.prompt.shape}")
        if self.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")


class RequestQueue:
    """Deterministic admission queue with FIFO or EDF ordering.

    ``deadline_of`` supplies a fallback deadline for EDF when a request
    carries none (e.g. ``arrival + slo``) — evaluated at push time, so
    the requests themselves are never mutated.
    """

    ORDERS = ("fifo", "edf")

    def __init__(self, order: str = "fifo", deadline_of=None):
        if order not in self.ORDERS:
            raise ValueError(f"unknown queue order {order!r}; "
                             f"choose from {self.ORDERS}")
        self.order = order
        self.deadline_of = deadline_of
        self._heap: list = []

    def _key(self, req: Request):
        if self.order == "fifo":
            return (req.arrival, req.rid)
        dl = req.deadline
        if dl is None and self.deadline_of is not None:
            dl = self.deadline_of(req)
        if dl is None:
            dl = float("inf")
        return (dl, req.arrival, req.rid)

    def push(self, req: Request) -> None:
        # rid in the entry keeps the heap total-ordered without ever
        # comparing Request objects
        heapq.heappush(self._heap, (self._key(req), req.rid, req))

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Request:
        return self._heap[0][2]

    def reap(self, predicate) -> list[Request]:
        """Remove and return every queued request for which
        ``predicate(req)`` is true — the fault plane's pre-admission
        sweep for cancelled / expired requests.  The surviving heap is
        re-heapified, so ordering semantics are untouched."""
        reaped = [req for _, _, req in self._heap if predicate(req)]
        if reaped:
            self._heap = [e for e in self._heap if not predicate(e[2])]
            heapq.heapify(self._heap)
        return reaped

    def requests(self) -> list[Request]:
        """Snapshot of all queued requests (arbitrary order)."""
        return [req for _, _, req in self._heap]

    def __len__(self) -> int:
        return len(self._heap)
