"""Segment-wise serving engine with T-Tamer early exit (the paper's
technique as a first-class serving feature — DESIGN.md §2-3).

The engine executes a decode step SEGMENT BY SEGMENT.  After every ramp
segment it:
  1. computes the loss proxy ell = 1 - confidence for each lane,
  2. hands it to the pluggable `Strategy` (``observe`` updates per-lane
     state and returns the mask of lanes continuing deeper), and
  3. serves, per lane, the logits of whatever node ``strategy.serve``
     designates — argmin ramp under recall, last probed without.

The engine holds NO policy logic of its own: any strategy from
``repro.strategy.make`` (recall index, thresholds, patience, skip
tables, ...) plugs in unchanged, and the same object reproduces its
offline ``strategy.evaluate`` decisions here (tested in
tests/test_system.py).  Strategies with ``online = False`` (the
hindsight oracles) are rejected — segments cannot be un-run.

TPU adaptation (DESIGN.md §3): lanes are fixed-shape; exited lanes are
masked, and the whole token is ONE device program (`make_token_step`):
each segment launch is gated by ``lax.cond(active.any(), ...)``, so the
decision to stop running deeper segments once every lane has exited
("batch-level" saving) is made on device — no host round-trip per
segment.  Segment counters accumulate as device scalars and the host
syncs exactly once per token (tokens + served nodes + stats in a single
``device_get``).  Per-lane policy FLOPs (what a lane-granular runtime
such as per-request dispatch would pay) are accounted separately — both
numbers are reported by the serving benchmarks.

State skew: when a lane exits early, deeper segments' KV/SSM cache
writes are MASKED for that lane (``_mask_lane_writes``) — the holes are
hidden from later attention by the stored-position mask.  This is the
standard early-exit cache policy (cf. Apparate / DeeBERT serving), a
quality-for-latency approximation the T-Tamer cost model already prices
in via the calibration traces; it also makes every lane's output stream
a function of its own request alone, which is what lets the
continuous-batching runtime (repro.serving.runtime) recycle lanes with
admission-order invariance.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.strategy.base import Strategy

__all__ = ["Engine", "GenerationStats", "Classifier", "make_token_step",
           "bank_observe", "bank_serve", "fold_readout"]


def _check_online(strategy: Strategy) -> Strategy:
    if not getattr(strategy, "online", True):
        raise ValueError(
            f"{type(strategy).__name__} needs hindsight (online=False) and "
            "cannot drive the serving engine; use strategy.evaluate on "
            "offline traces instead")
    # the engine's aux channel carries predicted labels, NOT support bins
    # — a table strategy built without a Support would silently consume
    # them as bins, so refuse it here rather than serve garbage
    if hasattr(strategy, "support") and strategy.support is None:
        raise ValueError(
            f"{type(strategy).__name__} was built without a Support and "
            "reads bins from the aux channel; the engine supplies "
            "predictions there — construct it with the cascade's Support")
    return strategy


@dataclasses.dataclass
class GenerationStats:
    tokens: np.ndarray              # (B, T) generated tokens
    served_nodes: np.ndarray        # (B, T) which node served each token
    segments_run_batch: int         # segments actually launched (batch)
    segments_run_policy: int        # sum over lanes of nodes probed
    segments_full: int              # full-depth reference


def _mask_lane_writes(new_cache, old_cache, active: jax.Array,
                      paged: bool = False):
    """Keep inactive lanes' cache bits: leaves are layer-stacked
    ``(L, B, ...)``, so broadcast the lane mask over axis 1.

    In paged mode the attention leaves are page-pool shaped (no lane
    axis) and the decode path already redirected masked lanes' writes to
    the garbage page — only the lane-indexed SSM state still needs the
    where()."""
    def sel(n, o):
        return jnp.where(active.reshape((1, -1) + (1,) * (n.ndim - 2)),
                         n, o)
    if not paged:
        return jax.tree.map(sel, new_cache, old_cache)
    out = dict(new_cache)
    if "ssm" in new_cache:
        out["ssm"] = jax.tree.map(sel, new_cache["ssm"], old_cache["ssm"])
    return out


def bank_observe(strategies, states, node, losses, preds, active, sid):
    """Fold one node into every bank member's state; lanes only follow
    their own member's continue/stop verdict (``sid`` selects).  Shared
    by the engine's token step and the runtime's simulation stepper."""
    new_states, conts = [], []
    for k, strat in enumerate(strategies):
        mask = active if len(strategies) == 1 else active & (sid == k)
        st, cont = strat.observe(states[k], node, losses, mask, aux=preds)
        new_states.append(st)
        conts.append(cont)
    if len(strategies) == 1:
        return tuple(new_states), conts[0]
    out = jnp.zeros_like(active)
    for k, cont in enumerate(conts):
        out = jnp.where(sid == k, cont, out)
    return tuple(new_states), out


def bank_serve(strategies, states, sid):
    served = strategies[0].serve(states[0]).astype(jnp.int32)
    for k in range(1, len(strategies)):
        served = jnp.where(sid == k,
                           strategies[k].serve(states[k]).astype(jnp.int32),
                           served)
    return served


def fold_readout(strategies, states, node, logits, ell, active, sid, best):
    """Fold one ramp/head readout into the bank: observe the loss proxy,
    then refresh ``best`` with this node's logits for exactly the lanes
    whose SERVED node is this one (post-observe serve() mask — an
    earlier-exited lane's logits are never overwritten by deeper ramps
    or the head).  Shared by the engine's token step and
    `Classifier.classify` so the serve semantics cannot drift apart.

    Returns (states, active, best)."""
    preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    states, active = bank_observe(strategies, states, node, ell, preds,
                                  active, sid)
    take = bank_serve(strategies, states, sid) == node
    best = jnp.where(take[:, None], logits.astype(jnp.float32), best)
    return states, active, best


def make_token_step(params, cfg: ModelConfig, strategies, *,
                    jit: bool = True, donate: bool | None = None,
                    carry_state: bool = False, paged: bool = False,
                    paged_kernel: bool = False, prefill_slots: int = 0,
                    node_offset: int = 0, walk_io: bool = False,
                    resume_walk: bool = False):
    """Build the one-token segment sweep shared by `Engine.generate` and
    the continuous-batching runtime (`repro.serving.runtime`).

    The whole sweep is a single device program: each segment launch is
    gated by ``lax.cond(active.any(), ...)`` so batch-level skipping is
    decided on device (no per-segment host round-trip), exited lanes'
    cache writes are masked (a lane's stream depends on its own request
    only), and the segment counters accumulate as device scalars so
    callers sync at most once per token.

    Args:
      strategies: a tuple *bank* of online strategies; the per-lane
        ``sid`` (B,) int32 argument picks each lane's member — this is
        how the runtime serves per-request strategies / lambdas inside
        one static-shape batch.  The Engine passes a one-member bank.
      jit: wrap in ``jax.jit`` (caches donated off-CPU).
      donate: override cache-buffer donation (default: on for
        accelerator backends, off on CPU where XLA can't honor it).
      carry_state: runtime mode — the step takes the bank's per-lane
        states as a sixth argument and returns them updated.  By default
        a strategy explores per token, so every occupied lane's state is
        re-initialized at its token boundary via
        `strategy.base.reset_lanes` (pytree-sliced, on device).  A
        strategy that sets ``persistent = True`` opts out of the
        boundary reset: its state survives across the tokens of one
        request and is reset ONLY by the scheduler's admission-time
        `init_lane` — which is also what guarantees, for both kinds, a
        recycled lane can never observe its predecessor's state.

      paged: the caches are the paged KV pool (models.model
        `paged_cache_specs` layout) and the step takes a
        `models.attention.PagedKV` handle after ``sid`` — the per-lane
        page tables plus this token's (page, slot) write targets, both
        planned host-side by `serving.kvpool.KVPool.prepare_step`.
        Attention writes of exited/unoccupied lanes are redirected to
        the garbage page inside the decode (same visibility semantics
        as the ring path's masked writes).
      paged_kernel: trace the paged decode against the Pallas
        paged-attention kernel instead of the jnp page-table gather.
        The `attention.paged_kernel` contextvar is read at TRACE time,
        so this must be decided when the step is built — flipping the
        context manager around calls of an already-compiled step is a
        silent no-op.  Off by default: on CPU the kernel runs in
        interpret mode (correctness only); on TPU it is the hot path.
      node_offset: global id of this model's FIRST node — the multi-
        model cascade runtime (serving.cascade) builds one step per
        ladder model over ONE combined strategy bank, so each model's
        ramps/head must fold under their global node ids (model m's
        nodes are [offset, offset + n_m)).  The default 0 is the
        single-model case.
      walk_io: the step additionally takes a ``walk`` pair ``(active
        (B,) bool, best_logits (B, vocab) f32)`` as its LAST argument
        and returns the updated pair appended to its outputs — the
        ESCALATION HANDOFF BUFFER.  A lane still active after this
        model's head wants to probe a deeper ladder model; its walk
        state + served-so-far logits hand off to that model's step
        (possibly several steps later, after a catch-up prefill) so the
        cross-model walk serves exactly what a single fused program
        would have.
      resume_walk: (needs carry_state + walk_io) this step CONTINUES
        mid-token walks started on an earlier ladder model: the bank
        states arrive pre-folded and are NOT re-initialized at the
        token boundary (the first model's step already did that reset).
        Strategies with ``persistent = True`` are rejected — their
        cross-token state cannot also encode a mid-token handoff.
      prefill_slots: > 0 (paged mode only) grows the step with CHUNKED
        PREFILL co-scheduled with decode (DESIGN.md §9): the step takes
        a `models.attention.PrefillChunk` of up to ``prefill_slots``
        prompt tokens per admitting lane after ``states`` and, inside
        the SAME device program, runs the full-depth chunk sweep
        against the paged pool — no separate batch-1 prefill program,
        no extra host sync, decode lanes keep decoding.  Lanes whose
        chunk finishes the prompt (``chunk.emit``) get their first
        token (argmax of the final-position head logits) returned in
        ``next_tok`` — exactly what the stop-the-world admission would
        have seeded the lane with.

    Returns ``step(tok (B,) i32, caches, pos (B,) i32, occupied (B,)
    bool, sid (B,) i32[, kv][, states][, chunk]) -> (next_tok,
    new_caches, served_node, seg_batch, seg_policy[, states])`` — seg_*
    are int32 scalars counting this token's launched segments and
    per-lane probed segments.
    """
    import contextlib

    from repro.models.attention import paged_kernel as _paged_kernel_ctx
    from repro.strategy.base import reset_lanes
    strategies = tuple(_check_online(s) for s in strategies)
    kernel_ctx = (_paged_kernel_ctx if (paged and paged_kernel)
                  else contextlib.nullcontext)
    if prefill_slots and not paged:
        raise ValueError("prefill_slots needs the paged KV pool "
                         "(chunks are committed page by page)")
    if resume_walk:
        if not (carry_state and walk_io):
            raise ValueError("resume_walk continues a handed-off walk; "
                             "it needs carry_state and walk_io")
        for s in strategies:
            if getattr(s, "persistent", False):
                raise ValueError(
                    f"{type(s).__name__} is persistent — its cross-token "
                    "state cannot double as a mid-token walk handoff")

    def step(tok, caches, pos, occupied, sid, kv=None, states_in=None,
             chunk=None, walk=None):
        b = tok.shape[0]
        x = params["embed"]["table"][tok][:, None, :]
        if resume_walk:
            # mid-token continuation: the earlier ladder model's step
            # already reset + folded these states for this token
            states = states_in
        elif carry_state:
            # per-token exploration: every occupied lane starts this
            # token from a fresh state, sliced per lane so unoccupied
            # lanes' (stale, masked-out) leaves stay bit-stable.
            # `persistent` strategies keep their state across tokens
            # (admission's init_lane is their only reset).
            states = tuple(
                st if getattr(s, "persistent", False)
                else reset_lanes(s, st, occupied)
                for s, st in zip(strategies, states_in))
        else:
            states = tuple(s.init(b) for s in strategies)
        active = occupied
        best_logits = jnp.zeros((b, cfg.vocab), jnp.float32)
        if walk_io:
            # escalation handoff in: resume each lane's walk activity
            # and its best-served-so-far logits from the previous
            # ladder model's step
            walk_active, walk_best = walk
            active = occupied & walk_active
            best_logits = walk_best
        seg_batch = jnp.zeros((), jnp.int32)
        seg_policy = jnp.zeros((), jnp.int32)
        new_caches = list(caches)
        node = node_offset
        # context entered at TRACE time: selects which attention impl
        # (jnp gather vs Pallas kernel) gets traced into the program
        with kernel_ctx():
            for si, seg in enumerate(cfg.segments):
                seg_batch = seg_batch + active.any().astype(jnp.int32)
                seg_policy = seg_policy + active.sum(dtype=jnp.int32)

                def run(ops, si=si, node=node):
                    x, cache, states, act, best = ops
                    x2, nc, ro = M.decode_segment(
                        params, cfg, si, x, cache, pos,
                        paged=kv if paged else None,
                        write_mask=act if paged else None)
                    nc = _mask_lane_writes(nc, cache, act, paged=paged)
                    if ro is not None:
                        # ramp readout: serve-from-this-node logits for
                        # lanes whose served node is the current one (one
                        # head matmul via models.model.ramp_readout;
                        # recall refreshes happen via serve()'s argmin
                        # bookkeeping)
                        states, act, best = fold_readout(
                            strategies, states, node, *ro, act, sid, best)
                    return (x2, nc, states, act, best)

                ops = (x, caches[si], states, active, best_logits)
                x, new_caches[si], states, active, best_logits = \
                    jax.lax.cond(active.any(), run, lambda o: o, ops)
                if seg.ramp:
                    node += 1

        def run_head(ops):
            x, states, act, best = ops
            logits, ell = M.ramp_readout(params, cfg, x[:, 0, :])
            states, act, best = fold_readout(strategies, states, node,
                                             logits, ell, act, sid, best)
            return (x, states, act, best)

        ops = (x, states, active, best_logits)
        x, states, active, best_logits = jax.lax.cond(
            active.any(), run_head, lambda o: o, ops)

        next_tok = jnp.argmax(best_logits, axis=-1).astype(jnp.int32)

        if prefill_slots:
            # the co-scheduled prefill chunk: full-depth sweep over the
            # admitting lanes' chunk tokens, traced into the SAME
            # program — the whole step is still one device launch and
            # one host sync.  Decode above never touches these lanes
            # (occupied excludes them), so the only shared state is the
            # page pool, where writes land in disjoint pages.
            with kernel_ctx():
                def run_chunk(cs):
                    xc = params["embed"]["table"][chunk.tok]
                    cs = list(cs)
                    for si in range(len(cfg.segments)):
                        xc, cs[si] = M.prefill_chunk_segment(
                            params, cfg, si, xc, cs[si], kv.page_table,
                            chunk)
                    h = xc[jnp.arange(b), chunk.last_idx, :]
                    logits, _ = M.ramp_readout(params, cfg, h)
                    return (tuple(cs),
                            jnp.argmax(logits, axis=-1).astype(jnp.int32))

                def skip_chunk(cs):
                    return tuple(cs), jnp.zeros((b,), jnp.int32)

                chunk_caches, t0 = jax.lax.cond(
                    chunk.active.any(), run_chunk, skip_chunk,
                    tuple(new_caches))
            new_caches = list(chunk_caches)
            # finishing lanes: seed the lane with its first token, just
            # like the stop-the-world admission would have
            next_tok = jnp.where(chunk.emit, t0, next_tok)

        served = bank_serve(strategies, states, sid)
        out = (next_tok, new_caches, served, seg_batch, seg_policy)
        if carry_state:
            out = out + (states,)
        if walk_io:
            # handoff out: post-head `active` is exactly the escalation
            # signal — the lane's strategy wants to probe a node beyond
            # this model's ladder rung
            out = out + ((active, best_logits),)
        return out

    if not jit:
        return step
    if donate is None:
        donate = jax.default_backend() != "cpu"
    return jax.jit(step, donate_argnums=(1,) if donate else ())


class Engine:
    """Batched greedy-decode engine with per-token early exit."""

    def __init__(self, params, cfg: ModelConfig, strategy: Strategy,
                 cache_len: int, jit: bool = True):
        self.params = params
        self.cfg = cfg
        self.strategy = _check_online(strategy)
        self.cache_len = cache_len
        self.jit = bool(jit)
        self._step = make_token_step(params, cfg, (self.strategy,),
                                     jit=self.jit)

    def prefill(self, batch: dict):
        return M.prefill(self.params, self.cfg, batch, self.cache_len)

    def generate(self, batch: dict, n_tokens: int) -> GenerationStats:
        cfg = self.cfg
        logits, caches, _, pos = self.prefill(batch)
        b = logits.shape[0]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        occupied = jnp.ones((b,), bool)
        sid = jnp.zeros((b,), jnp.int32)
        out_tokens, out_nodes = [], []
        seg_batch = seg_policy = 0

        for _ in range(n_tokens):
            tok, caches, served, sb, sp = self._step(tok, caches, pos,
                                                     occupied, sid)
            # the ONLY host sync of the token: emitted tokens, served
            # nodes, and both segment counters in one transfer
            tok_h, served_h, sb_h, sp_h = jax.device_get(
                (tok, served, sb, sp))
            out_tokens.append(tok_h)
            out_nodes.append(served_h)
            seg_batch += int(sb_h)
            seg_policy += int(sp_h)
            pos = pos + 1

        return GenerationStats(
            tokens=np.stack(out_tokens, 1),
            served_nodes=np.stack(out_nodes, 1),
            segments_run_batch=seg_batch,
            segments_run_policy=seg_policy,
            segments_full=n_tokens * len(cfg.segments) * b,
        )


class Classifier:
    """Classification-mode serving — the paper's §6 experimental setting.

    One request = one input sequence; the prediction is read at the last
    position of a ramp (no decode loop).  The engine runs segment-by-
    segment over the PREFILL, consulting the strategy after each ramp,
    and serves whatever node ``strategy.serve`` designates.  This is
    Alg. 1 applied at the request level, where the latency saving is the
    skipped backbone depth.
    """

    def __init__(self, params, cfg: ModelConfig, strategy: Strategy):
        self.params = params
        self.cfg = cfg
        self.strategy = _check_online(strategy)

    def classify(self, batch: dict) -> dict:
        from repro.models.blocks import block_forward
        cfg = self.cfg
        params = self.params
        strategy = self.strategy
        x, positions = M._embed_inputs(params, cfg, batch)
        b = x.shape[0]
        state = strategy.init(b)
        active = jnp.ones((b,), bool)
        best_logits = jnp.zeros((b, cfg.vocab), jnp.float32)
        node = 0
        seg_run = seg_policy = 0
        n_seg = len(cfg.segments)
        for si, seg in enumerate(cfg.segments):
            if not bool(active.any()):
                break
            p_seg = params["segments"][si]["blocks"]

            def body(h, p_layer, seg=seg):
                y, _, _ = block_forward(p_layer, h, positions, seg.block,
                                        cfg.norm_eps)
                return y, None

            x, _ = jax.lax.scan(body, x, p_seg)
            seg_run += 1
            seg_policy += int(active.sum())
            if seg.ramp:
                # the engine's shared fold: observe, then refresh best
                # logits for lanes whose SERVED node is this ramp
                logits, loss = M.ramp_readout(params, cfg, x[:, -1, :],
                                              segment=si)
                (state,), active, best_logits = fold_readout(
                    (strategy,), (state,), node, logits, loss, active,
                    None, best_logits)
                node += 1
        if bool(active.any()):
            logits, loss = M.ramp_readout(params, cfg, x[:, -1, :])
            (state,), active, best_logits = fold_readout(
                (strategy,), (state,), node, logits, loss, active, None,
                best_logits)
        return {
            "labels": np.asarray(jnp.argmax(best_logits, axis=-1)),
            "served_node": np.asarray(strategy.serve(state)),
            "segments_run_batch": seg_run,
            "segments_run_policy": seg_policy,
            "segments_full": n_seg * b,
        }
