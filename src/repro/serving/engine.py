"""Segment-wise serving engine with T-Tamer early exit (the paper's
technique as a first-class serving feature — DESIGN.md §2-3).

The engine executes a decode step SEGMENT BY SEGMENT.  After every ramp
segment it:
  1. computes the loss proxy ell = 1 - confidence for each lane,
  2. hands it to the pluggable `Strategy` (``observe`` updates per-lane
     state and returns the mask of lanes continuing deeper), and
  3. serves, per lane, the logits of whatever node ``strategy.serve``
     designates — argmin ramp under recall, last probed without.

The engine holds NO policy logic of its own: any strategy from
``repro.strategy.make`` (recall index, thresholds, patience, skip
tables, ...) plugs in unchanged, and the same object reproduces its
offline ``strategy.evaluate`` decisions here (tested in
tests/test_system.py).  Strategies with ``online = False`` (the
hindsight oracles) are rejected — segments cannot be un-run.

TPU adaptation (DESIGN.md §3): lanes are fixed-shape; exited lanes are
masked, and the engine stops launching deeper segments once every lane has
exited ("batch-level" saving).  Per-lane policy FLOPs (what a
lane-granular runtime such as per-request dispatch would pay) are
accounted separately in the stats — both numbers are reported by the
serving benchmarks.

State skew: when a token exits early, deeper layers' KV/SSM caches are
simply not written for that position (the stored-position mask hides the
hole from later attention).  This is the standard early-exit cache policy
(cf. Apparate / DeeBERT serving) — a quality-for-latency approximation the
T-Tamer cost model already prices in via the calibration traces.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.strategy.base import Strategy

__all__ = ["Engine", "GenerationStats", "Classifier"]


def _check_online(strategy: Strategy) -> Strategy:
    if not getattr(strategy, "online", True):
        raise ValueError(
            f"{type(strategy).__name__} needs hindsight (online=False) and "
            "cannot drive the serving engine; use strategy.evaluate on "
            "offline traces instead")
    # the engine's aux channel carries predicted labels, NOT support bins
    # — a table strategy built without a Support would silently consume
    # them as bins, so refuse it here rather than serve garbage
    if hasattr(strategy, "support") and strategy.support is None:
        raise ValueError(
            f"{type(strategy).__name__} was built without a Support and "
            "reads bins from the aux channel; the engine supplies "
            "predictions there — construct it with the cascade's Support")
    return strategy


@dataclasses.dataclass
class GenerationStats:
    tokens: np.ndarray              # (B, T) generated tokens
    served_nodes: np.ndarray        # (B, T) which node served each token
    segments_run_batch: int         # segments actually launched (batch)
    segments_run_policy: int        # sum over lanes of nodes probed
    segments_full: int              # full-depth reference


class Engine:
    """Batched greedy-decode engine with per-token early exit."""

    def __init__(self, params, cfg: ModelConfig, strategy: Strategy,
                 cache_len: int, jit: bool = True):
        self.params = params
        self.cfg = cfg
        self.strategy = _check_online(strategy)
        self.cache_len = cache_len
        n_seg = len(cfg.segments)

        def seg_fn(si, x, cache_seg, pos):
            return M.decode_segment(params, cfg, si, x, cache_seg, pos)

        def embed_fn(tokens):
            return params["embed"]["table"][tokens][:, None, :]

        def head_fn(x):
            from repro.models.common import rms_norm
            final = rms_norm(params["final_norm"], x, cfg.norm_eps)
            logits = M.unembed(params, cfg, final)[:, 0]
            p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            return logits, 1.0 - p.max(axis=-1)

        if jit:
            self._seg = [jax.jit(lambda x, c, pos, si=si:
                                 seg_fn(si, x, c, pos))
                         for si in range(n_seg)]
            self._embed = jax.jit(embed_fn)
            self._head = jax.jit(head_fn)
        else:
            self._seg = [lambda x, c, pos, si=si: seg_fn(si, x, c, pos)
                         for si in range(n_seg)]
            self._embed = embed_fn
            self._head = head_fn

    def prefill(self, batch: dict):
        return M.prefill(self.params, self.cfg, batch, self.cache_len)

    def generate(self, batch: dict, n_tokens: int) -> GenerationStats:
        cfg = self.cfg
        strategy = self.strategy
        logits, caches, _, pos = self.prefill(batch)
        b = logits.shape[0]
        tok = jnp.argmax(logits, axis=-1)
        out_tokens, out_nodes = [], []
        seg_batch = seg_policy = 0
        n_seg = len(cfg.segments)

        for _ in range(n_tokens):
            state = strategy.init(b)
            x = self._embed(tok)
            active = jnp.ones((b,), bool)
            best_logits = jnp.zeros((b, cfg.vocab), jnp.float32)
            node = 0
            new_caches = list(caches)
            for si in range(n_seg):
                # skip the remaining depth once every lane has exited
                if not bool(active.any()):
                    break
                x, new_caches[si], conf = self._seg[si](x, caches[si], pos)
                seg_batch += 1
                seg_policy += int(active.sum())
                if conf is not None:
                    # serve-from-this-node logits for lanes whose served
                    # node is the current one (the ramp head shares the
                    # unembedding, so materializing them is one head
                    # matmul; recall refreshes happen via serve()'s
                    # argmin bookkeeping, no isinstance dispatch)
                    from repro.models.common import rms_norm
                    rp = self.params["segments"][si]["ramp"]
                    h = rms_norm(rp["norm"], x[:, 0, :], cfg.norm_eps)
                    node_logits = M.unembed(self.params, cfg,
                                            h[:, None, :])[:, 0]
                    preds = jnp.argmax(node_logits, axis=-1)
                    state, active = strategy.observe(
                        state, node, conf, active,
                        aux=preds.astype(jnp.int32))
                    take = strategy.serve(state) == node
                    best_logits = jnp.where(take[:, None],
                                            node_logits.astype(jnp.float32),
                                            best_logits)
                    node += 1
            if bool(active.any()):
                # final head node (for lanes still active)
                final_logits, final_loss = self._head(x)
                preds = jnp.argmax(final_logits, axis=-1)
                state, active = strategy.observe(
                    state, node, final_loss, active,
                    aux=preds.astype(jnp.int32))
                take = strategy.serve(state) == node
                best_logits = jnp.where(take[:, None],
                                        final_logits.astype(jnp.float32),
                                        best_logits)
            caches = new_caches
            tok = jnp.argmax(best_logits, axis=-1)
            out_tokens.append(np.asarray(tok))
            out_nodes.append(np.asarray(strategy.serve(state)))
            pos = pos + 1

        return GenerationStats(
            tokens=np.stack(out_tokens, 1),
            served_nodes=np.stack(out_nodes, 1),
            segments_run_batch=seg_batch,
            segments_run_policy=seg_policy,
            segments_full=n_tokens * n_seg * b,
        )


class Classifier:
    """Classification-mode serving — the paper's §6 experimental setting.

    One request = one input sequence; the prediction is read at the last
    position of a ramp (no decode loop).  The engine runs segment-by-
    segment over the PREFILL, consulting the strategy after each ramp,
    and serves whatever node ``strategy.serve`` designates.  This is
    Alg. 1 applied at the request level, where the latency saving is the
    skipped backbone depth.
    """

    def __init__(self, params, cfg: ModelConfig, strategy: Strategy):
        self.params = params
        self.cfg = cfg
        self.strategy = _check_online(strategy)

    def classify(self, batch: dict) -> dict:
        from repro.models.blocks import block_forward
        from repro.models.common import rms_norm
        cfg = self.cfg
        params = self.params
        strategy = self.strategy
        x, positions = M._embed_inputs(params, cfg, batch)
        b = x.shape[0]
        state = strategy.init(b)
        active = jnp.ones((b,), bool)
        best_logits = jnp.zeros((b, cfg.vocab), jnp.float32)
        node = 0
        seg_run = seg_policy = 0
        n_seg = len(cfg.segments)
        for si, seg in enumerate(cfg.segments):
            if not bool(active.any()):
                break
            p_seg = params["segments"][si]["blocks"]

            def body(h, p_layer, seg=seg):
                y, _, _ = block_forward(p_layer, h, positions, seg.block,
                                        cfg.norm_eps)
                return y, None

            x, _ = jax.lax.scan(body, x, p_seg)
            seg_run += 1
            seg_policy += int(active.sum())
            if seg.ramp:
                rp = params["segments"][si]["ramp"]
                h = rms_norm(rp["norm"], x[:, -1, :], cfg.norm_eps)
                logits = M.unembed(params, cfg, h[:, None, :])[:, 0]
                probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
                loss = 1.0 - probs.max(axis=-1)
                preds = jnp.argmax(logits, axis=-1)
                state, active = strategy.observe(
                    state, node, loss, active, aux=preds.astype(jnp.int32))
                # post-observe serve() mask: only lanes whose SERVED node
                # is this ramp refresh — an earlier-exited lane's logits
                # are never overwritten by deeper ramps or the head
                take = strategy.serve(state) == node
                best_logits = jnp.where(take[:, None],
                                        logits.astype(jnp.float32),
                                        best_logits)
                node += 1
        if bool(active.any()):
            final = rms_norm(params["final_norm"], x[:, -1:, :],
                             cfg.norm_eps)
            logits = M.unembed(params, cfg, final)[:, 0]
            probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
            preds = jnp.argmax(logits, axis=-1)
            state, active = strategy.observe(
                state, node, 1.0 - probs.max(-1), active,
                aux=preds.astype(jnp.int32))
            take = strategy.serve(state) == node
            best_logits = jnp.where(take[:, None],
                                    logits.astype(jnp.float32), best_logits)
        return {
            "labels": np.asarray(jnp.argmax(best_logits, axis=-1)),
            "served_node": np.asarray(strategy.serve(state)),
            "segments_run_batch": seg_run,
            "segments_run_policy": seg_policy,
            "segments_full": n_seg * b,
        }
