"""Segment-wise serving engine with T-Tamer early exit (the paper's
technique as a first-class serving feature — DESIGN.md §2).

The engine executes a decode step SEGMENT BY SEGMENT.  After every ramp
segment it:
  1. computes the loss proxy ell = 1 - confidence for each lane,
  2. quantizes it on the calibrated support,
  3. gathers the if-stop decision from the precomputed T-Tamer table
     (O(1)/lane, Thm 4.5), and
  4. records exits.  With RECALL, an exiting lane serves the logits of its
     best (argmin-loss) ramp so far, not the ramp it exited at.

TPU adaptation (DESIGN.md §3): lanes are fixed-shape; exited lanes are
masked, and the engine stops launching deeper segments once every lane has
exited ("batch-level" saving).  Per-lane policy FLOPs (what a
lane-granular runtime such as per-request dispatch would pay) are
accounted separately in the stats — both numbers are reported by the
serving benchmarks.

State skew: when a token exits early, deeper layers' KV/SSM caches are
simply not written for that position (the stored-position mask hides the
hole from later attention).  This is the standard early-exit cache policy
(cf. Apparate / DeeBERT serving) — a quality-for-latency approximation the
T-Tamer cost model already prices in via the calibration traces.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.line_dp import LineTables
from repro.core.support import Support, quantize
from repro.models import model as M
from repro.models.config import ModelConfig

__all__ = ["EnginePolicy", "RecallIndexPolicy", "ThresholdPolicy",
           "Engine", "GenerationStats", "Classifier"]


class EnginePolicy:
    """Per-segment stop/continue + which ramp to serve."""

    n_nodes: int

    def reset(self, batch: int):
        raise NotImplementedError

    def observe(self, node: int, losses: jax.Array, active: jax.Array):
        """Update state with node losses; returns updated active mask of
        lanes that should CONTINUE past this node."""
        raise NotImplementedError

    def served_node(self) -> jax.Array:
        raise NotImplementedError


class RecallIndexPolicy(EnginePolicy):
    """The paper's Alg. 1, vectorized over lanes."""

    def __init__(self, tables: LineTables, support: Support,
                 lam: float = 0.5):
        self.tables = tables
        self.support = support
        self.lam = lam
        self.n_nodes = tables.n

    def reset(self, batch: int):
        k = self.tables.k
        self._x_idx = jnp.full((batch,), k + 1, jnp.int32)
        self._s_bin = jnp.zeros((batch,), jnp.int32)
        self._best_loss = jnp.full((batch,), jnp.inf, jnp.float32)
        self._best_node = jnp.zeros((batch,), jnp.int32)

    def observe(self, node: int, losses: jax.Array, active: jax.Array):
        scaled = self.lam * losses
        b = quantize(self.support, scaled)
        better = active & (scaled < self._best_loss)
        self._best_loss = jnp.where(better, scaled, self._best_loss)
        self._best_node = jnp.where(better, node, self._best_node)
        self._x_idx = jnp.where(active, jnp.minimum(self._x_idx, b + 1),
                                self._x_idx)
        self._s_bin = jnp.where(active, b, self._s_bin)
        if node + 1 >= self.n_nodes:
            return jnp.zeros_like(active)
        stop_next = self.tables.stop[node + 1, self._s_bin, self._x_idx]
        return active & ~stop_next

    def served_node(self) -> jax.Array:
        return self._best_node      # RECALL: argmin ramp


class ThresholdPolicy(EnginePolicy):
    """Confidence-threshold baseline (DeeBERT-style, no recall)."""

    def __init__(self, n_nodes: int, threshold: float):
        self.n_nodes = n_nodes
        self.threshold = threshold

    def reset(self, batch: int):
        self._last_node = jnp.zeros((batch,), jnp.int32)

    def observe(self, node: int, losses: jax.Array, active: jax.Array):
        self._last_node = jnp.where(active, node, self._last_node)
        if node + 1 >= self.n_nodes:
            return jnp.zeros_like(active)
        return active & (losses > self.threshold)

    def served_node(self) -> jax.Array:
        return self._last_node      # NO recall: last inspected


@dataclasses.dataclass
class GenerationStats:
    tokens: np.ndarray              # (B, T) generated tokens
    served_nodes: np.ndarray        # (B, T) which node served each token
    segments_run_batch: int         # segments actually launched (batch)
    segments_run_policy: int        # sum over lanes of nodes probed
    segments_full: int              # full-depth reference


class Engine:
    """Batched greedy-decode engine with per-token early exit."""

    def __init__(self, params, cfg: ModelConfig, policy: EnginePolicy,
                 cache_len: int, jit: bool = True):
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.cache_len = cache_len
        self._ramp_segments = [i for i, s in enumerate(cfg.segments)
                               if s.ramp]
        n_seg = len(cfg.segments)

        def seg_fn(si, x, cache_seg, pos):
            return M.decode_segment(params, cfg, si, x, cache_seg, pos)

        def embed_fn(tokens):
            return params["embed"]["table"][tokens][:, None, :]

        def head_fn(x):
            from repro.models.common import rms_norm
            final = rms_norm(params["final_norm"], x, cfg.norm_eps)
            logits = M.unembed(params, cfg, final)[:, 0]
            p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            return logits, 1.0 - p.max(axis=-1)

        if jit:
            self._seg = [jax.jit(lambda x, c, pos, si=si:
                                 seg_fn(si, x, c, pos))
                         for si in range(n_seg)]
            self._embed = jax.jit(embed_fn)
            self._head = jax.jit(head_fn)
        else:
            self._seg = [lambda x, c, pos, si=si: seg_fn(si, x, c, pos)
                         for si in range(n_seg)]
            self._embed = embed_fn
            self._head = head_fn

    def prefill(self, batch: dict):
        return M.prefill(self.params, self.cfg, batch, self.cache_len)

    def generate(self, batch: dict, n_tokens: int) -> GenerationStats:
        cfg = self.cfg
        logits, caches, _, pos = self.prefill(batch)
        b = logits.shape[0]
        tok = jnp.argmax(logits, axis=-1)
        out_tokens, out_nodes = [], []
        seg_batch = seg_policy = 0
        n_seg = len(cfg.segments)
        n_nodes = cfg.n_ramps + 1

        for _ in range(n_tokens):
            self.policy.reset(b)
            x = self._embed(tok)
            active = jnp.ones((b,), bool)
            best_logits = jnp.zeros((b, cfg.vocab), jnp.float32)
            have_logits = jnp.zeros((b,), bool)
            node = 0
            new_caches = list(caches)
            for si in range(n_seg):
                # skip the remaining depth once every lane has exited
                if not bool(active.any()):
                    break
                x, new_caches[si], conf = self._seg[si](x, caches[si], pos)
                seg_batch += 1
                seg_policy += int(active.sum())
                if conf is not None:
                    # serve-from-this-node logits for lanes that stop here
                    # (recall handled by policy's best_node bookkeeping at
                    # the logits level: we materialize node logits lazily —
                    # the ramp head shares the unembedding, so recompute
                    # for the argmin node is one extra head matmul)
                    from repro.models.common import rms_norm
                    rp = self.params["segments"][si]["ramp"]
                    h = rms_norm(rp["norm"], x[:, 0, :], cfg.norm_eps)
                    node_logits = M.unembed(self.params, cfg,
                                            h[:, None, :])[:, 0]
                    prev_active = active
                    active = self.policy.observe(node, conf, active)
                    # lanes whose best node is the current one refresh
                    best_now = (self.policy.served_node() == node) \
                        if isinstance(self.policy, RecallIndexPolicy) \
                        else (prev_active & ~active)
                    best_logits = jnp.where(best_now[:, None],
                                            node_logits.astype(jnp.float32),
                                            best_logits)
                    have_logits = have_logits | best_now
                    node += 1
            if bool(active.any()):
                # final head node (for lanes still active)
                final_logits, final_loss = self._head(x)
                prev_active = active
                active = self.policy.observe(node, final_loss, active)
                take_final = (self.policy.served_node() == node) \
                    if isinstance(self.policy, RecallIndexPolicy) \
                    else prev_active
                best_logits = jnp.where(take_final[:, None],
                                        final_logits.astype(jnp.float32),
                                        best_logits)
                have_logits = have_logits | take_final
            caches = new_caches
            tok = jnp.argmax(best_logits, axis=-1)
            out_tokens.append(np.asarray(tok))
            out_nodes.append(np.asarray(self.policy.served_node()))
            pos = pos + 1

        return GenerationStats(
            tokens=np.stack(out_tokens, 1),
            served_nodes=np.stack(out_nodes, 1),
            segments_run_batch=seg_batch,
            segments_run_policy=seg_policy,
            segments_full=n_tokens * n_seg * b,
        )


class Classifier:
    """Classification-mode serving — the paper's §6 experimental setting.

    One request = one input sequence; the prediction is read at the last
    position of a ramp (no decode loop).  The engine runs segment-by-
    segment over the PREFILL, consulting the T-Tamer if-stop table after
    each ramp, and serves the argmin-loss ramp's label (recall).  This is
    Alg. 1 applied at the request level, where the latency saving is the
    skipped backbone depth.
    """

    def __init__(self, params, cfg: ModelConfig, policy: EnginePolicy):
        self.params = params
        self.cfg = cfg
        self.policy = policy

    def classify(self, batch: dict) -> dict:
        from repro.models.blocks import block_forward
        from repro.models.common import rms_norm
        cfg = self.cfg
        params = self.params
        x, positions = M._embed_inputs(params, cfg, batch)
        b = x.shape[0]
        self.policy.reset(b)
        active = jnp.ones((b,), bool)
        best_logits = jnp.zeros((b, cfg.vocab), jnp.float32)
        node = 0
        seg_run = seg_policy = 0
        n_seg = len(cfg.segments)
        for si, seg in enumerate(cfg.segments):
            if not bool(active.any()):
                break
            p_seg = params["segments"][si]["blocks"]

            def body(h, p_layer, seg=seg):
                y, _, _ = block_forward(p_layer, h, positions, seg.block,
                                        cfg.norm_eps)
                return y, None

            x, _ = jax.lax.scan(body, x, p_seg)
            seg_run += 1
            seg_policy += int(active.sum())
            if seg.ramp:
                rp = params["segments"][si]["ramp"]
                h = rms_norm(rp["norm"], x[:, -1, :], cfg.norm_eps)
                logits = M.unembed(params, cfg, h[:, None, :])[:, 0]
                probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
                loss = 1.0 - probs.max(axis=-1)
                active = self.policy.observe(node, loss, active)
                take = (self.policy.served_node() == node) \
                    if isinstance(self.policy, RecallIndexPolicy) else \
                    (~active)
                best_logits = jnp.where(take[:, None],
                                        logits.astype(jnp.float32),
                                        best_logits)
                node += 1
        if bool(active.any()):
            final = rms_norm(params["final_norm"], x[:, -1:, :],
                             cfg.norm_eps)
            logits = M.unembed(params, cfg, final)[:, 0]
            probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
            active2 = self.policy.observe(node, 1.0 - probs.max(-1), active)
            take = (self.policy.served_node() == node) \
                if isinstance(self.policy, RecallIndexPolicy) else active
            best_logits = jnp.where(take[:, None],
                                    logits.astype(jnp.float32), best_logits)
        return {
            "labels": np.asarray(jnp.argmax(best_logits, axis=-1)),
            "served_node": np.asarray(self.policy.served_node()),
            "segments_run_batch": seg_run,
            "segments_run_policy": seg_policy,
            "segments_full": n_seg * b,
        }
