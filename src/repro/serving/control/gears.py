"""Load-indexed gear plans (DESIGN.md §11).

A **gear** is one complete serving configuration: a T-Tamer strategy
(the provably-optimal stop/skip policy for its lambda) plus the host
knobs that accompany it — cascade escalate policy patience, chunked-
prefill budget, escalation lane split.  `GearPlanner` precomputes a
BANK of gears offline from calibration traces, prices each one with
the same cost model the simulation charges, and indexes them by the
arrival rate they can sustain:

    work      = expected node-equivalents per token (probes for walk
                strategies; objective explore cost / per-node cost for
                jump strategies, so a skipped-but-still-computed
                backbone under cumulative edge costs is priced in)
    tok/s     = n_lanes / (overhead + seg_time * work)
    max_rate  = utilization * tok/s / mean_tokens     [requests/sec]

`GearBank` orders gears QUALITY-FIRST (most work, lowest loss, first)
so ``slot_for_rate`` degrades monotonically: serve the best gear whose
capacity covers the observed load, falling back to the cheapest gear
when even it is saturated.  The bank's order fixes the strategy-bank
slot layout the stepper traces over — slots never move after that; the
control plane only changes which slot new admissions use and what
tables live inside a slot.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.strategy.base import evaluate
from repro.strategy.cascade import Cascade
from repro.strategy.registry import make as make_strategy

__all__ = ["GearSpec", "Gear", "GearBank", "GearPlanner"]


@dataclasses.dataclass(frozen=True)
class GearSpec:
    """Declarative gear: the lambda point + host knobs."""

    name: str
    lam: float
    strategy: str = "skip_recall"
    kwargs: dict = dataclasses.field(default_factory=dict)
    patience: int | None = None          # cascade de-escalation window
    prefill_budget: int | None = None    # chunked-prefill tokens/step
    esc_budgets: tuple | None = None     # per-model catch-up budgets
    lane_split: tuple | None = None      # per-rung escalation lane caps

    def __post_init__(self):
        if not 0.0 < self.lam <= 1.0:
            raise ValueError(f"gear {self.name!r}: lam must be in (0, 1], "
                             f"got {self.lam}")


@dataclasses.dataclass
class Gear:
    """A solved gear: spec + strategy + its priced capacity."""

    spec: GearSpec
    cascade: Cascade
    strategy: object
    work: float          # expected node-equivalents per token
    est_loss: float      # holdout mean served loss, RAW units
    max_rate: float      # sustainable requests/sec
    slot: int = -1       # strategy-bank slot (assigned by GearBank)

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def prefill_budget(self):
        return self.spec.prefill_budget

    @property
    def patience(self):
        return self.spec.patience

    @property
    def esc_budgets(self):
        return self.spec.esc_budgets

    @property
    def lane_split(self):
        return self.spec.lane_split

    def describe(self) -> dict:
        return {"name": self.name, "slot": self.slot,
                "lam": self.spec.lam, "strategy": self.spec.strategy,
                "work": self.work, "est_loss": self.est_loss,
                "max_rate": self.max_rate}


class GearBank:
    """Quality-first ordered gears; order == strategy-bank slot layout."""

    def __init__(self, gears):
        gears = list(gears)
        if not gears:
            raise ValueError("a gear bank needs at least one gear")
        names = [g.name for g in gears]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate gear names: {names}")
        # most work first = best quality first; loss breaks ties
        gears.sort(key=lambda g: (-g.work, g.est_loss))
        for slot, g in enumerate(gears):
            g.slot = slot
        self.gears = gears

    def __len__(self) -> int:
        return len(self.gears)

    def __iter__(self):
        return iter(self.gears)

    def __getitem__(self, slot: int) -> Gear:
        return self.gears[slot]

    def by_name(self, name: str) -> Gear:
        for g in self.gears:
            if g.name == name:
                return g
        raise KeyError(f"no gear named {name!r}; have "
                       f"{[g.name for g in self.gears]}")

    @property
    def strategies(self) -> tuple:
        """Slot-ordered strategy tuple — what the stepper traces over."""
        return tuple(g.strategy for g in self.gears)

    @property
    def rate_thresholds(self) -> list[float]:
        """Ascending capacity edges for telemetry's ``load_level``."""
        return sorted(g.max_rate for g in self.gears)

    def slot_for_rate(self, rate: float) -> int:
        """Best (highest-quality) gear whose capacity covers ``rate``;
        the cheapest gear when nothing does (graceful saturation)."""
        for g in self.gears:
            if g.max_rate >= rate:
                return g.slot
        return self.gears[-1].slot

    def describe(self) -> list[dict]:
        return [g.describe() for g in self.gears]


class GearPlanner:
    """Offline gear solver against calibration traces.

    ``losses``: (T, n) RAW per-node calibration losses; a trailing
    ``holdout`` fraction is held out of table fitting and used to price
    each gear's work/loss — the same split keeps capacity estimates
    honest about generalization.  ``node_costs``: (n,) per-node compute
    in FLOP-fraction units (each gear's objective costs are
    ``(1 - lam) * node_costs``, matching the offline sweeps).
    """

    def __init__(self, losses, node_costs, *, k: int = 16,
                 seg_time: float, overhead: float, n_lanes: int,
                 mean_tokens: float, utilization: float = 0.85,
                 holdout: float = 0.25, boundaries=None,
                 entry_costs=None):
        losses = np.asarray(losses, np.float64)
        if losses.ndim != 2:
            raise ValueError(f"losses must be (T, n), got {losses.shape}")
        n_hold = max(1, int(round(losses.shape[0] * float(holdout))))
        if n_hold >= losses.shape[0]:
            raise ValueError("holdout fraction leaves no fitting rows")
        self.fit_losses = losses[:-n_hold]
        self.holdout_losses = losses[-n_hold:]
        self.node_costs = np.asarray(node_costs, np.float64)
        if self.node_costs.shape != (losses.shape[1],):
            raise ValueError(f"node_costs shape {self.node_costs.shape} "
                             f"vs {losses.shape[1]} trace columns")
        self.k = int(k)
        self.seg_time = float(seg_time)
        self.overhead = float(overhead)
        self.n_lanes = int(n_lanes)
        self.mean_tokens = float(mean_tokens)
        self.utilization = float(utilization)
        self.boundaries = boundaries
        self.entry_costs = entry_costs

    def solve(self, spec: GearSpec) -> Gear:
        """Calibrate + solve one gear and price it on the holdout."""
        cascade = Cascade.from_traces(
            self.fit_losses, (1.0 - spec.lam) * self.node_costs,
            k=self.k, lam=spec.lam, solve=False,
            boundaries=self.boundaries, entry_costs=self.entry_costs)
        strategy = make_strategy(spec.strategy, cascade, **spec.kwargs)
        work, est_loss = self.price(strategy, cascade)
        return Gear(spec=spec, cascade=cascade, strategy=strategy,
                    work=work, est_loss=est_loss,
                    max_rate=self.rate_for_work(work))

    def price(self, strategy, cascade: Cascade,
              losses=None) -> tuple[float, float]:
        """(work, raw mean served loss) of a strategy on held-out rows.

        ``work`` is the mean number of PROBED nodes per token — exactly
        what the runtime `SimStepper` charges a lane per step (its
        ``policy`` counter sums active lanes per node), so
        ``rate_for_work`` prices capacity in the units the serve clock
        pays.  Jump strategies' objective explore cost (which also
        bills the skipped-but-computed backbone under cumulative edge
        costs) is deliberately NOT used: the replay sim only executes
        observed nodes, and a capacity estimate must match the executor
        it gates.
        """
        rows = self.holdout_losses if losses is None else np.asarray(losses)
        res = evaluate(strategy, rows.astype(np.float32))
        work = float(np.mean(np.asarray(res.n_probed)))
        est_loss = float(np.mean(np.asarray(res.served_loss))) / strategy.lam
        return work, est_loss

    def rate_for_work(self, work: float) -> float:
        """Sustainable requests/sec at a given per-token work level."""
        tok_s = self.n_lanes / (self.overhead + self.seg_time * work)
        return self.utilization * tok_s / self.mean_tokens

    def plan(self, specs) -> GearBank:
        """Solve every spec into a quality-first `GearBank`."""
        return GearBank([self.solve(s) for s in specs])
