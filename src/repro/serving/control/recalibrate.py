"""`Recalibrator` — online re-fit of the cascade tables (DESIGN.md §11).

The offline tables are only as good as the calibration distribution;
when the served traffic drifts (harder prompts, different overthinking
mix), a frozen gear keeps probing where the VALUE function says losses
used to improve, and its real capacity quietly collapses.  This object
closes that gap without ever touching the hot path:

  * the stepper's ``row_tap`` streams observed (per-node raw losses,
    served node) outcomes into a bounded row window + per-node serve
    histogram — O(1) per token, host-side;
  * every ``interval`` of serve time (and once at least ``min_rows``
    rows have accumulated), `recalibrate` re-fits EVERY gear's
    `Cascade` from the observed rows (`Cascade.refit` — same lambda,
    same support size, so tables come back shape-identical), rebuilds
    each gear's strategy through the registry, and publishes it into
    its reserved `BankSwap` slot;
  * with a `GearPlanner` attached, each gear's work/capacity estimate
    is re-priced on the observed rows too, so ``slot_for_rate`` tracks
    what the gears can REALLY sustain now, not what the stale
    calibration promised.

The re-solve runs on the host between steps (a few line/skip DPs over a
(k, n) grid — microseconds next to a token step); the publish is the
`BankSwap` array swap, guaranteed retrace-free by the slot signature.
"""

from __future__ import annotations

import collections

import numpy as np

from repro.serving.control.gears import GearBank, GearPlanner
from repro.serving.control.swap import BankSwap
from repro.strategy.registry import make as make_strategy

__all__ = ["Recalibrator"]


class Recalibrator:
    """Streaming outcome window + periodic re-fit/publish."""

    def __init__(self, bank: GearBank, swap: BankSwap, *,
                 interval: float, min_rows: int = 256,
                 max_rows: int = 4096, planner: GearPlanner | None = None):
        if not interval > 0:
            raise ValueError(f"interval must be > 0, got {interval}")
        if min_rows < 2:
            raise ValueError("min_rows must be >= 2 (chain fitting "
                             "needs consecutive rows)")
        self.bank = bank
        self.swap = swap
        self.interval = float(interval)
        self.min_rows = int(min_rows)
        self.planner = planner
        self._rows: collections.deque = collections.deque(
            maxlen=int(max_rows))
        n = bank[0].cascade.n_nodes
        self.node_counts = np.zeros(n, np.int64)   # served-node histogram
        self.last = 0.0
        self.recals = 0
        self.events: list[dict] = []

    # ---- streaming feed (flushed from row_tap at step boundaries) ----

    def observe(self, rows, served=None) -> None:
        """Fold a batch of observed outcomes: ``rows`` (B, n) RAW
        per-node losses, ``served`` (B,) served node indices."""
        rows = np.asarray(rows, np.float64)
        for row in rows:
            self._rows.append(row)
        if served is not None:
            np.add.at(self.node_counts, np.asarray(served, np.int64), 1)

    @property
    def n_rows(self) -> int:
        return len(self._rows)

    # ---- the periodic re-solve ---------------------------------------

    def due(self, now: float) -> bool:
        return (float(now) - self.last >= self.interval
                and self.n_rows >= self.min_rows)

    def recalibrate(self, now: float) -> int:
        """Re-fit every gear from the observed row window and publish
        the rebuilt strategies into their reserved slots.  Returns the
        number of slots published; records an event either way."""
        rows = np.stack(tuple(self._rows))
        published = 0
        for gear in self.bank:
            casc = gear.cascade.refit(rows)
            strategy = make_strategy(gear.spec.strategy, casc,
                                     **gear.spec.kwargs)
            self.swap.publish(gear.slot, strategy, now)
            gear.cascade = casc
            gear.strategy = strategy
            if self.planner is not None:
                gear.work, gear.est_loss = self.planner.price(
                    strategy, casc, losses=rows)
                gear.max_rate = self.planner.rate_for_work(gear.work)
            published += 1
        self.last = float(now)
        self.recals += 1
        self.events.append({
            "t": float(now), "rows": int(rows.shape[0]),
            "published": published,
            "gears": self.bank.describe(),
        })
        return published
