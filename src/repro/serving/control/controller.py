"""`AdaptiveController` — the control loop the `Server` drives
(DESIGN.md §11).

Wiring (all three hooks are host-side and step-synchronous):

  * ``begin(metrics, stepper)`` — binds the telemetry window to the
    run's metrics, installs the `BankSwap` as the stepper's
    ``bank_source`` (the per-step array feed) and taps the stepper's
    observed outcomes for the `Recalibrator`.  Steppers without a
    ``bank_source`` attribute (a bare engine stepper) still get gear
    SWITCHING — admission routing via ``sid_of`` and host knobs via
    ``apply_gear`` — but online recalibration is disabled for them.
  * ``on_arrivals(times)`` — feeds the load signal.
  * ``on_step_end(now, queue_depth)`` — the decision point, called at
    the one instant no token step is in flight: flush tapped outcome
    rows, read the telemetry, pick the gear for the observed arrival
    rate (with ``hold``-step hysteresis so a single noisy window never
    thrashes the bank), land at most one swap, and run a due
    recalibration.

Swaps are atomic by construction: they land between steps, in-flight
lanes keep their admitted ``sid``, and publishes are signature-guarded
array exchanges — the swap-safety tests pin all three properties.
"""

from __future__ import annotations

import numpy as np

from repro.serving.control.gears import GearBank, GearPlanner
from repro.serving.control.recalibrate import Recalibrator
from repro.serving.control.swap import BankSwap
from repro.serving.control.telemetry import TelemetryWindow

__all__ = ["AdaptiveController"]


class AdaptiveController:
    """Telemetry -> gear selection -> swap/publish, between steps."""

    # observability plane (DESIGN.md §12): the server installs the
    # tracer; gear switches and recalibrations land as events so the
    # flight recorder can catch gear thrash
    tracer = None

    def __init__(self, bank: GearBank, *, span: float,
                 slo: float | None = None, hold: int = 3,
                 lead: float = 0.0,
                 recal_interval: float | None = None,
                 recal_min_rows: int = 256, recal_max_rows: int = 4096,
                 planner: GearPlanner | None = None,
                 start: int | None = None):
        if hold < 1:
            raise ValueError("hold must be >= 1")
        self.bank = bank
        # default start: the best gear (slot 0) — an idle server serves
        # quality; load pushes it down the bank
        self.swap = BankSwap(bank.strategies,
                             start=0 if start is None else int(start))
        self.telemetry = TelemetryWindow(span, slo=slo)
        self.hold = int(hold)
        # slope lead-time: a trailing-window rate estimate LAGS a ramp
        # by ~span/2, so on a steep diurnal rise the controller would
        # hold a near-saturated gear until the queue already pays for
        # it.  Projecting the rate forward by ``lead`` seconds of the
        # measured slope (rising side only — falling ramps err toward
        # the cheaper gear, the SLO-safe direction) turns the
        # inflection DETECTOR into the inflection REACTION.
        self.lead = float(lead)
        self.recal: Recalibrator | None = None
        self._recal_cfg = None
        if recal_interval is not None:
            self._recal_cfg = (float(recal_interval), int(recal_min_rows),
                               int(recal_max_rows), planner)
        self.stepper = None
        self._row_buf: list = []
        self._want: int | None = None
        self._streak = 0

    # ---- lifecycle (Server hooks) ------------------------------------

    def begin(self, metrics, stepper) -> None:
        self.telemetry.bind(metrics)
        self.stepper = stepper
        if hasattr(stepper, "bank_source"):
            stepper.bank_source = self.swap
            stepper.row_tap = self._tap
            if self._recal_cfg is not None:
                interval, min_rows, max_rows, planner = self._recal_cfg
                self.recal = Recalibrator(
                    self.bank, self.swap, interval=interval,
                    min_rows=min_rows, max_rows=max_rows, planner=planner)
        # engine-style steppers without a bank_source: gear switching
        # only (sid routing + host knobs); no online recalibration
        self._apply(self.bank[self.swap.gear])

    def sid_of(self, req) -> int:
        """Admission-time routing — pass this as the Server's sid_of."""
        return self.swap.sid_of(req)

    def on_arrivals(self, times) -> None:
        self.telemetry.on_arrivals(times)

    def _tap(self, losses, served) -> None:
        # called mid-step from the stepper; buffer only — all folding
        # happens at the step boundary
        self._row_buf.append((losses, served))

    def on_step_end(self, now: float, queue_depth: int) -> None:
        if self._row_buf:
            for losses, served in self._row_buf:
                picked = losses[np.arange(len(served)), served]
                self.telemetry.on_losses(now, picked)
                if self.recal is not None:
                    self.recal.observe(losses, served)
            self._row_buf.clear()
        esc = getattr(self.stepper, "esc", None)
        self.telemetry.on_gauges(
            queue_depth=queue_depth,
            escalations=sum(esc.lanes_in_use(m)
                            for m in range(1, len(esc.bank)))
            if esc is not None else 0)
        self._select_gear(now)
        if self.recal is not None and self.recal.due(now):
            n_rows = self.recal.n_rows
            self.recal.recalibrate(now)
            if self.tracer is not None:
                self.tracer.emit("recal", t=now, n_rows=n_rows)

    # ---- gear selection ----------------------------------------------

    def _select_gear(self, now: float) -> None:
        rate = self.telemetry.arrival_rate(now)
        if self.lead > 0.0:
            rate += self.lead * max(self.telemetry.rate_slope(now), 0.0)
        want = self.bank.slot_for_rate(rate)
        if want == self.swap.gear:
            self._want, self._streak = None, 0
            return
        if want == self._want:
            self._streak += 1
        else:
            self._want, self._streak = want, 1
        if self._streak >= self.hold:
            prev = self.swap.gear
            self.swap.swap_to(want, now)
            self._apply(self.bank[want])
            if self.tracer is not None:
                self.tracer.emit(
                    "gear_switch", t=now, src=int(prev), dst=int(want),
                    src_name=self.bank[prev].name,
                    dst_name=self.bank[want].name)
            self._want, self._streak = None, 0

    def _apply(self, gear) -> None:
        apply = getattr(self.stepper, "apply_gear", None)
        if apply is not None:
            apply(gear)

    # ---- reporting ---------------------------------------------------

    @property
    def gear(self):
        """The currently active gear."""
        return self.bank[self.swap.gear]

    def gear_name_of(self, slot: int) -> str:
        """Gear label for a strategy-bank slot — the Pareto frontier's
        per-gear attribution reads routing off the active gear's name
        rather than the raw slot index."""
        return self.bank[int(slot)].name

    def stats(self) -> dict:
        return {
            "gear": self.gear.name,
            "gear_switches": len(self.swap.switches),
            "switches": [
                {"t": t, "from": self.bank[a].name, "to": self.bank[b].name}
                for t, a, b in self.swap.switches],
            "recalibrations": self.recal.recals if self.recal else 0,
            "publishes": len(self.swap.publishes),
            "gears": self.bank.describe(),
        }
