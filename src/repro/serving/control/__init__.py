"""repro.serving.control — the adaptive control plane (DESIGN.md §11).

Closes the loop from telemetry to strategy: everything before this
package is feed-forward (offline calibration -> frozen tables -> serve);
this package WRITES BACK into the decision layer while the server runs.

    TelemetryWindow  — sliding-window load/quality estimates + the
                       load-level signal and inflection detection.
    GearPlanner      — offline bank of load-indexed gear plans, each a
                       provably-optimal T-Tamer strategy for its regime.
    Recalibrator     — online re-fit of `Cascade` tables from observed
                       outcomes, re-solved off the hot path.
    BankSwap         — atomic strategy-bank exchange between token
                       steps: a device-array publish + a host-side gear
                       pointer, never a retrace, never a dropped lane.
    AdaptiveController — the glue the `Server` drives via its
                       begin / on_arrivals / on_step_end hooks.
"""

from repro.serving.control.controller import AdaptiveController
from repro.serving.control.gears import (Gear, GearBank, GearPlanner,
                                         GearSpec)
from repro.serving.control.recalibrate import Recalibrator
from repro.serving.control.swap import BankSwap
from repro.serving.control.telemetry import TelemetrySnapshot, TelemetryWindow

__all__ = [
    "TelemetryWindow", "TelemetrySnapshot",
    "GearSpec", "Gear", "GearBank", "GearPlanner",
    "Recalibrator", "BankSwap", "AdaptiveController",
]
