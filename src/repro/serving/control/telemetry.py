"""`TelemetryWindow` — the control plane's sensor (DESIGN.md §11).

Folds the per-step emissions the server already produces (TTFT and
goodput-under-SLO via `RuntimeMetrics`' sliding window, served losses
via the stepper's ``row_tap``, arrivals, queue depth / pages-in-use /
escalation gauges) into trailing-window estimates, and derives the two
signals gear selection runs on:

  * **load level** — the arrival rate quantized against the gear bank's
    capacity thresholds;
  * **inflection detection** — the rate's finite-difference slope over
    the window's two halves, so the controller can tell a sustained
    diurnal ramp from noise and react while the ramp is still climbing
    instead of after the queue has already exploded.

Everything is bounded (`SlidingWindow` rings) and host-side; reading a
snapshot never touches the device.
"""

from __future__ import annotations

import dataclasses

from repro.serving.runtime.metrics import RuntimeMetrics, SlidingWindow

__all__ = ["TelemetryWindow", "TelemetrySnapshot"]

GAUGES = ("queue_depth", "pages_in_use", "escalations", "recalls")


@dataclasses.dataclass
class TelemetrySnapshot:
    """One window-consistent read of the serving state."""

    now: float
    arrival_rate: float        # requests/sec over the trailing window
    rate_slope: float          # d(rate)/dt between the window's halves
    mean_served_loss: float | None
    goodput_tok_s: float | None
    throughput_tok_s: float | None
    mean_served_node: float | None
    ttft_p95: float | None
    queue_depth: int = 0
    pages_in_use: int = 0
    escalations: int = 0
    recalls: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class TelemetryWindow:
    """Sliding-window fold of the server's emissions."""

    def __init__(self, span: float, *, slo: float | None = None,
                 maxlen: int = 4096):
        if not span > 0:
            raise ValueError(f"telemetry span must be > 0, got {span}")
        self.span = float(span)
        self.slo = slo
        self._arr = SlidingWindow(span, maxlen)     # arrival timestamps
        self._loss = SlidingWindow(span, maxlen)    # served losses
        self.gauges = {g: 0 for g in GAUGES}
        self.metrics: RuntimeMetrics | None = None
        self.t0 = 0.0

    def bind(self, metrics: RuntimeMetrics) -> None:
        """Attach to a serve run's metrics: turns on its bounded window
        (satellite fix) and anchors the rate clock at its start."""
        metrics.enable_window(self.span)
        self.metrics = metrics
        self.t0 = metrics.t_start

    # ---- feeds -------------------------------------------------------

    def on_arrival(self, t: float) -> None:
        self._arr.push(t, 1.0)

    def on_arrivals(self, times) -> None:
        for t in times:
            self._arr.push(float(t), 1.0)

    def on_losses(self, t: float, losses) -> None:
        for v in losses:
            self._loss.push(t, float(v))

    def on_gauges(self, **kv) -> None:
        for name, value in kv.items():
            if name not in self.gauges:
                raise KeyError(f"unknown gauge {name!r}; "
                               f"known: {GAUGES}")
            self.gauges[name] = int(value)

    # ---- derived signals ---------------------------------------------

    def _span_eff(self, now: float) -> float:
        """Trailing span actually covered (short right after start)."""
        return min(self.span, max(float(now) - self.t0, 1e-9))

    def arrival_rate(self, now: float) -> float:
        """Requests/sec over the trailing window (0.0 when empty —
        explicit, never NaN)."""
        return len(self._arr.items(now)) / self._span_eff(now)

    def rate_slope(self, now: float) -> float:
        """Finite-difference slope of the arrival rate: late-half rate
        minus early-half rate, per unit time.  Positive on a diurnal
        ramp-up, negative on the way down, ~0 in steady state — the
        inflection signal."""
        items = self._arr.items(now)
        half = self._span_eff(now) / 2.0
        if half <= 0 or not items:
            return 0.0
        mid = float(now) - half
        early = sum(1 for t, _ in items if t < mid)
        late = len(items) - early
        return (late - early) / half / half

    def inflecting(self, now: float, eps: float) -> bool:
        """Is the load moving fast enough to act on (|slope| > eps)?"""
        return abs(self.rate_slope(now)) > float(eps)

    def mean_served_loss(self, now: float) -> float | None:
        vals = self._loss.values(now)
        if not vals:
            return None
        return sum(vals) / len(vals)

    def load_level(self, now: float, thresholds) -> int:
        """Quantize the arrival rate against ascending rate thresholds:
        returns how many the current rate meets or exceeds (0 = idle
        regime, len(thresholds) = beyond the last)."""
        rate = self.arrival_rate(now)
        return sum(1 for th in thresholds if rate >= float(th))

    def snapshot(self, now: float) -> TelemetrySnapshot:
        win = {}
        if self.metrics is not None:
            win = self.metrics.window_summary(now, slo=self.slo)
        ttft = win.get("ttft") or {}
        return TelemetrySnapshot(
            now=float(now),
            arrival_rate=self.arrival_rate(now),
            rate_slope=self.rate_slope(now),
            mean_served_loss=self.mean_served_loss(now),
            goodput_tok_s=win.get("goodput_tok_s"),
            throughput_tok_s=win.get("throughput_tok_s"),
            mean_served_node=win.get("mean_served_node"),
            ttft_p95=ttft.get("p95"),
            **{g: self.gauges[g] for g in GAUGES},
        )
