"""`BankSwap` — atomic strategy-bank exchange (DESIGN.md §11).

The swap point is the PR-2 static bank: steppers trace their decision
program over a fixed-size tuple of strategies and route per-lane with a
stamped ``sid``, and (since the control-plane PR) they take every
slot's DYNAMIC ARRAYS as a traced argument.  That turns both control
actions into host-side pointer moves:

  * **gear switch** — ``swap_to(slot)`` changes which slot NEW
    admissions are stamped with (`sid_of`); in-flight lanes keep their
    admitted ``sid`` and finish on the gear that admitted them, so a
    switch never drops or restyles a live stream;
  * **table publish** — ``publish(slot, strategy)`` replaces the slot's
    strategy with a re-calibrated one whose arrays have identical
    pytree structure, shapes and dtypes (enforced against the slot's
    reserved `slot_signature`), so the next step's jit lookup is a
    cache HIT — zero retraces by construction.

Both land between token steps only: the `Server` consults this object
via the stepper's ``bank_source`` at the top of each step, and the
`AdaptiveController` mutates it in ``on_step_end`` — there is no
instant at which a half-applied bank is visible to device code.
"""

from __future__ import annotations

from repro.strategy.base import dynamic_arrays
from repro.strategy.registry import reserve_bank, slot_signature

__all__ = ["BankSwap"]


class BankSwap:
    """Mutable strategy bank with signature-guarded publishes."""

    def __init__(self, strategies, *, start: int = 0):
        members, self.signatures = reserve_bank(strategies)
        self.strategies = list(members)
        self._arrays = [dynamic_arrays(s) for s in self.strategies]
        if not 0 <= start < len(self.strategies):
            raise ValueError(f"start slot {start} outside bank of "
                             f"{len(self.strategies)}")
        self.gear = int(start)
        self.switches: list[tuple[float, int, int]] = []   # (t, old, new)
        self.publishes: list[tuple[float, int]] = []       # (t, slot)

    def __len__(self) -> int:
        return len(self.strategies)

    # ---- what the stepper reads each step ----------------------------

    def bank_arrays(self) -> tuple:
        """Per-slot dynamic arrays for the next token step (the traced
        argument of the stepper's decision program)."""
        return tuple(self._arrays)

    def sid_of(self, req) -> int:
        """Admission stamp: every request admitted from now decides on
        the ACTIVE gear's slot.  The request keeps this sid for life."""
        return self.gear

    # ---- what the controller writes between steps --------------------

    def swap_to(self, slot: int, now: float) -> bool:
        """Point new admissions at ``slot``; returns True on a change."""
        slot = int(slot)
        if not 0 <= slot < len(self.strategies):
            raise ValueError(f"slot {slot} outside bank of "
                             f"{len(self.strategies)}")
        if slot == self.gear:
            return False
        self.switches.append((float(now), self.gear, slot))
        self.gear = slot
        return True

    def publish(self, slot: int, strategy, now: float) -> None:
        """Install a re-calibrated strategy into ``slot``.

        The newcomer must carry the slot's exact swap signature (class,
        array structure, shapes, dtypes) — the contract that makes the
        publish retrace-free.  A violating publish raises and leaves
        the bank untouched.
        """
        slot = int(slot)
        sig = slot_signature(strategy)
        if sig != self.signatures[slot]:
            raise ValueError(
                f"publish into slot {slot} changes the swap signature "
                f"(reserved {self.signatures[slot]!r}, got {sig!r}); "
                "recalibrated tables must keep structure/shapes/dtypes")
        self.strategies[slot] = strategy
        self._arrays[slot] = dynamic_arrays(strategy)
        self.publishes.append((float(now), slot))
