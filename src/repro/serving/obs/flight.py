"""`FlightRecorder` — anomaly-triggered post-mortem bundles
(DESIGN.md §12).

The recorder rides the tracer's event stream as its listener: it
never adds producers of its own, it just watches the same lifecycle
events and keeps trigger state.  When any trigger fires it freezes a
bundle — the last N ring events, the triggering request's FULL span
history, and a metrics snapshot — so the anomaly arrives with its
causes attached instead of a lone log line.

Triggers (all thresholds constructor-tunable):

  * ``slo_burst``     — ``slo_burst`` consecutive first tokens over
    the TTFT SLO.  One late request is load; a burst is a stall.
  * ``page_exhaustion`` — ``page_burst`` consecutive admission
    attempts refused for lack of KV pages.  Queueing under pressure
    is by design; a refusal *streak* means the pool stopped turning
    over.
  * ``stuck_waiter``  — an escalation waiter older than
    ``stuck_after`` serve-seconds with no grant.  Deep-lane grants
    normally arrive within a few steps; an old waiter is a leaked
    lane or a wedged scheduler.
  * ``gear_thrash``   — ``thrash_count`` gear switches inside
    ``thrash_window`` serve-seconds.  Hysteresis should make switches
    rare; thrash means the controller is chasing noise.
  * ``regret_burst``  — windowed p99 per-request regret above
    ``regret_threshold`` (fed by the `RegretMeter` via `note_regret`,
    not by a span kind).  A calibrated recall serve sits at ~zero
    regret; a sustained burst means the tables have drifted from the
    traffic or a no-recall gear is paying the impossibility tax.  The
    bundle pins the window's worst offender's full span history.

Each trigger kind fires at most ``max_bundles_per_kind`` times per
serve (anomalies tend to repeat every step once entered — one bundle
per failure mode is the useful artifact, a dump storm is not).  For
long soaks that cap is too blunt — it would only ever capture the
first anomaly of each kind across simulated hours — so
``rearm_interval`` re-arms all triggers every N serve-seconds:
bundles stay capped *per window*, and every window gets a fresh
budget.  `reset()` does the same re-arm explicitly.
"""

from __future__ import annotations

import collections
import json
import os
from typing import Any, Callable

from repro.serving.obs.trace import Event, SpanTracer

__all__ = ["FlightRecorder"]


class FlightRecorder:
    def __init__(self, *, window: int = 2048, slo: float | None = None,
                 slo_burst: int = 5, page_burst: int = 3,
                 stuck_after: float = 30.0, thrash_count: int = 6,
                 thrash_window: float = 60.0, out_dir: str | None = None,
                 max_bundles_per_kind: int = 1,
                 rearm_interval: float | None = None,
                 regret_window: int = 64,
                 regret_threshold: float | None = None):
        self.window = int(window)
        self.slo = slo
        self.slo_burst = int(slo_burst)
        self.page_burst = int(page_burst)
        self.stuck_after = float(stuck_after)
        self.thrash_count = int(thrash_count)
        self.thrash_window = float(thrash_window)
        self.out_dir = out_dir
        self.max_bundles_per_kind = int(max_bundles_per_kind)
        self.rearm_interval = (float(rearm_interval)
                               if rearm_interval else None)
        self.regret_window = int(regret_window)
        self.regret_threshold = (float(regret_threshold)
                                 if regret_threshold is not None else None)

        self.bundles: list[dict[str, Any]] = []
        self.dump_paths: list[str] = []
        self._tracer: SpanTracer | None = None
        self._snapshot_fn: Callable[[], dict[str, Any]] | None = None
        self._fired: collections.Counter = collections.Counter()
        self._rearms = 0
        self._window_end: float | None = None

        self._slo_streak = 0
        self._page_streak = 0
        self._waiters: dict[tuple[int, int], float] = {}   # (rid, model) -> t
        self._switch_ts: collections.deque[float] = collections.deque()
        # (t, rid, regret) of the last `regret_window` finished requests
        self._regret_recent: collections.deque = collections.deque(
            maxlen=self.regret_window)

    # ---------------------------------------------------------- wiring
    def bind(self, tracer: SpanTracer,
             snapshot_fn: Callable[[], dict[str, Any]] | None = None,
             ) -> None:
        """Attach to a tracer as a listener (chained — the ledger and
        other consumers can ride the same stream).  ``snapshot_fn`` is
        called lazily at dump time for the metrics section."""
        self._tracer = tracer
        self._snapshot_fn = snapshot_fn
        tracer.add_listener(self.observe)

    def reset(self) -> None:
        """Re-arm every trigger: clear streak state and the per-kind
        fired counters.  Captured bundles and dump paths are kept."""
        self._fired.clear()
        self._slo_streak = 0
        self._page_streak = 0
        self._waiters.clear()
        self._switch_ts.clear()
        self._regret_recent.clear()
        self._rearms += 1

    # ---------------------------------------------------------- stream
    def observe(self, ev: Event) -> None:
        if self.rearm_interval is not None:
            if self._window_end is None:
                self._window_end = ev.t + self.rearm_interval
            elif ev.t >= self._window_end:
                self.reset()
                self._window_end = ev.t + self.rearm_interval
        kind = ev.kind
        if kind == "token":
            ttft = dict(ev.data).get("ttft")
            if ttft is not None and self.slo is not None:
                if float(ttft) > self.slo:
                    self._slo_streak += 1
                    if self._slo_streak >= self.slo_burst:
                        self.trigger("slo_burst", ev.t, rid=ev.rid,
                                     detail={"streak": self._slo_streak,
                                             "ttft": float(ttft),
                                             "slo": self.slo})
                else:
                    self._slo_streak = 0
        elif kind == "page_blocked":
            self._page_streak += 1
            if self._page_streak >= self.page_burst:
                self.trigger("page_exhaustion", ev.t, rid=ev.rid,
                             detail={"streak": self._page_streak})
        elif kind == "admitted":
            self._page_streak = 0
        elif kind == "esc_wait":
            self._waiters[(ev.rid, ev.model)] = ev.t
        elif kind in ("esc_grant", "esc_resolve", "finish", "deescalate",
                      "cancel", "deadline_miss"):
            if kind in ("finish", "cancel", "deadline_miss"):
                # terminal for the rid: sweep every model's waiter
                stale = [k for k in self._waiters if k[0] == ev.rid]
            else:
                stale = [(ev.rid, ev.model)]
            for k in stale:
                self._waiters.pop(k, None)
        elif kind == "gear_switch":
            self._switch_ts.append(ev.t)
            while (self._switch_ts and
                   ev.t - self._switch_ts[0] > self.thrash_window):
                self._switch_ts.popleft()
            if len(self._switch_ts) >= self.thrash_count:
                self.trigger("gear_thrash", ev.t,
                             detail={"switches": len(self._switch_ts),
                                     "window_s": self.thrash_window})
        # Stuck-waiter check piggybacks on every event's timestamp —
        # no timer thread, and in sim mode "age" is virtual age.
        if self._waiters:
            oldest = min(self._waiters.items(), key=lambda kv: kv[1])
            (rid, model), t0 = oldest
            if ev.t - t0 > self.stuck_after:
                self._waiters.pop((rid, model), None)
                self.trigger("stuck_waiter", ev.t, rid=rid,
                             detail={"model": model, "waited_s": ev.t - t0})

    def note_regret(self, t: float, rid: int, regret: float) -> None:
        """Fold one finished request's regret in (called by the
        `RegretMeter`, which rides the span stream — regret is not a
        span kind, so this is its own entry point).  Same rearm-window
        semantics as `observe`."""
        if self.regret_threshold is None:
            return
        if self.rearm_interval is not None:
            if self._window_end is None:
                self._window_end = t + self.rearm_interval
            elif t >= self._window_end:
                self.reset()
                self._window_end = t + self.rearm_interval
        self._regret_recent.append((t, int(rid), float(regret)))
        if len(self._regret_recent) < 4:
            return          # too few points for a percentile to mean much
        vals = sorted(r for _, _, r in self._regret_recent)
        p99 = vals[min(len(vals) - 1, int(0.99 * len(vals)))]
        if p99 > self.regret_threshold:
            worst = max(self._regret_recent, key=lambda x: x[2])
            self.trigger("regret_burst", t, rid=worst[1],
                         detail={"p99": p99,
                                 "threshold": self.regret_threshold,
                                 "worst_regret": worst[2],
                                 "window": len(self._regret_recent)})

    # ---------------------------------------------------------- dump
    def trigger(self, kind: str, t: float, *, rid: int | None = None,
                detail: dict[str, Any] | None = None) -> dict | None:
        if self._fired[kind] >= self.max_bundles_per_kind:
            return None
        self._fired[kind] += 1
        tracer = self._tracer
        events = list(tracer.events)[-self.window:] if tracer else []
        bundle: dict[str, Any] = {
            "schema": "flight_bundle/v1",
            "trigger": kind,
            "t": float(t),
            "rid": rid,
            "detail": detail or {},
            "events": [ev.as_dict() for ev in events],
            "request_span": ([ev.as_dict()
                              for ev in tracer.request_span(rid)]
                             if tracer and rid is not None else []),
            "span_events_dropped": (tracer.span_dropped(rid)
                                    if tracer and rid is not None else 0),
        }
        if self._snapshot_fn is not None:
            try:
                bundle["metrics"] = self._snapshot_fn()
            except Exception as e:        # snapshot must never kill a serve
                bundle["metrics"] = {"error": repr(e)}
        self.bundles.append(bundle)
        if self.out_dir:
            os.makedirs(self.out_dir, exist_ok=True)
            # len(bundles) is a monotone sequence — unlike the per-kind
            # fired counter, it never collides across re-arm windows.
            path = os.path.join(
                self.out_dir, f"flight-{kind}-{len(self.bundles)}.json")
            with open(path, "w") as f:
                json.dump(bundle, f, indent=2, default=float)
            self.dump_paths.append(path)
        return bundle

    # ---------------------------------------------------------- stats
    def stats(self) -> dict[str, Any]:
        return {"bundles": len(self.bundles),
                "triggers": dict(self._fired),
                "rearms": self._rearms,
                "pending_waiters": len(self._waiters)}
