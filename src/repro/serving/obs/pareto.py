"""`ParetoTracker` — the streaming empirical accuracy-latency frontier
(DESIGN.md §15).

Every finished request is one point ``(latency, served-loss)``; the
tracker keeps the non-dominated set (lower latency AND lower loss)
incrementally, with per-gear attribution so an adaptive serve shows
WHICH gear produced each frontier point.  The offline Pareto sweeps
(`benchmarks.bench_runtime`) compare whole configurations; this is the
same axis pair measured live, per request, inside one serve.

Dominance is minimize-both: point q dominates p when ``q.latency <=
p.latency`` and ``q.loss <= p.loss`` (exact ties count as dominated,
first-come-wins, so the frontier stays small under identical sim
points).  Each ``add`` is O(frontier), which stays tiny in practice —
frontiers over thousands of serve points hold a few dozen entries.

`as_doc` exports the ``obs_pareto/v1`` schema `benchmarks.check_trace
--pareto` validates.
"""

from __future__ import annotations

from typing import Any

__all__ = ["ParetoTracker"]


class ParetoTracker:
    """Incremental non-dominated (latency, served-loss) set."""

    def __init__(self) -> None:
        self.n_points = 0
        self.by_gear: dict[str, int] = {}       # gear -> points seen
        self._frontier: list[dict[str, Any]] = []   # sorted by latency

    def add(self, rid: int, latency_s: float, loss: float,
            gear: str = "fixed") -> bool:
        """Fold one finished request in; True if it joined the frontier."""
        self.n_points += 1
        self.by_gear[gear] = self.by_gear.get(gear, 0) + 1
        lat, loss = float(latency_s), float(loss)
        for q in self._frontier:
            if q["latency_s"] <= lat and q["loss"] <= loss:
                return False            # dominated (ties lose too)
        self._frontier = [
            q for q in self._frontier
            if not (lat <= q["latency_s"] and loss <= q["loss"])]
        self._frontier.append({"rid": int(rid), "latency_s": lat,
                               "loss": loss, "gear": gear})
        self._frontier.sort(key=lambda q: (q["latency_s"], q["loss"]))
        return True

    @property
    def frontier(self) -> list[dict[str, Any]]:
        return list(self._frontier)

    def as_doc(self) -> dict[str, Any]:
        by_gear = {}
        for gear, n in sorted(self.by_gear.items()):
            by_gear[gear] = {
                "points": n,
                "frontier": sum(1 for q in self._frontier
                                if q["gear"] == gear)}
        return {
            "schema": "obs_pareto/v1",
            "points": self.n_points,
            "frontier_size": len(self._frontier),
            "frontier": [
                {"rid": q["rid"],
                 "latency_s": round(q["latency_s"], 9),
                 "loss": round(q["loss"], 9),
                 "gear": q["gear"]}
                for q in self._frontier],
            "by_gear": by_gear,
        }
