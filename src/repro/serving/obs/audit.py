"""`InvariantLedger` — streaming contracts over the span stream
(DESIGN.md §13).

The ledger rides `SpanTracer.add_listener` exactly like the flight
recorder: it adds no producers and no device syncs, it just folds every
event into O(live-rids) contract state.  A serve with no ledger — or no
tracer at all — is bit-identical, which is the same guarantee PR 7 pins
for tracing itself.

Contracts (each reports ``checks`` / ``violations`` and a verdict):

  * ``page_conservation`` — with a bound `KVPool`, the pool's own
    `check_invariants()` runs at every counter-event edge: allocs ==
    frees + in_use, refcounts never negative, every reference accounted
    to a lane table or prefix-cache entry, reserved budgets within the
    free list.  Without a pool the contract degrades to what the event
    stream alone can see (pages_in_use gauges never negative).
  * ``escalation_resolves`` — every ``escalate`` reaches
    ``esc_resolve`` / ``recall`` / ``deescalate`` / ``finish`` within
    ``horizon`` serve-seconds (virtual seconds in sim mode).  A waiter
    older than the horizon is a leaked deep lane or a wedged scheduler.
  * ``lane_conservation`` — lane occupancy across admit/recycle:
    admitting onto a lane that still holds a live request, a token or
    finish on a lane that disagrees with the rid's admission, a token
    before any admission — each is a conservation break.
  * ``walk_floor_monotonic`` — under ``--escalate-policy commit`` a
    request's served model rung may never move back down (that is what
    "commit" means; only recall policies may de-escalate).  Armed by
    passing ``policy="commit"`` and the cascade's ``boundaries``.
  * ``ttft_exactly_once`` — exactly one token event per rid carries the
    ``ttft`` stamp, and it is the rid's FIRST token.
  * ``admission_never_drop`` — the T-Tamer admission guarantee: queue,
    never drop.  At finalize every queued rid must have been admitted
    and finished — a page-blocked request may wait, but must land.
  * ``cancel_halts_stream`` — a reaped rid (``cancel`` /
    ``deadline_miss``) emits NOTHING afterwards: no tokens, no prefill
    chunks, no escalation grants.  A late emission means the server
    reaped the bookkeeping but left the lane running.
  * ``cancel_releases_pages`` — at the reap event of a lane-holding
    request, the pool shows zero pages and zero budget on that lane
    (the server releases BEFORE emitting, so the probe reads the
    post-teardown state; COW-shared prefix pages survive via their
    cache refs, which is the point).
  * ``rung_stall_liveness`` — an escalation whose window overlaps a
    scripted ``rung_stall`` may take the stall's duration extra, but
    no more: the stall allowance is added to the horizon, and
    exceeding even that is a deadlocked waiter, not a slow one.

Verdicts are ``pass`` / ``violated`` / ``unverifiable``.  The live
listener sees every emit regardless of ring evictions, so live verdicts
are exact.  `audit_events` (offline, over an exported ring) reports
``unverifiable`` instead of guessing whenever events were dropped:
a truncated ring makes "no admission seen" indistinguishable from
"admission evicted", and a false positive would poison CI.

On violation the ledger freezes a `flight_bundle/v1`-style dump (the
offending rid's FULL span history + the recent ring window) so the
break arrives with its causes attached — same artifact shape the
flight recorder emits and `benchmarks.check_trace --bundle` validates.
"""

from __future__ import annotations

import json
import os
from typing import Any

from repro.serving.obs.trace import Event, SpanTracer

__all__ = ["InvariantLedger", "audit_events", "CONTRACTS"]

CONTRACTS = (
    "page_conservation",
    "escalation_resolves",
    "lane_conservation",
    "walk_floor_monotonic",
    "ttft_exactly_once",
    "admission_never_drop",
    "cancel_halts_stream",
    "cancel_releases_pages",
    "rung_stall_liveness",
)

_ESC_CLEARS = {"esc_resolve", "recall", "deescalate", "finish"}


class InvariantLedger:
    """Streaming auditor over a tracer's event stream.

    ``horizon`` bounds how long an escalation may stay unresolved
    (serve-seconds).  ``policy`` + ``boundaries`` arm the walk-floor
    contract: ``boundaries`` is the cascade's per-model node count
    tuple, mapping a served node to its model rung.  ``pool`` (or the
    pool the server binds) turns page conservation from gauge checks
    into the pool's full `check_invariants` audit, sampled every
    ``pool_check_every`` counter events.  ``max_violations`` caps the
    retained detail list; ``max_bundles`` caps frozen dumps.
    """

    def __init__(self, *, horizon: float = 120.0,
                 policy: str | None = None,
                 boundaries: tuple[int, ...] | None = None,
                 pool=None, pool_check_every: int = 1,
                 out_dir: str | None = None, window: int = 512,
                 max_violations: int = 64, max_bundles: int = 8):
        self.horizon = float(horizon)
        self.policy = policy
        self.boundaries = tuple(boundaries) if boundaries else None
        self.pool = pool
        self.pool_check_every = max(1, int(pool_check_every))
        self.out_dir = out_dir
        self.window = int(window)
        self.max_violations = int(max_violations)
        self.max_bundles = int(max_bundles)

        self.checks: dict[str, int] = {c: 0 for c in CONTRACTS}
        self.violations: list[dict[str, Any]] = []
        self.n_violations: dict[str, int] = {c: 0 for c in CONTRACTS}
        self.bundles: list[dict[str, Any]] = []
        self.dump_paths: list[str] = []
        self.events_seen = 0
        self.finalized = False
        self._tracer: SpanTracer | None = None

        # O(live-rids) state, cleaned on finish
        self._queued: set[int] = set()            # queued, not yet admitted
        self._admitted: dict[int, int] = {}       # rid -> lane
        self._lane_rid: dict[int, int] = {}       # lane -> rid
        self._ttft_seen: set[int] = set()
        self._tokens: dict[int, int] = {}         # rid -> token count
        self._esc_open: dict[tuple[int, int], float] = {}  # (rid,model)->t
        self._floor: dict[int, int] = {}          # rid -> deepest model rung
        self._counters = 0
        self._t_last = 0.0
        # fault plane (DESIGN.md §14)
        self._reaped: set[int] = set()            # cancelled / expired rids
        self._stalls: list[tuple[int, float, float]] = []  # (model, t0, t1)

    # ------------------------------------------------------------ wiring
    def bind(self, tracer: SpanTracer, *, pool=None) -> None:
        """Attach to a tracer as a chained listener.  The server passes
        the stepper's pool (when it has one) so page conservation audits
        the real allocator instead of just the exported gauges."""
        self._tracer = tracer
        if pool is not None:
            self.pool = pool
        tracer.add_listener(self.observe)

    def _node_model(self, node: int) -> int:
        if not self.boundaries:
            return 0
        acc = 0
        for m, n in enumerate(self.boundaries):
            acc += n
            if node < acc:
                return m
        return len(self.boundaries) - 1

    # ------------------------------------------------------------ stream
    def observe(self, ev: Event) -> None:
        self.events_seen += 1
        self._t_last = max(self._t_last, ev.t)
        kind = ev.kind
        if (ev.rid >= 0 and self._reaped and ev.rid in self._reaped
                and kind in ("token", "prefill_chunk", "escalate",
                             "esc_wait", "esc_grant", "esc_resolve",
                             "recall", "finish")):
            self._violate("cancel_halts_stream", ev,
                          f"rid {ev.rid} emitted {kind} after being "
                          f"reaped")
            return   # a phantom emission must not feed other contracts
        if kind == "queued":
            self._queued.add(ev.rid)
        elif kind == "admitted":
            self.checks["lane_conservation"] += 1
            prev = self._lane_rid.get(ev.lane)
            if prev is not None:
                self._violate("lane_conservation", ev,
                              f"lane {ev.lane} admitted rid {ev.rid} "
                              f"while still holding rid {prev}")
            if ev.rid in self._admitted:
                self._violate("lane_conservation", ev,
                              f"rid {ev.rid} admitted twice (lanes "
                              f"{self._admitted[ev.rid]} and {ev.lane})")
            self._queued.discard(ev.rid)
            self._admitted[ev.rid] = ev.lane
            self._lane_rid[ev.lane] = ev.rid
        elif kind == "token":
            self.checks["lane_conservation"] += 1
            lane = self._admitted.get(ev.rid)
            if lane is None:
                self._violate("lane_conservation", ev,
                              f"token for rid {ev.rid} before admission")
            elif ev.lane >= 0 and ev.lane != lane:
                self._violate("lane_conservation", ev,
                              f"token for rid {ev.rid} on lane {ev.lane} "
                              f"but admitted on lane {lane}")
            n = self._tokens.get(ev.rid, 0) + 1
            self._tokens[ev.rid] = n
            d = dict(ev.data)
            self.checks["ttft_exactly_once"] += 1
            if "ttft" in d:
                if ev.rid in self._ttft_seen:
                    self._violate("ttft_exactly_once", ev,
                                  f"rid {ev.rid} emitted a second ttft")
                elif n != 1:
                    self._violate("ttft_exactly_once", ev,
                                  f"rid {ev.rid} stamped ttft on token "
                                  f"{n}, not its first")
                self._ttft_seen.add(ev.rid)
            elif n == 1:
                self._violate("ttft_exactly_once", ev,
                              f"rid {ev.rid} first token has no ttft")
            if self.policy == "commit" and self.boundaries:
                self.checks["walk_floor_monotonic"] += 1
                node = int(d.get("node", -1))
                if node >= 0:
                    m = self._node_model(node)
                    floor = self._floor.get(ev.rid, 0)
                    if m < floor:
                        self._violate(
                            "walk_floor_monotonic", ev,
                            f"rid {ev.rid} served model {m} after "
                            f"committing to model {floor}")
                    elif m > floor:
                        self._floor[ev.rid] = m
        elif kind == "escalate":
            self._esc_open[(ev.rid, ev.model)] = ev.t
        elif kind in _ESC_CLEARS:
            if kind == "finish":
                for key in [k for k in self._esc_open if k[0] == ev.rid]:
                    self._close_escalation(key, ev.t)
                self._finish(ev)
            else:
                key = (ev.rid, ev.model)
                if key in self._esc_open:
                    self._close_escalation(key, ev.t)
        elif kind in ("cancel", "deadline_miss"):
            self._reap(ev)
        elif kind == "rung_stall":
            d = dict(ev.data)
            self.checks["rung_stall_liveness"] += 1
            self._stalls.append((int(ev.model),
                                 float(d.get("t0", ev.t)),
                                 float(d.get("until", ev.t))))
        elif kind == "counter":
            self._counters += 1
            d = dict(ev.data)
            pages = d.get("pages_in_use")
            if pages is not None:
                self.checks["page_conservation"] += 1
                if int(pages) < 0:
                    self._violate("page_conservation", ev,
                                  f"pages_in_use gauge {pages} < 0")
            if (self.pool is not None
                    and self._counters % self.pool_check_every == 0):
                self.checks["page_conservation"] += 1
                for msg in self.pool.check_invariants():
                    self._violate("page_conservation", ev, msg)
        # horizon sweep piggybacks on every event's timestamp — same
        # no-timer-thread idiom as the flight recorder's stuck waiter.
        # An escalation whose window overlaps a scripted rung stall
        # gets the stall's duration as extra allowance; exceeding even
        # that is a DEADLOCKED waiter (the rung-stall contract), while
        # exceeding the plain horizon with no stall in sight stays an
        # escalation-resolves break.
        if self._esc_open:
            key, t0 = min(self._esc_open.items(), key=lambda kv: kv[1])
            allow = self._stall_allowance(key[1], t0, ev.t)
            if ev.t - t0 > self.horizon + allow:
                del self._esc_open[key]
                rid, model = key
                contract = ("rung_stall_liveness" if allow > 0
                            else "escalation_resolves")
                self._violate(
                    contract,
                    Event(ev.t, "escalate", rid, -1, model),
                    f"rid {rid} escalation to model {model} unresolved "
                    f"after {ev.t - t0:.3f}s (horizon {self.horizon}s"
                    f" + stall allowance {allow:.3f}s)")

    def _stall_allowance(self, model: int, t0: float, t1: float) -> float:
        """Scripted stall time of ``model`` inside ``[t0, t1]``."""
        total = 0.0
        for m, s0, s1 in self._stalls:
            if m == model:
                total += max(0.0, min(t1, s1) - max(t0, s0))
        return total

    def _reap(self, ev: Event) -> None:
        """Fold a ``cancel`` / ``deadline_miss`` event: the rid is
        terminal — its open escalations close (the reap freed the deep
        lanes), its lane/queue state drops WITHOUT the finish-path
        violations (a reaped request is legally never finished), and
        with a bound pool the lane must already be page-clean."""
        rid = ev.rid
        self.checks["cancel_halts_stream"] += 1
        self._reaped.add(rid)
        for key in [k for k in self._esc_open if k[0] == rid]:
            del self._esc_open[key]
        self._queued.discard(rid)
        lane = self._admitted.pop(rid, None)
        if lane is not None and self._lane_rid.get(lane) == rid:
            del self._lane_rid[lane]
        self._tokens.pop(rid, None)
        self._ttft_seen.discard(rid)
        self._floor.pop(rid, None)
        if ev.lane >= 0 and self.pool is not None:
            self.checks["cancel_releases_pages"] += 1
            held = int(self.pool.n_held[ev.lane])
            budget = int(self.pool.budget[ev.lane])
            if held or budget:
                self._violate(
                    "cancel_releases_pages", ev,
                    f"rid {rid} reaped off lane {ev.lane} but the lane "
                    f"still holds {held} pages / {budget} budget")

    def _close_escalation(self, key: tuple[int, int], t: float) -> None:
        t0 = self._esc_open.pop(key)
        rid, model = key
        allow = self._stall_allowance(model, t0, t)
        contract = ("rung_stall_liveness" if allow > 0
                    else "escalation_resolves")
        self.checks[contract] += 1
        if t - t0 > self.horizon + allow:
            self._violate(
                contract, Event(t, "esc_resolve", rid, -1, model),
                f"rid {rid} escalation to model {model} resolved only "
                f"after {t - t0:.3f}s (horizon {self.horizon}s"
                f" + stall allowance {allow:.3f}s)")

    def _finish(self, ev: Event) -> None:
        self.checks["lane_conservation"] += 1
        lane = self._admitted.pop(ev.rid, None)
        if lane is None:
            self._violate("lane_conservation", ev,
                          f"finish for rid {ev.rid} never admitted")
        else:
            if ev.lane >= 0 and ev.lane != lane:
                self._violate("lane_conservation", ev,
                              f"rid {ev.rid} finished on lane {ev.lane} "
                              f"but admitted on lane {lane}")
            if self._lane_rid.get(lane) == ev.rid:
                del self._lane_rid[lane]
        self.checks["admission_never_drop"] += 1
        if ev.rid in self._queued:
            self._queued.discard(ev.rid)
            self._violate("admission_never_drop", ev,
                          f"rid {ev.rid} finished while still queued")
        # drop per-rid state: O(live-rids) overall
        self._tokens.pop(ev.rid, None)
        self._ttft_seen.discard(ev.rid)
        self._floor.pop(ev.rid, None)

    # ---------------------------------------------------------- verdicts
    def finalize(self, t_end: float | None = None) -> dict[str, Any]:
        """End-of-serve sweep: unresolved escalations, requests queued
        or admitted but never finished.  Idempotent; returns `report`."""
        if not self.finalized:
            self.finalized = True
            t = self._t_last if t_end is None else float(t_end)
            for (rid, model), t0 in sorted(self._esc_open.items()):
                self.checks["escalation_resolves"] += 1
                self._violate(
                    "escalation_resolves",
                    Event(t, "escalate", rid, -1, model),
                    f"rid {rid} escalation to model {model} never "
                    f"resolved (opened at {t0:.3f}s)")
            self._esc_open.clear()
            for rid in sorted(self._queued):
                self.checks["admission_never_drop"] += 1
                self._violate(
                    "admission_never_drop", Event(t, "queued", rid),
                    f"rid {rid} queued but never admitted at serve end")
            for rid, lane in sorted(self._admitted.items()):
                self.checks["admission_never_drop"] += 1
                self._violate(
                    "admission_never_drop", Event(t, "admitted", rid, lane),
                    f"rid {rid} admitted on lane {lane} but never "
                    f"finished")
            if self.pool is not None:
                self.checks["page_conservation"] += 1
                for msg in self.pool.check_invariants():
                    self._violate("page_conservation",
                                  Event(t, "counter"), msg)
        return self.report()

    def _violate(self, contract: str, ev: Event, msg: str) -> None:
        self.n_violations[contract] += 1
        if len(self.violations) < self.max_violations:
            self.violations.append({
                "contract": contract, "t": float(ev.t),
                "rid": int(ev.rid) if ev.rid >= 0 else None,
                "detail": msg,
            })
        self._freeze(contract, ev, msg)

    def _freeze(self, contract: str, ev: Event, msg: str) -> None:
        """flight_bundle/v1-style dump with the offending rid's full
        span history — the same artifact shape as the flight recorder."""
        if len(self.bundles) >= self.max_bundles:
            return
        tracer = self._tracer
        rid = int(ev.rid) if ev.rid >= 0 else None
        bundle: dict[str, Any] = {
            "schema": "flight_bundle/v1",
            "trigger": f"ledger:{contract}",
            "t": float(ev.t),
            "rid": rid,
            "detail": {"message": msg},
            "events": ([e.as_dict() for e in
                        list(tracer.events)[-self.window:]]
                       if tracer is not None else []),
            "request_span": ([e.as_dict()
                              for e in tracer.request_span(rid)]
                             if tracer is not None and rid is not None
                             else []),
            "span_events_dropped": (tracer.span_dropped(rid)
                                    if tracer is not None and rid is not None
                                    else 0),
        }
        self.bundles.append(bundle)
        if self.out_dir:
            os.makedirs(self.out_dir, exist_ok=True)
            path = os.path.join(
                self.out_dir,
                f"ledger-{contract}-{len(self.bundles)}.json")
            with open(path, "w") as f:
                json.dump(bundle, f, indent=2, default=float)
            self.dump_paths.append(path)

    # ------------------------------------------------------------ report
    @property
    def total_violations(self) -> int:
        return sum(self.n_violations.values())

    @property
    def ok(self) -> bool:
        return self.total_violations == 0

    def report(self, *, unverifiable: bool = False) -> dict[str, Any]:
        contracts = {}
        for c in CONTRACTS:
            if unverifiable:
                verdict = "unverifiable"
            elif self.n_violations[c]:
                verdict = "violated"
            else:
                verdict = "pass"
            contracts[c] = {"checks": self.checks[c],
                            "violations": self.n_violations[c],
                            "verdict": verdict}
        return {
            "schema": "ledger_report/v1",
            "mode": "offline" if unverifiable else "live",
            "events_seen": self.events_seen,
            "finalized": self.finalized,
            "horizon_s": self.horizon,
            "contracts": contracts,
            "violations": list(self.violations),
            "total_violations": self.total_violations,
        }

    def stats(self) -> dict[str, Any]:
        return {"events_seen": self.events_seen,
                "checks": sum(self.checks.values()),
                "violations": self.total_violations,
                "bundles": len(self.bundles)}


def audit_events(events, *, dropped: int = 0,
                 **ledger_kwargs) -> dict[str, Any]:
    """Offline audit of an exported event ring (or `Event` list).

    With ``dropped == 0`` the ring is the complete stream and the
    verdicts are exact — identical to what a live ledger would have
    said.  With ``dropped > 0`` the ring is only a suffix of the true stream:
    a missing admission may simply have been evicted, so every verdict
    degrades to an explicit ``unverifiable`` and any would-be
    violations are reported as ``suspect`` (diagnostic only) rather
    than counted — an honest "cannot audit a truncated ring" instead
    of a false positive.
    """
    ledger = InvariantLedger(**ledger_kwargs)
    for ev in events:
        if not isinstance(ev, Event):
            d = dict(ev)
            data = tuple(sorted(
                (k, v) for k, v in d.items()
                if k not in ("t", "kind", "rid", "lane", "model")))
            ev = Event(float(d["t"]), str(d["kind"]),
                       int(d.get("rid", -1)), int(d.get("lane", -1)),
                       int(d.get("model", -1)), data)
        ledger.observe(ev)
    ledger.finalize()
    if dropped > 0:
        report = ledger.report(unverifiable=True)
        report["events_dropped"] = int(dropped)
        report["suspect"] = report.pop("violations")
        report["violations"] = []
        report["total_violations"] = 0
        for c in report["contracts"].values():
            c["suspect"] = c.pop("violations")
            c["violations"] = 0
        return report
    report = ledger.report()
    report["events_dropped"] = 0
    return report
