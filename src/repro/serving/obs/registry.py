"""`MetricsRegistry` — counters / gauges / histograms with labels,
one ``snapshot()`` / Prometheus-text / JSON surface (DESIGN.md §12).

The serving subsystems each keep their own stats dicts
(`RuntimeMetrics.summary()`, `KVPool.stats()`, `CascadeStats`,
chunk-planner counters, controller switch logs).  Rather than rewrite
those hot paths, the registry *absorbs* them: `absorb()` walks a
nested mapping and lands every numeric leaf as a labelled gauge, so
one snapshot carries the whole serve regardless of which subsystems
ran.  Live counters/histograms are there for code that wants to emit
directly (the flight recorder, future burn-in harness).
"""

from __future__ import annotations

import json
from typing import Any, Iterable, Mapping

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def _label_key(labels: Mapping[str, str]) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed cumulative buckets + sum + count (Prometheus semantics)."""

    kind = "histogram"
    DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                       1.0, 2.5, 5.0, 10.0)

    def __init__(self, buckets: Iterable[float] | None = None) -> None:
        self.buckets = tuple(sorted(buckets or self.DEFAULT_BUCKETS))
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1

    @property
    def value(self) -> dict[str, Any]:
        return {"buckets": {str(le): c for le, c
                            in zip(self.buckets, self.counts)},
                "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Registry keyed by (name, labelset); one instance per serve."""

    def __init__(self) -> None:
        self._metrics: dict[tuple[str, tuple], Any] = {}
        self._help: dict[str, str] = {}

    # ------------------------------------------------------- factories
    def _get(self, cls, name: str, labels: Mapping[str, str], **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(**kw)
        elif not isinstance(m, cls):
            raise TypeError(f"{name} already registered as {m.kind}")
        return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, buckets: Iterable[float] | None = None,
                  **labels: str) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def describe(self, name: str, help_text: str) -> None:
        self._help[name] = help_text

    # -------------------------------------------------------- absorb
    def absorb(self, prefix: str, stats: Mapping[str, Any] | None,
               **labels: str) -> None:
        """Flatten every numeric leaf of ``stats`` into gauges named
        ``prefix_<path>`` carrying ``labels``.  Non-numeric leaves and
        None are skipped; nested mappings recurse with ``_``-joined
        paths; lists of scalars land as ``_n``-indexed gauges only when
        short (<= 8) — long lists are summarised by their length."""
        if not stats:
            return
        for k, v in stats.items():
            name = f"{prefix}_{k}" if prefix else str(k)
            if isinstance(v, Mapping):
                self.absorb(name, v, **labels)
            elif isinstance(v, bool):
                self.gauge(name, **labels).set(float(v))
            elif isinstance(v, (int, float)):
                self.gauge(name, **labels).set(float(v))
            elif isinstance(v, (list, tuple)):
                if len(v) <= 8 and all(
                        isinstance(x, (int, float)) for x in v):
                    for i, x in enumerate(v):
                        self.gauge(f"{name}_{i}", **labels).set(float(x))
                else:
                    self.gauge(f"{name}_len", **labels).set(float(len(v)))
            # strings / None / objects: not a metric

    # -------------------------------------------------------- queries
    def value(self, name: str, default: float | None = None,
              **labels: str) -> Any:
        m = self._metrics.get((name, _label_key(labels)))
        return default if m is None else m.value

    def labelsets(self, name: str) -> list[dict[str, str]]:
        return [dict(ls) for (n, ls) in self._metrics if n == name]

    # -------------------------------------------------------- surfaces
    def snapshot(self) -> dict[str, Any]:
        """Flat ``{name{labels}: value}`` mapping — the one structure
        the reporter, ``--metrics-out`` and tests all read."""
        out: dict[str, Any] = {}
        for (name, labels), m in sorted(self._metrics.items()):
            out[name + _label_str(labels)] = m.value
        return out

    def prometheus_text(self) -> str:
        lines: list[str] = []
        seen_type: set[str] = set()
        for (name, labels), m in sorted(self._metrics.items()):
            if name not in seen_type:
                seen_type.add(name)
                if name in self._help:
                    lines.append(f"# HELP {name} {self._help[name]}")
                lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for le, c in zip(m.buckets, m.counts):
                    ls = _label_str(labels + (("le", str(le)),))
                    lines.append(f"{name}_bucket{ls} {c}")
                ls = _label_str(labels)
                lines.append(f"{name}_bucket"
                             f"{_label_str(labels + (('le', '+Inf'),))} "
                             f"{m.count}")
                lines.append(f"{name}_sum{ls} {m.sum}")
                lines.append(f"{name}_count{ls} {m.count}")
            else:
                lines.append(f"{name}{_label_str(labels)} {m.value}")
        return "\n".join(lines) + "\n"

    def to_json(self, path: str, *, extra: Mapping[str, Any] | None = None,
                ) -> dict[str, Any]:
        payload: dict[str, Any] = {"schema": "obs_metrics/v1",
                                   "metrics": self.snapshot()}
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=float)
        return payload
