"""Goodput-loss attribution from span intervals (DESIGN.md §13).

`bench_roofline.py` prices what the hardware (or the sim's cost model)
could serve; `RuntimeMetrics.summary` reports what actually counted
under the TTFT SLO.  This module decomposes the gap between the two
into CAUSES, read purely off the span stream's timestamps — no new
instrumentation, no device syncs.

Per finished request, the interval from arrival to first token is
partitioned exactly (the buckets sum to TTFT):

  * ``queue_wait``    — queued → first admission attempt that blocked,
    or → admission when never blocked: pure scheduling wait.
  * ``page_blocked``  — first ``page_blocked`` refusal → admission:
    the wait charged to KV-page pressure, not lane scarcity.
  * ``esc_wait`` / ``esc_catchup`` — escalation intervals overlapping
    the pre-first-token window (waiting for a deep lane vs replaying
    the prefix through the deep rung).
  * ``prefill``       — admission → first token, net of escalation
    overlap: prompt prefill sharing the step budget.
  * ``gear_transient``— any of the above reclassified when it overlaps
    a ``gear_transient_s`` window after a ``gear_switch`` (the cost of
    switching, not of the steady state).

Escalation intervals after the first token are tallied into the same
``esc_*`` totals (they stretch streams, not TTFT) but never into the
TTFT partition.

Under the fault plane (DESIGN.md §14) two more causes keep the
partition exact:

  * ``cancelled``     — a reaped request's wait from its last
    lifecycle edge (admission, else arrival) to its ``cancel`` /
    ``deadline_miss`` event; its emitted tokens are charged here
    wholesale in `goodput_lossmap` (they never count as goodput).
  * ``stall``         — any bucket time overlapping a scripted
    ``rung_stall`` window, reclassified the same way gear transients
    are (transient windows take precedence where the two overlap).

`goodput_lossmap` then attributes the tokens of every SLO-missing
request across its TTFT buckets proportionally, prices them per second,
and — when a roofline ceiling is supplied — adds the capacity the serve
never even attempted (``unserved_capacity``).  The result is an
``obs_metrics/v1``-exportable dict `ServeReport.add_lossmap` renders.
"""

from __future__ import annotations

from typing import Any, Iterable

from repro.serving.obs.trace import Event

__all__ = ["stall_decomposition", "goodput_lossmap", "sim_token_ceiling",
           "STALL_CAUSES"]

STALL_CAUSES = ("queue_wait", "page_blocked", "prefill", "esc_wait",
                "esc_catchup", "gear_transient", "cancelled", "stall")


def _merge(windows: list[tuple[float, float]]) -> list[tuple[float, float]]:
    if not windows:
        return []
    windows = sorted(windows)
    out = [windows[0]]
    for s, e in windows[1:]:
        if s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def _overlap(s: float, e: float,
             windows: list[tuple[float, float]]) -> float:
    tot = 0.0
    for ws, we in windows:
        if we <= s:
            continue
        if ws >= e:
            break
        tot += min(e, we) - max(s, ws)
    return tot


def _intersect(a: list[tuple[float, float]],
               b: list[tuple[float, float]]) -> list[tuple[float, float]]:
    """Intersection of two merged window lists (both sorted)."""
    out = []
    for s, e in a:
        for ws, we in b:
            lo, hi = max(s, ws), min(e, we)
            if hi > lo:
                out.append((lo, hi))
    return _merge(out)


def stall_decomposition(events: Iterable[Event], *,
                        gear_transient_s: float = 0.0,
                        ) -> dict[str, Any]:
    """Fold the event stream into per-request TTFT partitions plus
    stream-wide escalation totals.  Returns::

        {"requests": {rid: {"ttft": s, "tokens": n, "finished": bool,
                            "buckets": {cause: s, ...}}},
         "stalls_s": {cause: total s}, "transient_windows": [...]}
    """
    arrival: dict[int, float] = {}
    first_block: dict[int, float] = {}
    admit_t: dict[int, float] = {}
    first_tok: dict[int, float] = {}
    tokens: dict[int, int] = {}
    finished: set[int] = set()
    reap_t: dict[int, float] = {}
    # escalation interval capture: (rid, model) -> [t_esc, t_wait, t_grant]
    esc_open: dict[tuple[int, int], list] = {}
    esc_ivals: dict[int, list[tuple[float, float, str]]] = {}
    switches: list[float] = []
    stall_w: list[tuple[float, float]] = []

    def _close(key: tuple[int, int], t_end: float) -> None:
        t0, tw, tg = esc_open.pop(key)
        rid = key[0]
        ivals = esc_ivals.setdefault(rid, [])
        if tw is not None:
            ivals.append((tw, tg if tg is not None else t_end, "esc_wait"))
        start = tg if tg is not None else (tw if tw is not None else t0)
        if t_end > start:
            ivals.append((start, t_end, "esc_catchup"))

    for ev in events:
        k = ev.kind
        if k == "queued":
            arrival.setdefault(ev.rid, ev.t)
        elif k == "page_blocked":
            first_block.setdefault(ev.rid, ev.t)
        elif k == "admitted":
            admit_t.setdefault(ev.rid, ev.t)
        elif k == "token":
            first_tok.setdefault(ev.rid, ev.t)
            tokens[ev.rid] = tokens.get(ev.rid, 0) + 1
        elif k == "escalate":
            esc_open[(ev.rid, ev.model)] = [ev.t, None, None]
        elif k == "esc_wait":
            st = esc_open.get((ev.rid, ev.model))
            if st is not None and st[1] is None:
                st[1] = ev.t
        elif k == "esc_grant":
            st = esc_open.get((ev.rid, ev.model))
            if st is not None:
                st[2] = ev.t
        elif k in ("esc_resolve", "recall", "deescalate"):
            if (ev.rid, ev.model) in esc_open:
                _close((ev.rid, ev.model), ev.t)
        elif k == "finish":
            finished.add(ev.rid)
            for key in [key for key in esc_open if key[0] == ev.rid]:
                _close(key, ev.t)
        elif k in ("cancel", "deadline_miss"):
            reap_t.setdefault(ev.rid, ev.t)
            for key in [key for key in esc_open if key[0] == ev.rid]:
                _close(key, ev.t)
        elif k == "rung_stall":
            d = dict(ev.data)
            stall_w.append((float(d.get("t0", ev.t)),
                            float(d.get("until", ev.t))))
        elif k == "gear_switch":
            switches.append(ev.t)

    transient = _merge([(t, t + gear_transient_s) for t in switches]) \
        if gear_transient_s > 0 else []
    stall_w = _merge(stall_w)
    # transient windows win where the two overlap (the partition must
    # charge each second exactly once)
    stall_x = _intersect(stall_w, transient)

    requests: dict[int, dict[str, Any]] = {}
    stalls = {c: 0.0 for c in STALL_CAUSES}
    for rid, tq in arrival.items():
        ta = admit_t.get(rid)
        t1 = first_tok.get(rid)
        buckets = {c: 0.0 for c in STALL_CAUSES}
        ivals: list[tuple[float, float, str]] = []
        if ta is not None:
            tb = first_block.get(rid)
            if tb is not None and tq <= tb <= ta:
                ivals.append((tq, tb, "queue_wait"))
                ivals.append((tb, ta, "page_blocked"))
            else:
                ivals.append((tq, ta, "queue_wait"))
            if t1 is not None and t1 > ta:
                # prefill = admit→first-token net of escalation overlap
                esc_in = [(max(s, ta), min(e, t1), c)
                          for s, e, c in esc_ivals.get(rid, ())
                          if e > ta and s < t1]
                esc_s = sum(e - s for s, e, _ in esc_in)
                ivals.extend(esc_in)
                ivals.append((ta, t1, "prefill"))
                buckets["prefill"] -= esc_s   # net out the overlap
        tr = reap_t.get(rid)
        if tr is not None and t1 is None:
            # reaped before its first token: the tail from the last
            # lifecycle edge to the reap is the cancel's cost
            start = ta if ta is not None else tq
            if tr > start:
                ivals.append((start, tr, "cancelled"))
        for s, e, c in ivals:
            dur = max(0.0, e - s)
            hot = _overlap(s, e, transient)
            st = _overlap(s, e, stall_w) - _overlap(s, e, stall_x)
            buckets[c] += dur - hot - st
            buckets["gear_transient"] += hot
            buckets["stall"] += st
        ttft = (t1 - tq) if t1 is not None else None
        requests[rid] = {"ttft": ttft, "tokens": tokens.get(rid, 0),
                         "finished": rid in finished,
                         "reaped": rid in reap_t, "buckets": buckets}
        for c, v in buckets.items():
            stalls[c] += v
        # post-first-token escalation time: stream stretch, not TTFT
        if t1 is not None:
            for s, e, c in esc_ivals.get(rid, ()):
                if e > t1:
                    stalls[c] += e - max(s, t1)
    return {"requests": requests, "stalls_s": stalls,
            "transient_windows": transient, "stall_windows": stall_w}


def sim_token_ceiling(n_lanes: int, seg_time: float, overhead: float,
                      mean_probes: float = 1.0) -> float:
    """The sim cost model's token roofline (lane accounting): every
    lane emits one token per step and a step costs ``overhead +
    seg_time * mean_probes`` virtual seconds — the same identity the
    control plane's `GearPlanner` prices gears with."""
    return n_lanes / (overhead + seg_time * float(mean_probes))


def goodput_lossmap(events: Iterable[Event], *, slo: float,
                    duration: float | None = None,
                    ceiling_tok_s: float | None = None,
                    gear_transient_s: float = 0.0) -> dict[str, Any]:
    """Decompose ``ceiling - goodput`` into attributed causes.

    Tokens of every SLO-missing request are split across its TTFT
    buckets proportionally and priced per second of serve duration;
    ``unserved_capacity`` absorbs the ceiling the serve never attempted
    (only when an explicit roofline ceiling is supplied).
    """
    events = list(events)
    decomp = stall_decomposition(events, gear_transient_s=gear_transient_s)
    if duration is None:
        duration = max((ev.t for ev in events), default=0.0)
    duration = float(duration) or 1.0

    total_tokens = 0
    good_tokens = 0
    missed = 0
    reaped = 0
    loss_tokens = {c: 0.0 for c in STALL_CAUSES}
    for rid, rec in decomp["requests"].items():
        total_tokens += rec["tokens"]
        if rec.get("reaped"):
            # a reaped request's tokens never count as goodput — the
            # answer was abandoned — so they are charged to the cancel
            # wholesale, keeping the partition exact
            reaped += 1
            loss_tokens["cancelled"] += rec["tokens"]
            continue
        ttft = rec["ttft"]
        if ttft is None:
            continue
        if ttft <= slo:
            good_tokens += rec["tokens"]
            continue
        missed += 1
        buckets = rec["buckets"]
        mass = sum(buckets.values())
        if mass <= 0:
            # zero-width partition (e.g. instant admission + token):
            # charge the scheduling bucket so no token goes unattributed
            loss_tokens["queue_wait"] += rec["tokens"]
            continue
        for c, v in buckets.items():
            loss_tokens[c] += rec["tokens"] * (v / mass)

    throughput = total_tokens / duration
    goodput = good_tokens / duration
    loss_rate = {c: v / duration for c, v in loss_tokens.items()}
    ceiling = ceiling_tok_s if ceiling_tok_s is not None else throughput
    if ceiling_tok_s is not None:
        loss_rate["unserved_capacity"] = max(0.0, ceiling - throughput)
    return {
        "schema": "obs_lossmap/v1",
        "slo": float(slo),
        "duration_s": duration,
        "throughput_tok_s": throughput,
        "goodput_tok_s": goodput,
        "ceiling_tok_s": float(ceiling),
        "loss_total_tok_s": max(0.0, ceiling - goodput),
        "loss_tok_s": loss_rate,
        "stalls_s": decomp["stalls_s"],
        "requests_missed": missed,
        "requests_reaped": reaped,
        "requests_total": len(decomp["requests"]),
    }
