"""Unified serving observability plane (DESIGN.md §12).

One package threads through every serving subsystem:

  * `trace`    — `SpanTracer`: bounded host-side ring of lifecycle
    events (queued → admitted → prefill chunks → per-token decode →
    escalate/recall/de-escalate → finish), fed only from data the
    steppers already sync once per token.  Zero overhead when absent:
    every producer guards with ``if tracer is not None``.
  * `registry` — `MetricsRegistry`: counters/gauges/histograms with
    labels, absorbing the per-subsystem stats dicts behind one
    ``snapshot()`` / Prometheus-text / JSON surface.
  * `export`   — Chrome/Perfetto trace-event JSON (one track per
    lane, one per model rung, decision instants) + optional
    ``jax.profiler`` capture hooks.
  * `flight`   — `FlightRecorder`: last-N-events post-mortem bundles
    on anomaly triggers (TTFT-SLO breach burst, page exhaustion,
    stuck escalation waiter, gear thrash).
  * `audit`    — `InvariantLedger`: streaming contracts over the same
    listener hook (page conservation, escalations resolve, lane
    occupancy, walk-floor monotonicity, TTFT-exactly-once, admission
    never drops) with flight-bundle dumps on violation.
  * `replay`   — deterministic re-serve of an exported trace artifact
    with `span_digest` / `decision_digest` equality checks.
  * `lossmap`  — goodput-loss attribution: the achieved-vs-roofline
    gap decomposed into causes from span intervals.
  * `regret`   — `RegretMeter`: per-request distance from the
    offline-optimal walk (the paper's separation theorem as live
    telemetry), decomposed by decision cause, as a pure listener.
  * `pareto`   — `ParetoTracker`: the streaming empirical
    accuracy-latency frontier with per-gear attribution.
  * `report`   — the one serve report renderer (replaces the bespoke
    print blocks `launch/serve.py` used to duplicate).

`Observability` is the small bundle the `Server` accepts.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.serving.obs.audit import InvariantLedger, audit_events
from repro.serving.obs.flight import FlightRecorder
from repro.serving.obs.pareto import ParetoTracker
from repro.serving.obs.regret import RegretMeter, regret_events
from repro.serving.obs.registry import MetricsRegistry
from repro.serving.obs.trace import SpanTracer, decision_attribution

__all__ = [
    "FlightRecorder",
    "InvariantLedger",
    "MetricsRegistry",
    "Observability",
    "ParetoTracker",
    "RegretMeter",
    "SpanTracer",
    "audit_events",
    "decision_attribution",
    "regret_events",
]


@dataclass
class Observability:
    """What a `Server` threads through a serve: a tracer (always, when
    observability is on), an optional flight recorder, invariant
    ledger and regret meter riding the same event stream, and an
    optional ``jax.profiler`` logdir for kernel-level capture around
    token steps."""

    tracer: SpanTracer = field(default_factory=SpanTracer)
    flight: FlightRecorder | None = None
    ledger: InvariantLedger | None = None
    regret: RegretMeter | None = None
    profile_dir: str | None = None
