"""Trace export: `SpanTracer` events → Chrome/Perfetto trace-event
JSON, plus optional ``jax.profiler`` capture around token steps.

Layout in the Perfetto UI:

  * pid 0 "lanes"   — one thread per lane; each request renders as a
    complete ("X") span from admission to finish, with per-token
    decisions ("token", "prefill_chunk") as thread-scoped instants.
  * pid 1 "models"  — one thread per model rung; escalate / esc_wait /
    esc_grant / esc_resolve / recall / deescalate land here as
    instants so ladder traffic reads at a glance.
  * pid 2 "control" — gear_switch / recal / page_blocked instants and
    "C" counter tracks (queue depth, pages in use) sampled at step
    edges.

Timestamps are the serve clock (virtual seconds in sim mode) scaled
to microseconds — Chrome's native unit — so a sim trace is exactly
deterministic and CI can pin its digest.
"""

from __future__ import annotations

import contextlib
import json
from typing import Any, Iterable

from repro.serving.obs.trace import Event

__all__ = ["to_perfetto", "write_trace", "events_doc", "write_events",
           "profiler_capture"]

_LANE_KINDS = {"token", "prefill_chunk", "admitted", "finish",
               "cancel", "deadline_miss"}
_MODEL_KINDS = {"escalate", "esc_wait", "esc_grant", "esc_resolve",
                "recall", "deescalate", "rung_stall"}


def _us(t: float) -> float:
    return round(t * 1e6, 3)


def to_perfetto(events: Iterable[Event], *,
                title: str = "t-tamer serve") -> dict[str, Any]:
    """Build a Chrome trace-event document from tracer events."""
    ev_list = list(events)
    out: list[dict[str, Any]] = []
    lanes: set[int] = set()
    models: set[int] = set()
    # Request spans: admitted -> finish per rid (X events need a dur).
    admit_at: dict[int, tuple[float, int]] = {}
    last_t = 0.0
    for ev in ev_list:
        last_t = max(last_t, ev.t)
        if ev.kind == "admitted" and ev.lane >= 0:
            admit_at[ev.rid] = (ev.t, ev.lane)
        if ev.lane >= 0:
            lanes.add(ev.lane)
        if ev.model >= 0:
            models.add(ev.model)

    for ev in ev_list:
        d = dict(ev.data)
        args: dict[str, Any] = {k: v for k, v in d.items()
                                if isinstance(v, (int, float, str, bool))}
        if ev.rid >= 0:
            args["rid"] = ev.rid
        if ev.kind == "queued":
            # Exact arrival stamp: the instant's ``ts`` is µs-rounded,
            # but replay (obs/replay.py) needs the raw serve-clock float.
            args["t_s"] = ev.t
        if ev.kind in ("finish", "cancel", "deadline_miss"):
            # every terminal kind closes the admit->end request span;
            # a reaped request renders with its terminal kind suffixed
            start = admit_at.pop(ev.rid, None)
            if start is not None:
                t0, lane = start
                name = (f"req {ev.rid}" if ev.kind == "finish"
                        else f"req {ev.rid} ({ev.kind})")
                out.append({"ph": "X", "name": name,
                            "cat": "request", "pid": 0, "tid": lane,
                            "ts": _us(t0), "dur": _us(ev.t - t0),
                            "args": args})
            if ev.kind == "finish":
                continue
            # cancel / deadline_miss keep their instant marker too
        if ev.kind == "counter":
            for k, v in d.items():
                if isinstance(v, (int, float)):
                    out.append({"ph": "C", "name": k, "pid": 2, "tid": 0,
                                "ts": _us(ev.t), "args": {"value": v}})
            continue
        if ev.kind in _MODEL_KINDS:
            pid, tid = 1, max(ev.model, 0)
        elif ev.kind in _LANE_KINDS and ev.lane >= 0:
            pid, tid = 0, ev.lane
        else:                      # queued / page_blocked / control plane
            pid, tid = 2, 0
        out.append({"ph": "i", "s": "t", "name": ev.kind, "cat": "decision",
                    "pid": pid, "tid": tid, "ts": _us(ev.t), "args": args})

    # Unfinished requests still render as spans up to the last event.
    for rid, (t0, lane) in sorted(admit_at.items()):
        out.append({"ph": "X", "name": f"req {rid} (open)",
                    "cat": "request", "pid": 0, "tid": lane,
                    "ts": _us(t0), "dur": _us(max(0.0, last_t - t0)),
                    "args": {"rid": rid, "open": True}})

    meta: list[dict[str, Any]] = []
    for pid, pname in ((0, "lanes"), (1, "models"), (2, "control")):
        meta.append({"ph": "M", "name": "process_name", "pid": pid,
                     "tid": 0, "args": {"name": pname}})
    for lane in sorted(lanes):
        meta.append({"ph": "M", "name": "thread_name", "pid": 0,
                     "tid": lane, "args": {"name": f"lane {lane}"}})
    for m in sorted(models):
        meta.append({"ph": "M", "name": "thread_name", "pid": 1,
                     "tid": m, "args": {"name": f"model {m}"}})
    meta.append({"ph": "M", "name": "thread_name", "pid": 2, "tid": 0,
                 "args": {"name": "control plane"}})

    return {"traceEvents": meta + out,
            "displayTimeUnit": "ms",
            "otherData": {"title": title, "clock": "serve-seconds"}}


def write_trace(tracer, path: str, *, title: str = "t-tamer serve",
                faults=None, regret=None) -> dict[str, Any]:
    doc = to_perfetto(tracer.events, title=title)
    doc["otherData"]["events_dropped"] = tracer.dropped
    doc["otherData"]["span_digest"] = tracer.span_digest()
    doc["otherData"]["decision_digest"] = tracer.decision_digest()
    if faults is not None:
        doc["otherData"]["faults"] = faults.as_doc()
    if regret is not None:
        # the regret meter is a listener, not a producer — its counter
        # track is synthesized here at export time (pid 2, one sample
        # per finished request) so the span stream itself stays
        # bit-identical with the meter on or off
        doc["traceEvents"].extend(
            {"ph": "C", "name": "regret", "pid": 2, "tid": 0,
             "ts": _us(t), "args": {"value": r}}
            for t, r in regret.counter_points())
    with open(path, "w") as f:
        json.dump(doc, f, default=float)
    return doc


def events_doc(tracer, *, faults=None) -> dict[str, Any]:
    """Raw-ring export (schema ``obs_trace/v1``): the lossless
    counterpart to the Perfetto document.  Keeps every event field
    bit-exactly (JSON floats round-trip), plus the two digests and the
    drop count — everything `obs/replay.py` needs to reconstruct the
    workload and verify a re-serve, with no µs rounding in the way.
    ``faults``: an optional `FaultPlan` whose ``faults/v1`` doc is
    embedded so a chaos serve replays under the same script."""
    doc = {
        "schema": "obs_trace/v1",
        "clock": "serve-seconds",
        "events": [ev.as_dict() for ev in tracer.events],
        "events_dropped": tracer.dropped,
        "span_digest": tracer.span_digest(),
        "decision_digest": tracer.decision_digest(),
    }
    if faults is not None:
        doc["faults"] = faults.as_doc()
    return doc


def write_events(tracer, path: str, *, faults=None) -> dict[str, Any]:
    doc = events_doc(tracer, faults=faults)
    with open(path, "w") as f:
        json.dump(doc, f, default=float)
    return doc


@contextlib.contextmanager
def profiler_capture(logdir: str | None):
    """Optional ``jax.profiler`` capture around the serve loop for
    kernel-level attribution against `bench_roofline.py`.  A no-op
    when ``logdir`` is falsy, and degrades to a no-op if the profiler
    backend is unavailable in this build."""
    if not logdir:
        yield
        return
    import jax
    try:
        jax.profiler.start_trace(logdir)
    except Exception:                     # pragma: no cover - env specific
        yield
        return
    try:
        yield
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception:                 # pragma: no cover - env specific
            pass
