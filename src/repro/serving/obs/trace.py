"""`SpanTracer` — bounded host-side ring buffer of request lifecycle
events (DESIGN.md §12).

Every event is one `Event` record ``(t, kind, rid, lane, model,
data)`` appended by whichever subsystem observed it; the producers
only ever touch data they already sync to the host once per token
(the served/emitted arrays, the router's slot maps, the pool's page
counters), so the jitted device program is untouched and a serve with
no tracer attached pays nothing beyond ``if tracer is not None``.

Event kinds (the schema CI validates in `benchmarks/check_trace.py`):

  queued        request entered the queue        (rid)
  admitted      request bound to a lane          (rid, lane)
  prefill_chunk one chunk of prompt prefilled    (rid, lane, width, done)
  token         one decode token served          (rid, lane, node, sid,
                                                  token?, loss?, esc?,
                                                  ttft? on first token)
  escalate      router began an escalation       (rid, model)
  esc_wait      escalation queued for a lane     (rid, model)
  esc_grant     waiter got its deep lane         (rid, model, lane)
  esc_resolve   catch-up done, rung serving      (rid, model)
  recall        deep rung exited at shallow node (rid, model, node)
  deescalate    request stepped back down        (rid, model)
  page_blocked  admission refused: no KV pages   (rid)
  gear_switch   control plane swapped gears      (from, to, names)
  recal         tables re-fit from served rows   (n_rows)
  counter       sampled gauges at a step edge    (queue, pages, ...)
  finish        request completed                (rid, lane)
  cancel        client hung up, request reaped   (rid, lane?)
  deadline_miss deadline expired, request reaped (rid, lane?)
  rung_stall    fault window froze a model rung  (model, t0, until)

Two digests:

  * `span_digest()` hashes the FULL ring — kinds, ids and virtual
    timestamps — so a seeded sim serve pins byte-for-byte (the golden
    value lives in tests, same idiom as the strategy goldens).
  * `decision_digest()` hashes only the per-request decision streams
    (rid → ordered served nodes), which is invariant to arrival
    order and lane placement — the tracer-level mirror of the
    (rid, token)-keyed trace-row property.
"""

from __future__ import annotations

import collections
import hashlib
from dataclasses import dataclass
from typing import Any, Callable, Iterable

__all__ = ["Event", "SpanTracer", "decision_attribution"]


@dataclass(frozen=True, slots=True)
class Event:
    t: float
    kind: str
    rid: int = -1
    lane: int = -1
    model: int = -1
    data: tuple = ()          # sorted (key, value) pairs, hashable

    def as_dict(self) -> dict[str, Any]:
        d = {"t": self.t, "kind": self.kind}
        if self.rid >= 0:
            d["rid"] = self.rid
        if self.lane >= 0:
            d["lane"] = self.lane
        if self.model >= 0:
            d["model"] = self.model
        d.update(self.data)
        return d


class SpanTracer:
    """Bounded ring of `Event`s + per-request live span index.

    ``capacity`` bounds the ring; ``span_events`` bounds any single
    request's indexed span (events past the cap are counted, not
    kept); ``keep_finished`` bounds how many completed spans stay
    addressable for post-mortems and tests.  Everything is O(1)
    amortised per event and strictly host-side.
    """

    def __init__(self, capacity: int = 65536, *, span_events: int = 512,
                 keep_finished: int = 256):
        self.capacity = int(capacity)
        self.events: collections.deque[Event] = collections.deque(
            maxlen=self.capacity)
        self.dropped = 0          # ring evictions
        self.span_events = int(span_events)
        self._live: dict[int, list[Event]] = {}
        self._span_dropped: collections.Counter = collections.Counter()
        self._done: collections.OrderedDict[int, list[Event]] = \
            collections.OrderedDict()
        self.keep_finished = int(keep_finished)
        self._clock: Callable[[], float] | None = None
        self.listener: Callable[[Event], None] | None = None
        self.n_emitted = 0

    # ---------------------------------------------------------- wiring
    def bind_clock(self, clock: Callable[[], float]) -> None:
        """Events emitted without an explicit ``t`` stamp from here —
        the server binds its own clock (virtual in sim mode, so the
        whole trace is deterministic)."""
        self._clock = clock

    def add_listener(self, fn: Callable[[Event], None]) -> None:
        """Chain ``fn`` onto the listener hook so several consumers
        (flight recorder, invariant ledger, ...) can ride the same
        stream.  Listeners fire in registration order and see every
        emit — including events the bounded ring later evicts."""
        prev = self.listener
        if prev is None:
            self.listener = fn
            return

        def _fan(ev: Event, _a=prev, _b=fn) -> None:
            _a(ev)
            _b(ev)

        self.listener = _fan

    # ---------------------------------------------------------- emit
    def emit(self, kind: str, *, t: float | None = None, rid: int = -1,
             lane: int = -1, model: int = -1, **data: Any) -> None:
        if t is None:
            t = self._clock() if self._clock is not None else 0.0
        ev = Event(float(t), kind, int(rid), int(lane), int(model),
                   tuple(sorted(data.items())))
        if len(self.events) == self.capacity:
            self.dropped += 1
        self.events.append(ev)
        self.n_emitted += 1
        if ev.rid >= 0:
            span = self._live.get(ev.rid)
            if span is None:
                span = self._live[ev.rid] = []
            if len(span) < self.span_events:
                span.append(ev)
            else:
                self._span_dropped[ev.rid] += 1
            if kind in ("finish", "cancel", "deadline_miss"):
                self._retire(ev.rid)
        if self.listener is not None:
            self.listener(ev)

    def _retire(self, rid: int) -> None:
        span = self._live.pop(rid, None)
        if span is None:
            return
        self._done[rid] = span
        while len(self._done) > self.keep_finished:
            old, _ = self._done.popitem(last=False)
            self._span_dropped.pop(old, None)

    # ---------------------------------------------------------- queries
    def request_span(self, rid: int) -> list[Event]:
        """Full recorded span for ``rid`` — live or recently finished."""
        return list(self._live.get(rid) or self._done.get(rid) or ())

    def live_rids(self) -> list[int]:
        return list(self._live)

    def span_dropped(self, rid: int) -> int:
        return int(self._span_dropped.get(rid, 0))

    # ---------------------------------------------------------- digests
    @staticmethod
    def _canon(ev: Event) -> str:
        data = ",".join(f"{k}={v!r}" for k, v in ev.data)
        return f"{ev.t!r}|{ev.kind}|{ev.rid}|{ev.lane}|{ev.model}|{data}"

    def span_digest(self) -> str:
        """sha256 over the canonical ring — timestamps included, so a
        seeded virtual-clock serve reproduces this byte-for-byte."""
        h = hashlib.sha256()
        for ev in self.events:
            h.update(self._canon(ev).encode())
            h.update(b"\n")
        return h.hexdigest()

    def decision_digest(self) -> str:
        """sha256 over rid-sorted per-request served-node streams only
        — no timestamps, no lanes — hence invariant to arrival order
        and lane placement for (rid, token)-keyed sim traces."""
        streams: dict[int, list[int]] = {}
        for ev in self.events:
            if ev.kind == "token":
                node = dict(ev.data).get("node", -1)
                streams.setdefault(ev.rid, []).append(int(node))
        h = hashlib.sha256()
        for rid in sorted(streams):
            h.update(f"{rid}:{streams[rid]}".encode())
            h.update(b"\n")
        return h.hexdigest()

    # ---------------------------------------------------------- stats
    def stats(self) -> dict[str, int]:
        return {
            "events": len(self.events),
            "emitted": self.n_emitted,
            "dropped": self.dropped,
            "live_spans": len(self._live),
            "finished_spans": len(self._done),
        }


def decision_attribution(events: Iterable[Event],
                         gear_of: Callable[[int], str] | None = None,
                         ) -> list[dict[str, Any]]:
    """Aggregate token events into decision-attribution rows: for each
    (exit node, gear, escalated) cell, the tokens served there plus
    the latency and served-loss mass that decision produced.  Latency
    contribution is the inter-token gap closed by that token (TTFT for
    the first), read straight off the event stream's timestamps —
    virtual seconds in sim mode, wall seconds in engine mode."""
    cells: dict[tuple, dict[str, Any]] = {}
    last_t: dict[int, float] = {}
    arrival: dict[int, float] = {}
    for ev in events:
        if ev.kind == "queued":
            arrival[ev.rid] = ev.t
            continue
        if ev.kind != "token":
            continue
        d = dict(ev.data)
        node = int(d.get("node", -1))
        sid = int(d.get("sid", -1))
        esc = bool(d.get("esc", False))
        prev = last_t.get(ev.rid, arrival.get(ev.rid, ev.t))
        gap = max(0.0, ev.t - prev)
        last_t[ev.rid] = ev.t
        key = (node, sid, esc)
        cell = cells.get(key)
        if cell is None:
            cell = cells[key] = {
                "node": node,
                "gear": gear_of(sid) if gear_of is not None else str(sid),
                "escalated": esc,
                "tokens": 0,
                "latency_sum_s": 0.0,
                "served_loss_sum": 0.0,
                "_loss_n": 0,
            }
        cell["tokens"] += 1
        cell["latency_sum_s"] += gap
        loss = d.get("loss")
        if loss is not None:
            cell["served_loss_sum"] += float(loss)
            cell["_loss_n"] += 1
    rows = []
    for key in sorted(cells):
        cell = cells[key]
        n_loss = cell.pop("_loss_n")
        cell["latency_sum_s"] = round(cell["latency_sum_s"], 6)
        cell["served_loss_sum"] = round(cell["served_loss_sum"], 6)
        cell["served_loss_mean"] = (
            round(cell["served_loss_sum"] / n_loss, 6) if n_loss else None)
        rows.append(cell)
    return rows
