"""Deterministic trace replay (DESIGN.md §13).

Every exported trace artifact is a self-contained repro: the ``queued``
events carry the full request spec (arrival stamp, prompt bytes, token
budget, per-request strategy/lambda), so the workload schedule can be
reconstructed from the artifact alone — no access to the original
workload generator or its seed.  Replaying re-serves that schedule
through the same virtual-clock stepper and asserts both digests:

  * ``span_digest``     — byte-exact event stream (timestamps included),
  * ``decision_digest`` — per-rid served-node streams (arrival-order and
    lane-placement invariant).

Two artifact shapes are accepted:

  * ``obs_trace/v1`` (`export.write_events`) — the lossless raw ring;
    the canonical replay input (floats round-trip through JSON exactly).
  * Chrome/Perfetto trace-event JSON (`export.write_trace`) — queued
    instants carry the same args plus a raw ``t_s`` stamp (the instant's
    own ``ts`` is µs-rounded), and ``otherData`` embeds the reference
    digests.  Span-digest equality additionally needs the raw ring, so
    a Perfetto-only replay verifies the decision digest and reports the
    span digest as unverifiable.

A ring that dropped events (``events_dropped > 0``) cannot be a
faithful workload record — arrivals may have been evicted — so replay
refuses it as ``unverifiable`` rather than reporting a hollow match.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable

import numpy as np

from repro.serving.obs.trace import Event, SpanTracer
from repro.serving.runtime.request import Request

__all__ = ["ReplayResult", "load_artifact", "events_from_doc",
           "workload_from_events", "workload_from_perfetto", "replay"]


@dataclasses.dataclass
class ReplayResult:
    ok: bool
    n_requests: int
    span_digest: str | None          # recomputed by the re-serve
    decision_digest: str | None
    ref_span_digest: str | None      # carried by the artifact
    ref_decision_digest: str | None
    mismatches: list[str]

    def summary(self) -> str:
        verdict = "MATCH" if self.ok else "MISMATCH"
        return (f"replay {verdict}: {self.n_requests} requests; "
                + ("; ".join(self.mismatches) if self.mismatches
                   else "span+decision digests equal"))


def _decode_prompt(hexstr: str) -> np.ndarray:
    return np.frombuffer(bytes.fromhex(hexstr), "<u4").astype(np.int32)


def _request_from(rid: int, t: float, d: dict[str, Any]) -> Request:
    if "prompt" in d:
        prompt = _decode_prompt(d["prompt"])
    else:                       # older traces: length only, content zeros
        prompt = np.zeros(int(d.get("plen", 1)), np.int32)
    return Request(rid=int(rid), prompt=prompt,
                   max_tokens=int(d.get("ntok", 1)), arrival=float(t),
                   lam=float(d["lam"]) if "lam" in d else None,
                   strategy=d.get("strategy"),
                   deadline=(float(d["deadline"])
                             if "deadline" in d else None),
                   cancel_at=(float(d["cancel_at"])
                              if "cancel_at" in d else None))


def events_from_doc(doc: dict[str, Any]) -> list[Event]:
    """Rebuild `Event` records from an ``obs_trace/v1`` document."""
    if doc.get("schema") != "obs_trace/v1":
        raise ValueError(f"not an obs_trace/v1 document: "
                         f"{doc.get('schema')!r}")
    out = []
    for d in doc["events"]:
        data = tuple(sorted(
            (k, v) for k, v in d.items()
            if k not in ("t", "kind", "rid", "lane", "model")))
        out.append(Event(float(d["t"]), str(d["kind"]),
                         int(d.get("rid", -1)), int(d.get("lane", -1)),
                         int(d.get("model", -1)), data))
    return out


def workload_from_events(events) -> list[Request]:
    """Reconstruct the workload schedule from queued events."""
    reqs = []
    seen = set()
    for ev in events:
        if ev.kind != "queued" or ev.rid in seen:
            continue
        seen.add(ev.rid)
        reqs.append(_request_from(ev.rid, ev.t, dict(ev.data)))
    return sorted(reqs, key=lambda r: (r.arrival, r.rid))


def workload_from_perfetto(doc: dict[str, Any]) -> list[Request]:
    """Reconstruct the workload from a Perfetto export's queued
    instants (their args carry the request spec + raw ``t_s``)."""
    reqs = []
    seen = set()
    for row in doc.get("traceEvents", ()):
        if row.get("ph") != "i" or row.get("name") != "queued":
            continue
        args = row.get("args", {})
        rid = int(args.get("rid", -1))
        if rid < 0 or rid in seen:
            continue
        seen.add(rid)
        t = float(args.get("t_s", row.get("ts", 0.0) / 1e6))
        reqs.append(_request_from(rid, t, args))
    return sorted(reqs, key=lambda r: (r.arrival, r.rid))


def load_artifact(path_or_doc) -> dict[str, Any]:
    if isinstance(path_or_doc, str):
        with open(path_or_doc) as f:
            return json.load(f)
    return path_or_doc


def replay(artifact, serve_fn: Callable[[list[Request]], Any],
           ) -> ReplayResult:
    """Re-serve an exported trace artifact and verify the digests.

    ``serve_fn(requests)`` must run the serve (same stepper config,
    strategy bank and seeds as the original — that is the caller's
    contract) and return the `SpanTracer` that observed it (an
    `Observability` bundle or a ``.tracer``-bearing object also works).
    """
    doc = load_artifact(artifact)
    mismatches: list[str] = []

    if doc.get("schema") == "obs_trace/v1":
        dropped = int(doc.get("events_dropped", 0))
        requests = workload_from_events(events_from_doc(doc))
        ref_span = doc.get("span_digest")
        ref_dec = doc.get("decision_digest")
    elif "traceEvents" in doc:
        other = doc.get("otherData", {})
        dropped = int(other.get("events_dropped", 0))
        requests = workload_from_perfetto(doc)
        ref_span = None          # µs rounding: span digest not carried
        ref_dec = other.get("decision_digest")
        if other.get("span_digest") and ref_dec is None:
            mismatches.append("perfetto artifact carries no "
                              "decision_digest")
    else:
        raise ValueError("unrecognized trace artifact (expected "
                         "obs_trace/v1 or Perfetto traceEvents)")

    if dropped > 0:
        return ReplayResult(
            ok=False, n_requests=len(requests), span_digest=None,
            decision_digest=None, ref_span_digest=ref_span,
            ref_decision_digest=ref_dec,
            mismatches=[f"unverifiable: source ring dropped {dropped} "
                        "events — the workload record is incomplete"])

    served = serve_fn(requests)
    tracer = getattr(served, "tracer", served)
    if not isinstance(tracer, SpanTracer):
        raise TypeError("serve_fn must return the SpanTracer that "
                        "observed the re-serve (or an object with a "
                        ".tracer)")
    span = tracer.span_digest()
    dec = tracer.decision_digest()
    if ref_span is not None and span != ref_span:
        mismatches.append(f"span digest {span[:12]}… != "
                          f"reference {ref_span[:12]}…")
    if ref_dec is not None and dec != ref_dec:
        mismatches.append(f"decision digest {dec[:12]}… != "
                          f"reference {ref_dec[:12]}…")
    if ref_span is None and ref_dec is None:
        mismatches.append("artifact carries no reference digests")
    return ReplayResult(ok=not mismatches, n_requests=len(requests),
                        span_digest=span, decision_digest=dec,
                        ref_span_digest=ref_span,
                        ref_decision_digest=ref_dec,
                        mismatches=mismatches)
