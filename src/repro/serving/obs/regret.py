"""`RegretMeter` — the decision-quality plane (DESIGN.md §15).

T-Tamer's separation theorem — recall strategies attain the optimal
accuracy-latency trade-off, no-recall strategies admit no constant-
factor approximation — is checked offline by the benchmark sweeps.
This module turns the optimality gap into LIVE telemetry: for every
finished request, how far did the serve land from that request's
offline-optimal walk, and which decision cost it?

The meter is a pure `SpanTracer` listener, exactly like the
`InvariantLedger`: it adds zero producers, zero device syncs, and a
traced serve with the meter armed is bit-identical to one without (the
listener-purity test pins this).  Everything it needs already rides
the span stream — ``token`` events carry the served node, its bank-row
loss and the walk's deepest probed node; ``recall ... denied=True``
marks governor demotions; ``gear_switch`` marks control transients.

ORACLE.  In **exact** mode (sim steppers, which replay a trace bank)
the meter holds the same ``(T, n)`` loss bank the stepper decides
from, so request ``rid``'s token ``t`` maps to row ``(rid * 9973 + t)
% T`` — the runtime's own deterministic row assignment.  The offline
optimum for every row is solved ONCE per lambda from the calibrated
`Cascade` tables via the existing `solve_skip` / `simulate_skip`
machinery and memoized, so the oracle is O(1) amortized per request.
Per-token regret is measured on the served-loss axis::

    regret(t) = max(0, lam * (loss[row, served] - oracle_loss[row]))

clipped at zero because a realized serve can BEAT the oracle's loss by
overpaying latency — that surplus shows on the Pareto frontier, not in
regret.  When the serve follows the oracle policy (``skip_recall`` on
the same calibration), regret is exactly zero by construction — which
is precisely the paper's theorem as a measurable signal.

In **expected** mode (engine steppers with no trace bank) the realized
loss comes off the token event and the oracle degrades to the solved
tables' expected optimal objective ``tables.value`` — an approximate
floor (it includes explore cost), honest enough for trend telemetry
and labelled as such in the report verdict.

CAUSE PARTITION.  Each positive-regret token lands in exactly ONE
bucket (mirror of `obs/lossmap.py`'s exact-partition style; the
partition-exactness test pins causes summing to total):

  * ``governor_denied``   — a ``recall ... denied=True`` landed for
    this rid in the same step (the degrade governor demoted the walk).
  * ``gear_transient``    — the token falls inside ``gear_transient_s``
    after a ``gear_switch`` (the cost of switching, not steady state).
  * ``escalated_too_late``— the walk served DEEPER than the oracle's
    stop: it paid extra rungs and still lost loss (overthinking the
    paper's Section-3 regime).
  * ``recall_forgone``    — the walk probed at least as deep as the
    oracle's serve node but served a shallower, worse one: the right
    answer was in hand and recall was not used.
  * ``exited_too_early``  — everything else: the walk stopped before
    the oracle's serve node (underthinking).

`regret_events` is the offline mirror over an exported event ring,
with `audit_events`-style ring-overflow honesty: a truncated ring
(``dropped > 0``) demotes the verdict to ``unverifiable`` and moves
the numbers into ``suspect`` rather than asserting them.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Any

import numpy as np

from repro.serving.obs.pareto import ParetoTracker
from repro.serving.obs.trace import Event, SpanTracer

__all__ = ["RegretMeter", "regret_events", "REGRET_CAUSES"]

REGRET_CAUSES = ("exited_too_early", "escalated_too_late",
                 "recall_forgone", "governor_denied", "gear_transient")

_ROW_PRIME = 9973     # the runtime's (rid, token) -> trace-row mapping


class RegretMeter:
    """Per-request regret vs the offline-optimal walk, as a listener.

    ``casc`` is the calibrated `Cascade` whose tables define the
    oracle; ``traces`` the raw ``(T, n)`` loss bank sim steppers replay
    (`bind` pulls it off the stepper when omitted).  ``gear_transient_s``
    reclassifies regret inside post-switch windows, same knob as the
    lossmap.  ``out_dir`` is where `finalize` drops ``regret.json`` /
    ``pareto.json`` when set.
    """

    def __init__(self, casc=None, *, traces=None,
                 gear_transient_s: float = 0.0,
                 out_dir: str | None = None, keep_worst: int = 5):
        self.casc = casc
        self.traces = None if traces is None else np.asarray(traces,
                                                             np.float32)
        self.gear_transient_s = float(gear_transient_s)
        self.out_dir = out_dir
        self.keep_worst = int(keep_worst)
        self.pareto = ParetoTracker()

        self.records: dict[int, dict[str, Any]] = {}   # finished rids
        self.finalized = False
        self._flight = None
        self._controller = None
        self._gear = "fixed"
        self._last_switch: float | None = None
        self._sid: dict[int, int] = {}     # rid -> strategy-bank slot
        self._oracle_memo: dict[float, tuple[np.ndarray, np.ndarray]] = {}

        # O(live-rids) per-request fold state, cleaned at finish/reap
        self._arrival: dict[int, float] = {}
        self._lam: dict[int, float] = {}
        self._tidx: dict[int, int] = {}
        self._sum: dict[int, float] = {}               # regret sum
        self._loss_sum: dict[int, float] = {}          # raw served loss
        self._causes: dict[int, dict[str, float]] = {}
        self._denied: set[int] = set()                 # pending demotions

    # ------------------------------------------------------------ wiring
    def bind(self, tracer: SpanTracer, *, stepper=None, flight=None,
             controller=None) -> None:
        """Attach as a chained listener.  ``stepper`` donates its trace
        bank when the meter was built without one (`SimStepper.bank` /
        `CascadeSimStepper.traces` — both the raw loss array); engine
        steppers have none and the meter serves expected mode.
        ``flight`` receives `note_regret` per finished request for the
        ``regret_burst`` trigger; ``controller`` names the initial
        gear."""
        if self.traces is None and stepper is not None:
            for attr in ("traces", "bank"):
                cand = getattr(stepper, attr, None)
                if isinstance(cand, np.ndarray) and cand.ndim == 2:
                    self.traces = cand
                    break
        self._flight = flight
        self._controller = controller
        if controller is not None:
            gear = getattr(controller, "gear", None)
            name = getattr(gear, "name", None)
            if name:
                self._gear = str(name)
        tracer.add_listener(self.observe)

    @property
    def mode(self) -> str:
        return "exact" if self.traces is not None else "expected"

    # ------------------------------------------------------------ oracle
    def _oracle(self, lam: float) -> tuple[np.ndarray, np.ndarray]:
        """(oracle_loss, oracle_node) over the whole trace bank at
        ``lam`` — solved once per lambda and memoized, so the per-token
        lookup is one array index.  ``oracle_loss`` is in the
        lam-scaled domain `simulate_skip` serves in."""
        key = round(float(lam), 9)
        hit = self._oracle_memo.get(key)
        if hit is not None:
            return hit
        import jax.numpy as jnp

        from repro.core import skip_dp
        from repro.core.support import quantize

        casc = self.casc
        mode = casc.skip_mode or ("cascade" if casc.boundaries
                                  else "cumulative")
        tables = casc.solve_skip(mode)
        scaled = np.asarray(key * self.traces, np.float32)
        bins = np.asarray(quantize(casc.support, jnp.asarray(scaled)))
        served, _, probed = skip_dp.simulate_skip(
            tables, scaled, bins, np.asarray(casc.edge_costs))
        node = np.where(probed, scaled, np.inf).argmin(axis=1)
        # degenerate stop-immediately rows (nothing probed): fall back
        # to the row's best node so regret stays finite and >= 0
        empty = ~probed.any(axis=1)
        if empty.any():
            node[empty] = scaled[empty].argmin(axis=1)
            served = np.where(empty, scaled[np.arange(len(scaled)), node],
                              served)
        out = (np.asarray(served, np.float64), node.astype(np.int64))
        self._oracle_memo[key] = out
        return out

    def _oracle_value(self) -> float:
        """Expected-mode floor: the tables' optimal expected objective."""
        casc = self.casc
        mode = casc.skip_mode or ("cascade" if casc.boundaries
                                  else "cumulative")
        return float(casc.solve_skip(mode).value)

    # ------------------------------------------------------------ stream
    def observe(self, ev: Event) -> None:
        kind = ev.kind
        if kind == "queued":
            self._arrival[ev.rid] = ev.t
            lam = dict(ev.data).get("lam")
            if lam is not None:
                self._lam[ev.rid] = float(lam)
        elif kind == "token":
            self._on_token(ev)
        elif kind == "recall":
            if dict(ev.data).get("denied"):
                self._denied.add(ev.rid)
        elif kind == "gear_switch":
            d = dict(ev.data)
            self._gear = str(d.get("dst_name", d.get("dst", self._gear)))
            self._last_switch = ev.t
        elif kind == "finish":
            self._on_finish(ev)
        elif kind in ("cancel", "deadline_miss"):
            # abandoned stream: regret is undefined for an answer
            # nobody received — drop the fold state, count nothing
            self._drop(ev.rid)

    def _cause_of(self, ev: Event, node: int, deepest: int,
                  oracle_node: int) -> str:
        if ev.rid in self._denied:
            return "governor_denied"
        if (self._last_switch is not None and self.gear_transient_s > 0
                and ev.t - self._last_switch <= self.gear_transient_s):
            return "gear_transient"
        if node > oracle_node:
            return "escalated_too_late"
        if node < oracle_node and deepest >= oracle_node:
            return "recall_forgone"
        return "exited_too_early"

    def _on_token(self, ev: Event) -> None:
        rid = ev.rid
        t = self._tidx.get(rid, 0)
        self._tidx[rid] = t + 1
        d = dict(ev.data)
        sid = d.get("sid")
        if sid is not None:
            self._sid[rid] = int(sid)
        node = int(d.get("node", -1))
        if node < 0 or self.casc is None:
            self._denied.discard(rid)
            return
        lam = self._lam.get(rid, float(self.casc.lam))
        deepest = int(d.get("deepest", node))
        if self.traces is not None:
            row = (rid * _ROW_PRIME + t) % len(self.traces)
            oracle_loss, oracle_node = self._oracle(lam)
            raw = float(self.traces[row, node])
            regret = max(0.0, lam * raw - float(oracle_loss[row]))
            cause = self._cause_of(ev, node, deepest,
                                   int(oracle_node[row]))
        else:
            loss = d.get("loss")
            if loss is None:
                self._denied.discard(rid)
                return
            raw = float(loss)
            regret = max(0.0, lam * raw - self._oracle_value())
            if rid in self._denied:
                cause = "governor_denied"
            elif (self._last_switch is not None
                  and self.gear_transient_s > 0
                  and ev.t - self._last_switch <= self.gear_transient_s):
                cause = "gear_transient"
            elif d.get("esc"):
                cause = "escalated_too_late"
            else:
                cause = "exited_too_early"
        self._denied.discard(rid)
        self._sum[rid] = self._sum.get(rid, 0.0) + regret
        self._loss_sum[rid] = self._loss_sum.get(rid, 0.0) + raw
        if regret > 0.0:
            causes = self._causes.setdefault(
                rid, {c: 0.0 for c in REGRET_CAUSES})
            causes[cause] += regret

    def _on_finish(self, ev: Event) -> None:
        rid = ev.rid
        n = self._tidx.get(rid, 0)
        if n == 0:
            self._drop(rid)
            return
        regret = self._sum.get(rid, 0.0) / n
        causes = {c: v / n for c, v in self._causes.get(
            rid, {c: 0.0 for c in REGRET_CAUSES}).items()}
        loss_mean = self._loss_sum.get(rid, 0.0) / n
        arrival = self._arrival.get(rid, ev.t)
        latency = max(0.0, ev.t - arrival)
        # gear attribution: admission-time routing (the strategy-bank
        # slot the controller's swap pointed new admissions at) when a
        # controller is bound; the last gear_switch name otherwise
        gear = self._gear
        if self._controller is not None and rid in self._sid:
            try:
                gear = self._controller.gear_name_of(self._sid[rid])
            except (IndexError, KeyError):
                pass
        self.records[rid] = {
            "rid": rid, "t": float(ev.t), "tokens": n,
            "regret": regret, "causes": causes,
            "latency_s": latency, "loss_mean": loss_mean,
            "gear": gear,
        }
        self.pareto.add(rid, latency, loss_mean, gear=gear)
        if self._flight is not None:
            note = getattr(self._flight, "note_regret", None)
            if note is not None:
                note(ev.t, rid, regret)
        self._drop(rid)

    def _drop(self, rid: int) -> None:
        for store in (self._arrival, self._lam, self._tidx, self._sum,
                      self._loss_sum, self._causes, self._sid):
            store.pop(rid, None)
        self._denied.discard(rid)

    # ------------------------------------------------------------ report
    def counter_points(self) -> list[tuple[float, float]]:
        """(finish-time, per-request regret) samples for the exporter's
        pid-2 Perfetto counter track."""
        return sorted((rec["t"], rec["regret"])
                      for rec in self.records.values())

    def regret_digest(self) -> str:
        """sha256 over rid-sorted per-request regret + cause splits —
        golden-pinnable on the sim's virtual clock, same idiom as the
        tracer's `span_digest`."""
        h = hashlib.sha256()
        for rid in sorted(self.records):
            rec = self.records[rid]
            causes = ",".join(f"{c}={rec['causes'][c]:.9f}"
                              for c in REGRET_CAUSES)
            h.update(f"{rid}:{rec['regret']:.9f}:{causes}".encode())
            h.update(b"\n")
        return h.hexdigest()

    def finalize(self, t_end: float | None = None) -> dict[str, Any]:
        """Idempotent end-of-serve hook; writes the ``out_dir`` sinks
        once and returns `report`."""
        if not self.finalized:
            self.finalized = True
            if self.out_dir:
                os.makedirs(self.out_dir, exist_ok=True)
                with open(os.path.join(self.out_dir, "regret.json"),
                          "w") as f:
                    json.dump(self.report(), f, indent=1, default=float)
                with open(os.path.join(self.out_dir, "pareto.json"),
                          "w") as f:
                    json.dump(self.pareto.as_doc(), f, indent=1,
                              default=float)
        return self.report()

    def report(self, *, unverifiable: bool = False) -> dict[str, Any]:
        regrets = np.asarray([self.records[r]["regret"]
                              for r in sorted(self.records)], np.float64)
        causes = {c: 0.0 for c in REGRET_CAUSES}
        tokens = 0
        for rec in self.records.values():
            tokens += rec["tokens"]
            for c in REGRET_CAUSES:
                causes[c] += rec["causes"][c]
        worst = sorted(self.records.values(),
                       key=lambda r: -r["regret"])[:self.keep_worst]
        doc: dict[str, Any] = {
            "schema": "obs_regret/v1",
            "mode": self.mode,
            "requests": len(self.records),
            "tokens": tokens,
            "regret_mean": float(regrets.mean()) if len(regrets) else 0.0,
            "regret_p99": (float(np.percentile(regrets, 99))
                           if len(regrets) else 0.0),
            "regret_max": float(regrets.max()) if len(regrets) else 0.0,
            "regret_total": float(regrets.sum()),
            "causes": causes,
            "worst": [{k: v for k, v in rec.items() if k != "t"}
                      for rec in worst],
            "digest": self.regret_digest(),
            "verdict": "unverifiable" if unverifiable else self.mode,
        }
        if unverifiable:
            # audit_events-style honesty: a truncated ring cannot
            # support the numbers — move them aside, assert nothing
            doc["suspect"] = {
                "regret_mean": doc["regret_mean"],
                "regret_p99": doc["regret_p99"],
                "regret_max": doc["regret_max"],
                "regret_total": doc["regret_total"],
                "causes": doc.pop("causes"),
            }
            for key in ("regret_mean", "regret_p99", "regret_max",
                        "regret_total"):
                doc[key] = None
            doc["causes"] = {}
            doc["worst"] = []
        return doc

    def stats(self) -> dict[str, Any]:
        return {"requests": len(self.records),
                "pareto_points": self.pareto.n_points,
                "frontier": len(self.pareto.frontier)}


def regret_events(events, *, dropped: int = 0,
                  **meter_kwargs) -> dict[str, Any]:
    """Offline regret over an exported event ring (or `Event` list).

    With ``dropped == 0`` the ring is the complete stream and the
    report is exactly what a live meter would have said.  With
    ``dropped > 0`` token counts (and hence row indices) may be wrong
    for any rid — the verdict demotes to ``unverifiable`` and the
    numbers move into ``suspect``, mirroring `audit_events`.
    """
    meter = RegretMeter(**meter_kwargs)
    for ev in events:
        if not isinstance(ev, Event):
            d = dict(ev)
            data = tuple(sorted(
                (k, v) for k, v in d.items()
                if k not in ("t", "kind", "rid", "lane", "model")))
            ev = Event(float(d["t"]), str(d["kind"]),
                       int(d.get("rid", -1)), int(d.get("lane", -1)),
                       int(d.get("model", -1)), data)
        meter.observe(ev)
    report = meter.report(unverifiable=dropped > 0)
    report["events_dropped"] = int(dropped)
    return report
