"""`ServeReport` — the one serve report (DESIGN.md §12).

`launch/serve.py` used to stitch each serve's closing report out of
bespoke ``print()`` blocks, three of which had drifted into near-
copies (the latency block, and two flavours of the "kv pool: peak …"
line).  The report now builds a `MetricsRegistry` first — every
number the old prints showed lands as a labelled gauge — and renders
its lines *from the registry*, so ``--metrics-out`` and the console
report can never disagree.

Sections are added for whatever subsystems actually ran; `lines()`
renders only what was added, in a stable order.
"""

from __future__ import annotations

from typing import Any, Mapping

from repro.serving.obs.registry import MetricsRegistry

__all__ = ["ServeReport", "segments_saved_line"]


def _ms(v: Any) -> str:
    return "n/a" if v is None else f"{1e3 * v:.0f}ms"


def segments_saved_line(seg_batch: int, seg_policy: int, *, steps: int,
                        n_seg: int, lane_steps: int) -> str:
    """One consistent line for every serving mode: each saving is a
    percentage of ITS OWN full-depth reference — batch-level counts
    segment launches (``steps * n_seg``), lane-level counts per-lane
    probes (``lane_steps * n_seg``)."""
    save_b = 100.0 * (1.0 - seg_batch / max(steps * n_seg, 1))
    save_l = 100.0 * (1.0 - seg_policy / max(lane_steps * n_seg, 1))
    return (f"segments saved: batch {save_b:.0f}% "
            f"({seg_batch}/{steps * n_seg} launches) / "
            f"lane {save_l:.0f}% ({seg_policy}/{lane_steps * n_seg} "
            f"per-lane probes)")


class ServeReport:
    def __init__(self) -> None:
        self.registry = MetricsRegistry()
        self._sections: list[str] = []
        self._models: list[str] = []        # cascade rung names, in order
        self._pool_models: list[str | None] = []
        self._switches: list[dict] = []     # human log, not a metric
        self._gear: str | None = None

    # -------------------------------------------------------- sections
    def add_runtime(self, summary: Mapping[str, Any], *,
                    slo_ms: float | None = None) -> None:
        self.registry.absorb("runtime", summary)
        if slo_ms is not None:
            self.registry.gauge("runtime_slo_ms").set(slo_ms)
        self._sections.append("runtime")

    def add_segments(self, seg_batch: int, seg_policy: int, *, steps: int,
                     n_seg: int, lane_steps: int) -> None:
        self.registry.absorb("segments", {
            "run_batch": seg_batch, "run_policy": seg_policy,
            "steps": steps, "n_seg": n_seg, "lane_steps": lane_steps})
        self._sections.append("segments")

    def add_pool(self, stats: Mapping[str, Any],
                 model: str | None = None) -> None:
        labels = {"model": model} if model is not None else {}
        self.registry.absorb("kv_pool", stats, **labels)
        self._pool_models.append(model)
        if "pool" not in self._sections:
            self._sections.append("pool")

    def add_cascade(self, cs: Mapping[str, Any]) -> None:
        self._models = list(cs.get("models", ()))
        for key in ("escalations", "recalls", "deescalations", "commits",
                    "repin_tokens"):
            if key in cs:
                self.registry.gauge(f"cascade_{key}").set(float(cs[key]))
        for m, n in zip(self._models, cs.get("tokens_served", ())):
            self.registry.gauge("cascade_tokens_served", model=m).set(n)
        for m, pool in cs.get("pools", {}).items():
            self.add_pool(pool, model=m)
        self._sections.append("cascade")

    def add_chunked_prefill(self, cs: Mapping[str, Any]) -> None:
        self.registry.absorb("chunked_prefill", cs)
        self._sections.append("chunk")

    def add_adaptive(self, st: Mapping[str, Any]) -> None:
        self._gear = st.get("gear")
        self._switches = list(st.get("switches", ()))
        self.registry.absorb("adaptive", {
            k: v for k, v in st.items()
            if k not in ("switches", "gear")})
        self._sections.append("adaptive")

    def add_trace(self, tracer, flight=None) -> None:
        self.registry.absorb("trace", tracer.stats())
        if flight is not None:
            self.registry.absorb("flight", flight.stats())
        self._sections.append("trace")

    def add_ledger(self, report: Mapping[str, Any]) -> None:
        """Invariant-ledger verdicts (audit.py's ``ledger_report/v1``)."""
        self._ledger = dict(report)
        self.registry.absorb("ledger", {
            "events_seen": report.get("events_seen", 0),
            "total_violations": report.get("total_violations", 0),
            "checks": sum(c.get("checks", 0) for c in
                          report.get("contracts", {}).values()),
        })
        self._sections.append("ledger")

    def add_lossmap(self, lm: Mapping[str, Any]) -> None:
        """Goodput-loss attribution (lossmap.py's ``obs_lossmap/v1``)."""
        self._lossmap = dict(lm)
        self.registry.absorb("lossmap", {
            k: v for k, v in lm.items()
            if k not in ("schema", "stalls_s")})
        self._sections.append("lossmap")

    def add_regret(self, doc: Mapping[str, Any]) -> None:
        """Decision-quality regret (regret.py's ``obs_regret/v1``)."""
        self._regret = dict(doc)
        self.registry.absorb("regret", {
            k: v for k, v in doc.items()
            if k in ("requests", "tokens", "regret_mean", "regret_p99",
                     "regret_max", "regret_total") and v is not None})
        self._sections.append("regret")

    def add_pareto(self, doc: Mapping[str, Any]) -> None:
        """Streaming frontier (pareto.py's ``obs_pareto/v1``)."""
        self._pareto = dict(doc)
        self.registry.absorb("pareto", {
            "points": doc.get("points", 0),
            "frontier_size": doc.get("frontier_size", 0)})
        self._sections.append("pareto")

    # -------------------------------------------------------- renderers
    def _v(self, name: str, default=None, **labels):
        return self.registry.value(name, default, **labels)

    def _runtime_lines(self) -> list[str]:
        v = self._v
        head = (f"completed {v('runtime_completed', 0):.0f}/"
                f"{v('runtime_requests', 0):.0f} requests, "
                f"{v('runtime_tokens', 0):.0f} tokens in "
                f"{v('runtime_duration', 0.0):.2f}s")
        ncan = v("runtime_cancelled", 0)
        nmiss = v("runtime_timed_out", 0)
        if ncan or nmiss:
            head += (f" (cancelled {ncan:.0f}, "
                     f"deadline-missed {nmiss:.0f})")
        lines = [
            head,
            (f"throughput: {v('runtime_throughput_tok_s', 0.0):.1f} tok/s "
             f"({v('runtime_throughput_req_s', 0.0):.2f} req/s)"),
            (f"latency: ttft p50 {_ms(v('runtime_ttft_p50'))} "
             f"p95 {_ms(v('runtime_ttft_p95'))} "
             f"p99 {_ms(v('runtime_ttft_p99'))}; "
             f"token p50 {_ms(v('runtime_token_latency_p50'))} "
             f"p95 {_ms(v('runtime_token_latency_p95'))} "
             f"p99 {_ms(v('runtime_token_latency_p99'))}"),
        ]
        att = v("runtime_slo_attainment")
        slo_ms = v("runtime_slo_ms")
        if att is not None and slo_ms is not None:
            lines.append(f"goodput (ttft<={slo_ms:.0f}ms): "
                         f"{v('runtime_goodput_tok_s', 0.0):.1f} tok/s "
                         f"(attainment {100 * att:.0f}%)")
        else:
            lines.append("goodput: n/a")
        slack50 = v("runtime_deadline_slack_p50")
        if slack50 is not None:
            lines.append(f"deadline slack: p50 {_ms(slack50)} "
                         f"p95 {_ms(v('runtime_deadline_slack_p95'))} "
                         f"p99 {_ms(v('runtime_deadline_slack_p99'))}")
        return lines

    def _segments_lines(self) -> list[str]:
        v = self._v
        return [segments_saved_line(
            int(v("segments_run_batch", 0)), int(v("segments_run_policy", 0)),
            steps=int(v("segments_steps", 0)),
            n_seg=int(v("segments_n_seg", 1)),
            lane_steps=int(v("segments_lane_steps", 0)))]

    def _pool_lines(self) -> list[str]:
        lines = []
        for model in self._pool_models:
            labels = {"model": model} if model is not None else {}
            v = lambda name, d=0: self._v(name, d, **labels)  # noqa: E731
            tag = f" [{model}]" if model is not None else ""
            lines.append(
                f"kv pool{tag}: peak {v('kv_pool_pages_peak'):.0f}/"
                f"{v('kv_pool_n_pages', 1) - 1:.0f} pages, "
                f"prefix hit rate "
                f"{100 * v('kv_pool_prefix_hit_rate', 0.0):.0f}% "
                f"({v('kv_pool_shared_tokens'):.0f} shared tokens), "
                f"{v('kv_pool_cow_splits'):.0f} COW splits, "
                f"{v('kv_pool_evictions'):.0f} evictions, "
                f"{v('kv_pool_grows'):.0f} grows, "
                f"{v('kv_pool_reserve_failures'):.0f} blocked admissions")
        return lines

    def _cascade_lines(self) -> list[str]:
        v = self._v
        served = [int(v("cascade_tokens_served", 0, model=m))
                  for m in self._models]
        total = max(sum(served), 1)
        return [
            "cascade: " + ", ".join(
                f"{m} served {n} tokens ({100 * n / total:.0f}%)"
                for m, n in zip(self._models, served)),
            (f"escalations {v('cascade_escalations', 0):.0f}, "
             f"recalls {v('cascade_recalls', 0):.0f}, "
             f"de-escalations {v('cascade_deescalations', 0):.0f}, "
             f"commits {v('cascade_commits', 0):.0f}, "
             f"re-pinned catch-up tokens "
             f"{v('cascade_repin_tokens', 0):.0f}"),
        ]

    def _chunk_lines(self) -> list[str]:
        v = self._v
        computed = v("chunked_prefill_tokens_computed", 0)
        skipped = v("chunked_prefill_tokens_skipped", 0)
        total = computed + skipped
        return [(f"chunked prefill: {computed:.0f} prompt tokens computed "
                 f"over {v('chunked_prefill_chunk_steps', 0):.0f} "
                 f"co-scheduled chunk steps, {skipped:.0f}/"
                 f"{max(total, 1):.0f} skipped via prefix cache "
                 f"({v('chunked_prefill_prefills', 0):.0f} admissions)")]

    def _adaptive_lines(self) -> list[str]:
        v = self._v
        lines = [(f"adaptive: final gear {self._gear}, "
                  f"{v('adaptive_gear_switches', 0):.0f} gear switches, "
                  f"{v('adaptive_recalibrations', 0):.0f} online "
                  f"recalibrations")]
        for sw in self._switches:
            lines.append(f"  t={sw['t']:6.2f}s  {sw['from']} -> {sw['to']}")
        return lines

    def _trace_lines(self) -> list[str]:
        v = self._v
        line = (f"trace: {v('trace_events', 0):.0f} events buffered "
                f"({v('trace_emitted', 0):.0f} emitted, "
                f"{v('trace_dropped', 0):.0f} dropped)")
        bundles = v("flight_bundles")
        if bundles is not None:
            line += f"; flight recorder bundles: {bundles:.0f}"
        return [line]

    def _ledger_lines(self) -> list[str]:
        rep = getattr(self, "_ledger", {})
        contracts = rep.get("contracts", {})
        total = rep.get("total_violations", 0)
        checks = sum(c.get("checks", 0) for c in contracts.values())
        verdict = "PASS" if total == 0 else "VIOLATED"
        if any(c.get("verdict") == "unverifiable"
               for c in contracts.values()):
            verdict = "UNVERIFIABLE"
        lines = [f"ledger: {len(contracts)} contracts, {checks} checks, "
                 f"{total} violations ({verdict})"]
        for v in rep.get("violations", ())[:5]:
            lines.append(f"  {v['contract']} @ t={v['t']:.2f}s: "
                         f"{v['detail']}")
        return lines

    def _lossmap_lines(self) -> list[str]:
        lm = getattr(self, "_lossmap", {})
        loss = lm.get("loss_tok_s", {})
        gap = lm.get("loss_total_tok_s", 0.0)
        head = (f"lossmap: ceiling {lm.get('ceiling_tok_s', 0.0):.1f} "
                f"tok/s, goodput {lm.get('goodput_tok_s', 0.0):.1f} "
                f"tok/s (gap {gap:.1f})")
        parts = [f"{c} {v:.2f}" for c, v in sorted(
            loss.items(), key=lambda kv: -kv[1]) if v > 0]
        if parts:
            head += ": " + ", ".join(parts)
        return [head]

    def _regret_lines(self) -> list[str]:
        rep = getattr(self, "_regret", {})
        verdict = rep.get("verdict", "exact")
        if verdict == "unverifiable":
            return [(f"regret: UNVERIFIABLE over "
                     f"{rep.get('requests', 0)} requests "
                     f"(ring dropped events; numbers demoted)")]
        mean = rep.get("regret_mean") or 0.0
        p99 = rep.get("regret_p99") or 0.0
        head = (f"regret: mean {mean:.4f} p99 {p99:.4f} over "
                f"{rep.get('requests', 0)} requests ({verdict})")
        parts = [f"{c} {v:.4f}" for c, v in sorted(
            rep.get("causes", {}).items(), key=lambda kv: -kv[1])
            if v > 0]
        if parts:
            head += ": " + ", ".join(parts)
        return [head]

    def _pareto_lines(self) -> list[str]:
        rep = getattr(self, "_pareto", {})
        head = (f"pareto: {rep.get('frontier_size', 0)} frontier points "
                f"/ {rep.get('points', 0)} served")
        parts = [f"{g} {s['frontier']}/{s['points']}"
                 for g, s in sorted(rep.get("by_gear", {}).items())
                 if s.get("frontier")]
        if parts:
            head += " (" + ", ".join(parts) + ")"
        return [head]

    def lines(self) -> list[str]:
        order = ("runtime", "adaptive", "segments", "cascade", "pool",
                 "chunk", "trace", "ledger", "lossmap", "regret",
                 "pareto")
        render = {"runtime": self._runtime_lines,
                  "adaptive": self._adaptive_lines,
                  "segments": self._segments_lines,
                  "cascade": self._cascade_lines,
                  "pool": self._pool_lines,
                  "chunk": self._chunk_lines,
                  "trace": self._trace_lines,
                  "ledger": self._ledger_lines,
                  "lossmap": self._lossmap_lines,
                  "regret": self._regret_lines,
                  "pareto": self._pareto_lines}
        out: list[str] = []
        for section in order:
            if section in self._sections:
                out.extend(render[section]())
        return out

    def print(self) -> None:
        for line in self.lines():
            print(line)
