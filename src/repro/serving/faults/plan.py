"""`FaultPlan` — a deterministic chaos script for a serve.

A plan is a pure value: which rids cancel and when, which rids carry
deadlines, which model rungs freeze over which virtual-time windows,
and how many KV pages a pressure event steals over which windows.
Everything is derived from a seed with `numpy.random.default_rng`, so
the same (seed, workload) pair always produces the same plan, and a
planned serve replays bit-identically — faults are part of the trace,
not noise on top of it.

Request-borne faults (`cancel_at`, `deadline`) are *stamped onto the
requests* with `stamp()` before the serve starts; they ride the queued
span events and therefore survive trace export → replay round trips.
Serve-borne faults (rung stalls, page squeezes) are read off the plan
by the stepper/pool at each step's virtual `now` — the plan object
itself is what the replay closure captures.

Schema: `as_doc()` / `from_doc()` round-trip the plan as a
``faults/v1`` JSON block, embedded in exported traces so
`benchmarks.check_trace` can validate the plan a trace was served
under.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable, Sequence

import numpy as np

__all__ = ["FaultPlan"]


class FaultPlan:
    """Seeded script of faults to inject into one serve.

    ``cancel_at`` / ``deadline`` map rid → absolute virtual time.
    ``stalls`` is a list of ``(model, t0, t1)`` windows during which
    every lane of that model rung is frozen.  ``squeezes`` is a list of
    ``(t0, t1, pages)`` windows during which ``pages`` KV pages are
    withheld from the pool's free headroom.
    """

    def __init__(self, *, seed: int = 0,
                 cancel_at: dict[int, float] | None = None,
                 deadline: dict[int, float] | None = None,
                 stalls: Iterable[Sequence] = (),
                 squeezes: Iterable[Sequence] = ()):
        self.seed = int(seed)
        self.cancel_at = {int(k): float(v)
                          for k, v in (cancel_at or {}).items()}
        self.deadline = {int(k): float(v)
                         for k, v in (deadline or {}).items()}
        self.stalls = [(int(m), float(t0), float(t1))
                       for m, t0, t1 in stalls]
        self.squeezes = [(float(t0), float(t1), int(p))
                         for t0, t1, p in squeezes]

    # --------------------------------------------------------- generate
    @classmethod
    def generate(cls, requests, *, seed: int,
                 cancel_rate: float = 0.0,
                 cancel_after: tuple[float, float] = (0.5, 4.0),
                 deadline=None,
                 stalls: Iterable[Sequence] = (),
                 squeezes: Iterable[Sequence] = ()) -> "FaultPlan":
        """Draw a plan for ``requests`` from ``seed``.

        ``cancel_rate`` is the per-request probability of a client
        hang-up, landing ``cancel_after`` ~ U(lo, hi) seconds after
        arrival.  ``deadline`` is either a scalar (every request gets
        ``arrival + deadline``) or a ``(lo, hi)`` window drawn
        uniformly per request.  ``stalls`` / ``squeezes`` pass through
        verbatim — they are serve-time windows, not per-request draws.
        """
        rng = np.random.default_rng(seed)
        cancel_at: dict[int, float] = {}
        deadlines: dict[int, float] = {}
        for req in requests:
            if cancel_rate > 0.0 and rng.random() < cancel_rate:
                lo, hi = cancel_after
                cancel_at[req.rid] = float(req.arrival
                                           + rng.uniform(lo, hi))
            if deadline is not None:
                if isinstance(deadline, (tuple, list)):
                    lo, hi = deadline
                    deadlines[req.rid] = float(req.arrival
                                               + rng.uniform(lo, hi))
                else:
                    deadlines[req.rid] = float(req.arrival
                                               + float(deadline))
        return cls(seed=seed, cancel_at=cancel_at, deadline=deadlines,
                   stalls=stalls, squeezes=squeezes)

    # ------------------------------------------------------------ stamp
    def stamp(self, requests) -> list:
        """Return new `Request` objects with the plan's request-borne
        faults written onto them.  Requests the plan does not touch are
        returned unchanged (same object)."""
        out = []
        for req in requests:
            ca = self.cancel_at.get(req.rid)
            dl = self.deadline.get(req.rid)
            if ca is None and dl is None:
                out.append(req)
                continue
            changes: dict[str, Any] = {}
            if ca is not None:
                changes["cancel_at"] = ca
            if dl is not None:
                changes["deadline"] = dl
            out.append(dataclasses.replace(req, **changes))
        return out

    # ---------------------------------------------------- serve queries
    def stall_active(self, model: int, t: float) -> bool:
        return any(m == model and t0 <= t < t1
                   for m, t0, t1 in self.stalls)

    def stall_window(self, model: int, t: float):
        """The ``(t0, t1)`` stall window covering ``t`` for ``model``,
        or None."""
        for m, t0, t1 in self.stalls:
            if m == model and t0 <= t < t1:
                return (t0, t1)
        return None

    def stall_overlap(self, model: int, t0: float, t1: float) -> float:
        """Total stall time for ``model`` inside ``[t0, t1]`` — the
        ledger's liveness allowance for escalations targeting a frozen
        rung."""
        total = 0.0
        for m, s0, s1 in self.stalls:
            if m == model:
                total += max(0.0, min(t1, s1) - max(t0, s0))
        return total

    def squeeze_pages(self, t: float) -> int:
        return sum(p for t0, t1, p in self.squeezes if t0 <= t < t1)

    def next_change(self, t: float) -> float | None:
        """Earliest scripted boundary strictly after ``t`` — the wake
        time for a serve loop that would otherwise deadlock waiting for
        a stall or squeeze window to pass."""
        edges = [e for _, t0, t1 in self.stalls for e in (t0, t1)
                 if e > t]
        edges += [e for t0, t1, _ in self.squeezes for e in (t0, t1)
                  if e > t]
        return min(edges) if edges else None

    # ----------------------------------------------------------- schema
    def as_doc(self) -> dict[str, Any]:
        return {
            "schema": "faults/v1",
            "seed": self.seed,
            "cancel_at": {str(k): v
                          for k, v in sorted(self.cancel_at.items())},
            "deadline": {str(k): v
                         for k, v in sorted(self.deadline.items())},
            "stalls": [list(w) for w in self.stalls],
            "squeezes": [list(w) for w in self.squeezes],
        }

    @classmethod
    def from_doc(cls, doc: dict[str, Any]) -> "FaultPlan":
        if doc.get("schema") != "faults/v1":
            raise ValueError(
                f"not a faults/v1 doc: {doc.get('schema')!r}")
        return cls(
            seed=doc.get("seed", 0),
            cancel_at={int(k): float(v)
                       for k, v in doc.get("cancel_at", {}).items()},
            deadline={int(k): float(v)
                      for k, v in doc.get("deadline", {}).items()},
            stalls=doc.get("stalls", ()),
            squeezes=doc.get("squeezes", ()),
        )

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_doc(json.load(f))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.as_doc(), f, indent=2)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FaultPlan(seed={self.seed}, "
                f"cancels={len(self.cancel_at)}, "
                f"deadlines={len(self.deadline)}, "
                f"stalls={len(self.stalls)}, "
                f"squeezes={len(self.squeezes)})")
