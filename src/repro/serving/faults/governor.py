"""`DegradeGovernor` — demote under pressure instead of failing.

The governor sits at the single point where a cascade decides to
escalate and answers one question: *can the deep rung still pay off?*
Escalating costs catch-up prefill (the deep rung must replay the
stream it skipped) and, if the target rung is inside a fault-plan
stall window, an unbounded wait.  When the remaining deadline budget
cannot cover that cost, escalating converts a servable request into a
deadline miss — so the governor denies the escalation and the router
serves the best already-probed shallow answer instead.  Recall is what
makes this demotion cheap and *legal*: the shallow rung's observed
node is a genuine T-Tamer walk answer, just an earlier stop on the
node line.

The governor holds no serve state and draws no randomness — a denial
is a pure function of (now, deadline, catch-up cost, stall flag), so a
governed serve replays bit-identically.
"""

from __future__ import annotations

from typing import Any

__all__ = ["DegradeGovernor"]


class DegradeGovernor:
    """Deadline-aware escalation gate.

    ``safety`` scales the catch-up cost estimate before comparing it
    against the remaining budget: > 1 denies earlier (conservative),
    < 1 gambles on the estimate being pessimistic.
    """

    def __init__(self, *, safety: float = 1.0):
        self.safety = float(safety)
        self.allowed = 0
        self.denied = 0
        self.denied_deadline = 0
        self.denied_stall = 0

    def allow_escalation(self, *, now: float,
                         deadline: float | None,
                         catchup_cost: float,
                         stalled: bool = False) -> bool:
        """True if the escalation may proceed.

        Denies when the target rung is stalled (escalating into a
        frozen rung parks the request for the whole window), or when a
        deadline leaves less budget than the scaled catch-up cost.
        """
        if stalled:
            self.denied += 1
            self.denied_stall += 1
            return False
        if (deadline is not None
                and deadline - now < self.safety * catchup_cost):
            self.denied += 1
            self.denied_deadline += 1
            return False
        self.allowed += 1
        return True

    def stats(self) -> dict[str, Any]:
        return {
            "governor_allowed": self.allowed,
            "governor_denied": self.denied,
            "governor_denied_deadline": self.denied_deadline,
            "governor_denied_stall": self.denied_stall,
        }
