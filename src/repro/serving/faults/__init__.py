"""Fault-tolerance plane: deterministic chaos scripts and graceful
degradation (DESIGN.md §14).

`FaultPlan` scripts request cancellations, deadline squeezes, rung-stall
windows, and page-pressure events as a pure function of a seed — every
fault lands at a scripted virtual time, so a faulted serve replays
bit-identically.  `DegradeGovernor` turns deadline pressure into
demotion instead of failure: escalations whose catch-up cost cannot fit
the remaining budget are denied and the small rung's recalled answer is
served instead.
"""

from repro.serving.faults.governor import DegradeGovernor
from repro.serving.faults.plan import FaultPlan

__all__ = ["FaultPlan", "DegradeGovernor"]
