"""Page allocation + shared-prefix index (host-side, DESIGN.md §8).

`PageAllocator` is a refcounted free list over the global page pool.
Page 0 is RESERVED as the garbage sink: the device-side paged writes of
masked/exited lanes are redirected there (with position -1, so gathered
garbage is never attended), and unused page-table entries point at it.

`PrefixCache` maps prompt-prefix hashes to page chains so a new request
whose prompt shares a prefix with an earlier one points its page table
at the SAME pages instead of storing duplicate KV.  Every cache entry
holds its own reference on each of its pages, which is what keeps a
prefix alive after the request that wrote it has released its lane;
entries are dropped LRU-first when admission needs pages back.
"""

from __future__ import annotations

import collections
import hashlib

import numpy as np

__all__ = ["GARBAGE_PAGE", "PageAllocator", "PrefixCache"]

GARBAGE_PAGE = 0


class PageAllocator:
    """Refcounted free-list allocator over ``n_pages`` fixed-size pages.

    Invariants (pinned by tests/serving/test_kvpool.py):
      * a page is either free or has refcount >= 1 — incref/decref of a
        free page raises (double-free guard),
      * ``alloc`` is atomic: it returns ``None`` rather than a partial
        list when fewer than ``n`` pages are free,
      * page ids come back in deterministic (ascending-preferred) order.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = int(n_pages)
        # LIFO stack initialized descending so pop() yields ascending ids
        self._free = list(range(self.n_pages - 1, GARBAGE_PAGE, -1))
        self._ref = np.zeros(self.n_pages, np.int32)

    # ------------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        # excludes the reserved garbage page
        return self.n_pages - 1 - len(self._free)

    def refcount(self, pid: int) -> int:
        return int(self._ref[pid])

    def alloc(self, n: int = 1) -> list[int] | None:
        """Take ``n`` pages (refcount 1 each) or ``None`` if short."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._ref[out] = 1
        return out

    def incref(self, pid: int) -> None:
        if pid == GARBAGE_PAGE:
            raise ValueError("page 0 is the reserved garbage sink")
        if self._ref[pid] <= 0:
            raise ValueError(f"incref of free page {pid}")
        self._ref[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page became free."""
        if pid == GARBAGE_PAGE:
            raise ValueError("page 0 is the reserved garbage sink")
        if self._ref[pid] <= 0:
            raise ValueError(f"double free of page {pid}")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)
            return True
        return False


def _prefix_key(tokens: np.ndarray, n: int, salt: bytes = b"") -> bytes:
    """Content hash of ``tokens[:n]`` (length-salted, dtype-canonical).

    ``salt`` namespaces the key — the cascade subsystem passes a MODEL
    key so identical prompt text admitted on two different models can
    never resolve to the same page chain (their KV bytes are different
    tensors entirely)."""
    h = hashlib.sha1(salt)
    h.update(np.ascontiguousarray(tokens[:n], np.int32).tobytes())
    h.update(n.to_bytes(8, "little"))
    return h.digest()


class PrefixCache:
    """LRU index: prompt-prefix hash -> (page ids, tokens covered).

    ``insert`` registers one entry per page-aligned prefix boundary plus
    one for the full prompt (whose last page may be PARTIAL — sharing it
    is what later forces a copy-on-write split when the new lane appends
    its own tokens).  ``lookup`` returns the longest match and increfs
    the matched pages on behalf of the caller's lane.

    ``model_key`` salts every hash: two caches (or one cache serving two
    models over a shared allocator) with different keys are fully
    isolated — the same prompt text never matches across models.
    """

    def __init__(self, allocator: PageAllocator,
                 model_key: str | None = None):
        self.allocator = allocator
        self._salt = (model_key or "").encode()
        self._entries: collections.OrderedDict[bytes, tuple[tuple[int, ...],
                                                            int]] = \
            collections.OrderedDict()
        # per-page count of refs held BY CACHE ENTRIES: a page whose
        # total refcount equals this is backing no live lane, so
        # evicting its entries makes real progress toward freeing it
        self._page_refs: collections.Counter[int] = collections.Counter()
        # stats (KVPool folds these into its report)
        self.lookups = 0
        self.hits = 0
        self.shared_tokens = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def _match_keys(self, tokens: np.ndarray, page_size: int):
        """Candidate prefix lengths, longest first: the full prompt
        (which may end mid-page), then each page-aligned boundary."""
        n = len(tokens)
        lens = [] if n % page_size == 0 else [n]
        lens += [k * page_size for k in range(n // page_size, 0, -1)]
        return lens

    def lookup(self, tokens: np.ndarray, page_size: int,
               peek: bool = False):
        """Longest shared prefix of ``tokens``.

        Returns ``(pages, n_tokens)`` — the page chain covering the first
        ``n_tokens`` of the prompt.  Unless ``peek``, the matched pages
        are increfed for the caller (the lane owns those references and
        must decref them at release).
        """
        if not peek:
            self.lookups += 1
        for ln in self._match_keys(tokens, page_size):
            ent = self._entries.get(_prefix_key(tokens, ln, self._salt))
            if ent is None:
                continue
            pages, n_tok = ent
            if not peek:
                self._entries.move_to_end(
                    _prefix_key(tokens, ln, self._salt))
                for pid in pages:
                    self.allocator.incref(pid)
                self.hits += 1
                self.shared_tokens += n_tok
            return list(pages), n_tok
        return [], 0

    def insert(self, tokens: np.ndarray, pages: list[int],
               page_size: int) -> None:
        """Register the prompt's page chain (full pages + partial tail).

        ``pages`` covers ``tokens`` in order.  Each NEW entry increfs its
        pages; keys that already exist are left untouched (the earlier
        entry is canonical — its pages carry the same KV by determinism).
        """
        n = len(tokens)
        bounds = [k * page_size for k in range(1, n // page_size + 1)]
        if n % page_size:
            bounds.append(n)
        for ln in bounds:
            key = _prefix_key(tokens, ln, self._salt)
            if key in self._entries:
                continue
            chain = tuple(pages[: (ln + page_size - 1) // page_size])
            for pid in chain:
                self.allocator.incref(pid)
                self._page_refs[pid] += 1
            self._entries[key] = (chain, ln)

    def _drop(self, key: bytes) -> int:
        pages, _ = self._entries.pop(key)
        freed = 0
        for pid in pages:
            self._page_refs[pid] -= 1
            if self.allocator.decref(pid):
                freed += 1
        return freed

    def evict(self, n_needed: int, pinned=None) -> int:
        """Drop entries, LRU first, until ``n_needed`` pages became FREE.

        Entries ALL of whose pages back a live lane are kept: dropping
        them can never free a page (the lane's refs pin it) — it would
        only burn future prefix hits.  An entry counts as progress when
        at least one of its pages is held by cache entries alone
        (``refcount == cache refs``); chains sharing pages may need
        several such evictions before the last ref drops.  ``pinned``
        (page id -> pin count) protects chains that pending admission
        reservations counted as shared — evicting those would silently
        turn a sufficient reservation into an under-estimate.  Returns
        the number of pages actually freed."""
        pinned = pinned or {}
        freed = 0
        progress = True
        while freed < n_needed and progress:
            progress = False
            for key, (pages, _) in list(self._entries.items()):
                if any(pinned.get(p, 0) > 0 for p in pages):
                    continue
                if not any(self.allocator.refcount(p) == self._page_refs[p]
                           for p in pages):
                    continue
                freed += self._drop(key)
                self.evictions += 1
                progress = True
                if freed >= n_needed:
                    break
        return freed

    def clear(self) -> None:
        """Drop every entry (release-all; used by pool reset)."""
        for key in list(self._entries.keys()):
            self._drop(key)
