"""Paged KV-cache subsystem (DESIGN.md §8).

Block-granular KV memory for the continuous-batching runtime: instead of
one private ``lanes x cache_len`` ring buffer per attention layer, every
layer's K/V lives in a global pool of fixed-size PAGES and each lane
holds a page TABLE (list of page ids).  Pages are refcounted, so lanes
whose prompts share a prefix point at the SAME pages (copy-on-write when
one of them has to append into a shared page), and admission is gated by
the free-page budget rather than a fixed lane width.

Host/device split: all allocation DECISIONS (free list, refcounts,
prefix hashing, COW planning) are plain-Python host state in this
package; everything that touches KV bytes (page gather for attention,
prompt scatter at admission, page copies for COW) happens on device
through jit-compatible pytrees — see `KVPool` and
`models.attention.attn_decode`'s paged path.
"""

from repro.serving.kvpool.alloc import PageAllocator, PrefixCache
from repro.serving.kvpool.pool import KVPool, PoolExhausted, StepPlan

__all__ = ["PageAllocator", "PrefixCache", "KVPool", "PoolExhausted",
           "StepPlan"]
