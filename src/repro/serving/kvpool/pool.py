"""The paged KV pool: host bookkeeping + device-plan construction
(DESIGN.md §8).

`KVPool` owns the allocation state for one `EngineStepper`:

  * per-lane page TABLES (``(n_lanes, lane_pages)`` int32, garbage-page
    padded) and sequence lengths,
  * the `PageAllocator` free list / refcounts and the `PrefixCache`,
  * per-lane page BUDGETS — admission reserves the worst-case page count
    up front (``sum(budget) <= free_count`` is the invariant), so lazy
    page growth and copy-on-write splits during decode can never fail
    mid-stream; a request that doesn't fit stays in the queue.

Every method returns plain numpy plans (page/slot indices) for the
stepper to feed into its jitted device programs — the pool itself never
touches a device array, which is what keeps allocation host-side while
gather/scatter stays on device.

Copy-on-write: a lane appends KV into its tail page every decode token.
If that page is referenced by ANYONE else — another lane's table or a
`PrefixCache` entry — the writer first gets a private copy
(`StepPlan.cow_src/cow_dst`, executed as a device page copy before the
token step).  Cached pages are therefore IMMUTABLE after the admission
prefill scatter: they hold exactly the prompt's KV, complete across
every layer (prefill runs full depth).  That immutability is what makes
sharing exact — decode appends land only in probed layers (early-exit
masking), so letting them touch a shared page would leak one request's
per-layer KV holes into another's attention.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.serving.kvpool.alloc import (GARBAGE_PAGE, PageAllocator,
                                        PrefixCache)

__all__ = ["KVPool", "PoolExhausted", "StepPlan", "AdmitPlan"]


class PoolExhausted(RuntimeError):
    """A request can never fit (config error, not transient pressure)."""


@dataclasses.dataclass
class AdmitPlan:
    """Device scatter plan for one admission (all host numpy)."""

    lane: int
    dest_page: np.ndarray     # (Lp,) i32 per-token page (garbage if shared)
    dest_slot: np.ndarray     # (Lp,) i32 per-token slot within the page
    pos_vals: np.ndarray      # (Lp,) i32 position to store (-1 if shared)
    new_pages: np.ndarray     # (lane_pages,) i32 pages to pos-reset (0 pad)
    n_shared_tokens: int


@dataclasses.dataclass
class StepPlan:
    """Per-token device plan: where each lane writes, plus the page
    copies (COW) and fresh-page resets that must run first."""

    write_page: np.ndarray    # (n_lanes,) i32 (garbage for idle lanes)
    write_slot: np.ndarray    # (n_lanes,) i32
    fresh: np.ndarray         # (n_lanes,) i32 page to pos-reset (0 = none)
    cow_src: np.ndarray       # (n_lanes,) i32 (0 = none)
    cow_dst: np.ndarray       # (n_lanes,) i32 (0 = none)


class KVPool:
    """Host-side paged-KV bookkeeping for ``n_lanes`` decode lanes."""

    def __init__(self, *, n_lanes: int, page_size: int, lane_pages: int,
                 n_pages: int | None = None,
                 max_lane_pages: int | None = None,
                 model_key: str | None = None,
                 reclaim_watermark: float | None = None):
        if page_size < 1 or lane_pages < 1:
            raise ValueError("page_size and lane_pages must be >= 1")
        if reclaim_watermark is not None and not 0.0 < reclaim_watermark <= 1.0:
            raise ValueError(
                f"reclaim_watermark must be in (0, 1], got "
                f"{reclaim_watermark}")
        self.n_lanes = int(n_lanes)
        self.page_size = int(page_size)
        self.lane_pages = int(lane_pages)
        # sliding-window reclamation (DESIGN.md §14): above this
        # occupancy fraction an admission short on headroom may CLIP the
        # oldest sole-owner page off the longest lane — trading that
        # lane's attention history for admission instead of refusing it.
        # None disables (engine mode: device page-table positions assume
        # an unclipped table).
        self.reclaim_watermark = (None if reclaim_watermark is None
                                  else float(reclaim_watermark))
        # the device page-table WIDTH (static shape): admission reserves
        # against `lane_pages`, but `grow` may extend a lane's budget in
        # page-aligned increments up to this hard capacity — the knob
        # that lets escalated lanes avoid double worst-case reservation
        self.max_lane_pages = max(self.lane_pages,
                                  int(max_lane_pages or self.lane_pages))
        # namespaces the prefix cache (multi-model cascades: identical
        # prompt text on two models must never share page chains)
        self.model_key = model_key
        # default: ring-equivalent HBM (n_lanes x lane capacity) + sink
        self.n_pages = int(n_pages) if n_pages is not None \
            else self.n_lanes * self.lane_pages + 1
        self.reset()

    def reset(self) -> None:
        """Fresh allocation state (the stepper re-materializes device
        pools separately — stale KV bytes are gated by pos resets)."""
        self.allocator = PageAllocator(self.n_pages)
        self.prefix = PrefixCache(self.allocator, model_key=self.model_key)
        self.table = np.full((self.n_lanes, self.max_lane_pages),
                             GARBAGE_PAGE, np.int32)
        self.n_held = np.zeros(self.n_lanes, np.int32)
        self.seq_len = np.zeros(self.n_lanes, np.int32)
        self.budget = np.zeros(self.n_lanes, np.int32)
        # reservations awaiting their admit: (need, matched-chain pages);
        # the pages are PINNED against eviction so the sharing the need
        # was computed from cannot disappear before admit
        self._pending: collections.deque[tuple[int, tuple[int, ...]]] = \
            collections.deque()
        self._pinned: collections.Counter[int] = collections.Counter()
        self.prompt_tokens = 0
        self.cow_splits = 0
        self.peak_pages = 0
        self.grows = 0
        # admissions refused for lack of headroom — the page-exhaustion
        # signal the observability flight recorder triggers on
        self.reserve_failures = 0
        # fault plane (DESIGN.md §14): pages clipped off each lane's
        # front by sliding-window reclamation (positions shift by
        # clipped * page_size), plus the chaos harness's page squeeze —
        # pages withheld from headroom while a pressure window is active
        self.clipped = np.zeros(self.n_lanes, np.int32)
        self.reclaimed_pages = 0
        self.squeezed = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def pages_for(self, prompt_len: int, max_tokens: int) -> int:
        total = prompt_len + max_tokens
        return -(-total // self.page_size)

    def _fresh_need(self, prompt, max_tokens: int) -> tuple[int, list]:
        """Worst-case NEW pages a request needs given current sharing,
        plus the matched prefix chain the estimate relies on.

        Shared FULL pages are never written again, so they cost nothing.
        A shared partial tail page still costs its copy-on-write split —
        which is exactly the tail page already counted in the total.
        A FRESH partial tail gets registered in the prefix cache at
        admission, so its first decode append ALSO splits (refcount > 1:
        the cache pins it) — reserve that page too (unused budget is
        simply returned at release)."""
        lp = len(prompt)
        total = self.pages_for(lp, max_tokens)
        pages, n_tok = self.prefix.lookup(prompt, self.page_size,
                                          peek=True)
        contested = 1 if (lp % self.page_size and n_tok < lp) else 0
        return total - n_tok // self.page_size + contested, pages

    def _headroom(self) -> int:
        """Pages neither allocated, lane-reserved, pending-reserved,
        nor withheld by an active pressure squeeze."""
        return (self.allocator.free_count - int(self.budget.sum())
                - sum(need for need, _ in self._pending)
                - self.squeezed)

    def set_squeeze(self, pages: int) -> None:
        """Withhold ``pages`` from admission headroom (chaos page
        pressure).  Squeezes only gate NEW reservations — budgets
        already granted keep the never-fail-mid-stream guarantee."""
        self.squeezed = max(0, int(pages))

    def reserve(self, prompt, max_tokens: int) -> bool:
        """The admission gate: reserve the request's worst-case page need
        (evicting cached prefixes if that closes the gap), or return
        False so the request STAYS QUEUED.  The scheduler calls this at
        pop time; the matching `admit` consumes the reservation — the
        two may be separated by other reserve/admit pairs of the same
        admission round (FIFO discipline, enforced by the deque).  The
        matched prefix chain is pinned against eviction until the admit,
        so the sharing this need was computed from cannot be evicted out
        from under it (by this call's own eviction or a later one's)."""
        if len(prompt) + max_tokens > self.max_lane_pages * self.page_size:
            raise PoolExhausted(
                f"request needs {len(prompt) + max_tokens} tokens but a "
                f"lane holds at most {self.max_lane_pages} pages x "
                f"{self.page_size} = "
                f"{self.max_lane_pages * self.page_size}")
        need, match = self._fresh_need(prompt, max_tokens)
        self._pinned.update(match)
        if need > self._headroom():
            self.prefix.evict(need - self._headroom(),
                              pinned=self._pinned)
        if need > self._headroom():
            # degradation ladder's last rung before refusing: clip
            # attention history off the longest lanes (DESIGN.md §14)
            self._reclaim(need - self._headroom())
        if need > self._headroom():
            self._pinned.subtract(match)
            self._pinned = +self._pinned        # drop zero counts
            self.reserve_failures += 1
            return False
        self._pending.append((need, tuple(match)))
        return True

    # ------------------------------------------------------------------
    # sliding-window reclamation (DESIGN.md §14)
    # ------------------------------------------------------------------

    def _occupancy(self) -> float:
        return self.allocator.pages_in_use / max(1, self.n_pages - 1)

    def _clip_candidate(self, lane: int) -> bool:
        """A lane may lose its head page only when that page is pure
        private history: the lane alone references it (so it is neither
        a prefix-cache chain nor pinned by a pending reservation) and
        the lane has at least one more page behind it — the tail being
        written is never clipped."""
        if self.n_held[lane] < 2:
            return False
        head = int(self.table[lane, 0])
        if head == GARBAGE_PAGE or self._pinned.get(head, 0):
            return False
        return self.allocator.refcount(head) == 1

    def _reclaim(self, need_pages: int) -> int:
        """Clip up to ``need_pages`` oldest sole-owner pages off the
        longest lanes while occupancy sits above the watermark.  Each
        clip shifts the victim's page table left one slot and frees the
        head page — the lane keeps decoding with a shorter attention
        window (``clipped[lane]`` records the shift so position math
        stays exact).  Returns pages actually reclaimed."""
        if self.reclaim_watermark is None:
            return 0
        got = 0
        while got < need_pages and self._occupancy() > self.reclaim_watermark:
            live = self.seq_len - self.clipped * self.page_size
            order = sorted(range(self.n_lanes),
                           key=lambda ln: (-int(live[ln]), ln))
            victim = next((ln for ln in order
                           if self._clip_candidate(ln)), None)
            if victim is None:
                break
            head = int(self.table[victim, 0])
            self.allocator.decref(head)           # sole ref: page freed
            self.table[victim, :-1] = self.table[victim, 1:]
            self.table[victim, -1] = GARBAGE_PAGE
            self.n_held[victim] -= 1
            self.clipped[victim] += 1
            self.reclaimed_pages += 1
            got += 1
        return got

    def admit(self, lane: int, prompt, max_tokens: int, *,
              register_prefix: bool = True) -> AdmitPlan:
        """Consume the oldest `reserve` and build the request's prefill
        scatter plan.  Sharing can only have IMPROVED since the reserve
        (earlier admissions of this round insert their prefixes), so the
        reservation is an upper bound on what gets allocated here.

        ``register_prefix=False`` defers the prefix-cache insert —
        chunked prefill admits BEFORE the prompt's KV bytes exist in the
        pool, and registering the chain early would let a concurrent
        admission share pages whose contents are still being written
        chunk by chunk.  The stepper calls `commit_prefix` once the
        final chunk has committed."""
        prompt = np.asarray(prompt, np.int32)
        lp, ps = len(prompt), self.page_size
        if self.n_held[lane]:
            raise ValueError(f"lane {lane} still holds pages")
        if not self._pending:
            raise ValueError("admit without a matching reserve")
        _, pinned = self._pending.popleft()
        self._pinned.subtract(pinned)
        self._pinned = +self._pinned            # drop zero counts
        shared, n_shared = self.prefix.lookup(prompt, ps)  # increfs
        n_prompt_pages = -(-lp // ps)
        fresh_prompt = n_prompt_pages - len(shared)
        got = self.allocator.alloc(fresh_prompt)
        if got is None:  # reserve guaranteed this; keep the invariant
            for pid in shared:
                self.allocator.decref(pid)
            raise PoolExhausted("allocator out of pages at admit "
                                "(reserve not consulted?)")
        pages = shared + got
        contested = 1 if (lp % ps and n_shared < lp) else 0
        need = self.pages_for(lp, max_tokens) - n_shared // ps + contested
        self.budget[lane] = need - fresh_prompt
        row = self.table[lane]
        row[:] = GARBAGE_PAGE
        row[:len(pages)] = pages
        self.n_held[lane] = len(pages)
        self.seq_len[lane] = lp
        self.clipped[lane] = 0

        # per-token scatter targets; shared tokens go to the sink
        tok = np.arange(lp, dtype=np.int32)
        dest_page = np.asarray(pages, np.int32)[tok // ps]
        dest_page[:n_shared] = GARBAGE_PAGE
        pos_vals = tok.copy()
        pos_vals[:n_shared] = -1
        new_pages = np.full(self.max_lane_pages, GARBAGE_PAGE, np.int32)
        new_pages[:len(got)] = got

        # future identical/extending prompts share these pages
        if register_prefix:
            self.prefix.insert(prompt, pages, ps)
        self.prompt_tokens += lp
        self.peak_pages = max(self.peak_pages, self.allocator.pages_in_use)
        return AdmitPlan(lane=lane, dest_page=dest_page,
                         dest_slot=(tok % ps).astype(np.int32),
                         pos_vals=pos_vals, new_pages=new_pages,
                         n_shared_tokens=n_shared)

    def commit_prefix(self, lane: int, prompt) -> None:
        """Register a deferred-admit lane's prompt chain in the prefix
        cache — called by the chunked-prefill stepper AFTER the final
        chunk's writes committed, at which point the pages hold exactly
        the prompt's KV across every layer (chunks run full depth) and
        sharing them is sound.  The lane has not decoded yet, so its
        table still holds exactly the prompt chain."""
        prompt = np.asarray(prompt, np.int32)
        n_prompt_pages = -(-len(prompt) // self.page_size)
        if n_prompt_pages > self.n_held[lane]:
            raise ValueError(
                f"lane {lane} holds {self.n_held[lane]} pages but the "
                f"prompt needs {n_prompt_pages} — commit_prefix before "
                "the final chunk?")
        pages = [int(p) for p in self.table[lane, :n_prompt_pages]]
        self.prefix.insert(prompt, pages, self.page_size)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def prepare_step(self, occupied: np.ndarray) -> StepPlan:
        """Plan this token's writes for every occupied lane: grow a fresh
        tail page at page boundaries, split shared tails (COW), emit
        (page, slot) write targets.  Call `note_written` after the device
        step commits."""
        n = self.n_lanes
        plan = StepPlan(
            write_page=np.full(n, GARBAGE_PAGE, np.int32),
            write_slot=np.zeros(n, np.int32),
            fresh=np.full(n, GARBAGE_PAGE, np.int32),
            cow_src=np.full(n, GARBAGE_PAGE, np.int32),
            cow_dst=np.full(n, GARBAGE_PAGE, np.int32))
        for lane in np.flatnonzero(occupied):
            pos = int(self.seq_len[lane])
            slot = pos % self.page_size
            # physical table index: reclamation shifts the table left,
            # so clipped pages no longer occupy slots
            pidx = pos // self.page_size - int(self.clipped[lane])
            if pidx >= self.max_lane_pages:
                raise PoolExhausted(
                    f"lane {lane} exceeded its page table "
                    f"({self.max_lane_pages} pages) — admission (plus "
                    "any grow() increments) must cap prompt_len + "
                    "max_tokens")
            if pidx == self.n_held[lane]:        # page boundary: grow
                got = self._alloc_from_budget(lane)
                self.table[lane, pidx] = got
                self.n_held[lane] += 1
                plan.fresh[lane] = got
            tail = int(self.table[lane, pidx])
            # any other reference — another lane OR a prefix-cache entry
            # — makes the tail immutable: split before appending (cached
            # pages must stay exact per-layer prompt snapshots)
            if self.allocator.refcount(tail) > 1:
                got = self._alloc_from_budget(lane)
                plan.cow_src[lane] = tail
                plan.cow_dst[lane] = got
                self.table[lane, pidx] = got
                self.allocator.decref(tail)
                self.cow_splits += 1
                tail = got
            plan.write_page[lane] = tail
            plan.write_slot[lane] = slot
        self.peak_pages = max(self.peak_pages, self.allocator.pages_in_use)
        return plan

    def _alloc_from_budget(self, lane: int) -> int:
        if self.budget[lane] <= 0:
            raise PoolExhausted(
                f"lane {lane} page budget exhausted (reservation bug)")
        got = self.allocator.alloc(1)
        if got is None:
            raise PoolExhausted(
                "free list empty despite reservation (invariant bug)")
        self.budget[lane] -= 1
        return got[0]

    def can_append(self, lane: int) -> bool:
        """Can the lane's NEXT decode append succeed from its reserved
        budget?  Mirrors exactly what `prepare_step` will need: a fresh
        page at a page boundary, a COW split when the tail is shared —
        callers of incremental reservation (`grow`) consult this before
        including the lane in a step and defer it when growth fails
        (the never-fail-mid-stream guarantee, kept incrementally)."""
        pos = int(self.seq_len[lane])
        pidx = pos // self.page_size - int(self.clipped[lane])
        if pidx >= self.max_lane_pages:
            return False
        need = 0
        if pidx == self.n_held[lane]:
            need = 1                                  # fresh tail page
        elif self.allocator.refcount(int(self.table[lane, pidx])) > 1:
            need = 1                                  # COW split
        return int(self.budget[lane]) >= need

    def tokens_headroom(self, lane: int) -> int:
        """Tokens the lane can still append WITHOUT another `grow`:
        slack in its held pages plus its reserved (budgeted) pages."""
        cap = (int(self.clipped[lane]) + int(self.n_held[lane])
               + int(self.budget[lane])) * self.page_size
        return cap - int(self.seq_len[lane])

    def grow(self, lane: int, extra_tokens: int) -> bool:
        """Extend a live lane's page budget by a page-aligned increment
        covering ``extra_tokens`` more appends — growth BEYOND the
        admission-time reservation (the escalated-lane fix: a stream
        re-admitted on another model reserves a small initial budget and
        grows as it decodes instead of double worst-case reservation).

        The increment is RESERVED here (the same never-fail-mid-stream
        guarantee as admission: decode only ever allocates from budget),
        so a True return means the next ``extra_tokens`` appends cannot
        hit an empty free list.  Returns False — leaving all state
        untouched — when the pool lacks headroom or the lane's table is
        at its hard ``max_lane_pages`` capacity; the caller defers the
        lane (emit nothing, retry next step) rather than crashing."""
        if extra_tokens < 1:
            raise ValueError(f"grow({extra_tokens})")
        if not self.n_held[lane]:
            raise ValueError(f"lane {lane} holds no pages (grow is for "
                             "live lanes; use reserve/admit)")
        inc = -(-int(extra_tokens) // self.page_size)
        if (int(self.n_held[lane]) + int(self.budget[lane]) + inc
                > self.max_lane_pages):
            return False
        if inc > self._headroom():
            self.prefix.evict(inc - self._headroom(), pinned=self._pinned)
        if inc > self._headroom():
            return False
        self.budget[lane] += inc
        self.grows += 1
        return True

    def note_written(self, occupied: np.ndarray) -> None:
        """Commit one decoded token per occupied lane."""
        self.seq_len[np.flatnonzero(occupied)] += 1

    def release(self, lane: int) -> None:
        """Drop the lane's page references (cached prefixes keep theirs,
        so the prompt's pages stay warm for future lookups)."""
        for pid in self.table[lane, :self.n_held[lane]]:
            self.allocator.decref(int(pid))
        self.table[lane] = GARBAGE_PAGE
        self.n_held[lane] = 0
        self.seq_len[lane] = 0
        self.budget[lane] = 0
        self.clipped[lane] = 0

    # ------------------------------------------------------------------

    def check_invariants(self) -> list[str]:
        """Audit the pool's conservation laws; returns violations (empty
        when healthy).  This is the invariant ledger's page-conservation
        probe (DESIGN.md §13) — pure host numpy over small arrays, cheap
        enough to run at every counter-event edge of a soak:

          * refcounts never negative; free XOR referenced per page,
          * allocs == frees + in_use: ``free_count + pages_in_use``
            covers every non-garbage page exactly once,
          * every reference is accounted for: a page's refcount equals
            the lane tables' holds plus the prefix cache's entry refs,
          * reserved budgets never exceed the free list
            (the never-fail-mid-stream admission guarantee).
        """
        bad: list[str] = []
        alloc = self.allocator
        ref = alloc._ref
        if (ref < 0).any():
            bad.append(f"negative refcount at pages "
                       f"{np.flatnonzero(ref < 0).tolist()}")
        free = set(alloc._free)
        if len(free) != len(alloc._free):
            bad.append("free list holds duplicate page ids")
        if alloc.free_count + alloc.pages_in_use != alloc.n_pages - 1:
            bad.append(
                f"page conservation broken: free={alloc.free_count} + "
                f"in_use={alloc.pages_in_use} != {alloc.n_pages - 1}")
        for pid in free:
            if ref[pid] != 0:
                bad.append(f"page {pid} free but refcount {int(ref[pid])}")
        # reference accounting: lane holds + cache refs == refcount
        held: collections.Counter[int] = collections.Counter()
        for lane in range(self.n_lanes):
            for pid in self.table[lane, :self.n_held[lane]]:
                held[int(pid)] += 1
        for pid in range(1, alloc.n_pages):
            if pid in free:
                continue
            expect = held.get(pid, 0) + self.prefix._page_refs.get(pid, 0)
            if int(ref[pid]) != expect:
                bad.append(
                    f"page {pid} refcount {int(ref[pid])} != "
                    f"{held.get(pid, 0)} lane holds + "
                    f"{self.prefix._page_refs.get(pid, 0)} cache refs")
            if int(ref[pid]) == 0:
                bad.append(f"page {pid} in use but refcount 0")
        pending = sum(need for need, _ in self._pending)
        if int(self.budget.sum()) + pending > alloc.free_count:
            bad.append(
                f"reserved budget {int(self.budget.sum())}+{pending} "
                f"pending exceeds free pages {alloc.free_count}")
        return bad

    @property
    def pages_in_use(self) -> int:
        return int(self.allocator.pages_in_use)

    def stats(self) -> dict:
        pf = self.prefix
        return {
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "pages_in_use": self.allocator.pages_in_use,
            "pages_peak": self.peak_pages,
            "pages_free": self.allocator.free_count,
            "prefix_entries": len(pf),
            "prefix_lookups": pf.lookups,
            "prefix_hits": pf.hits,
            "prefix_hit_rate": (pf.shared_tokens / self.prompt_tokens
                                if self.prompt_tokens else 0.0),
            "shared_tokens": pf.shared_tokens,
            "cow_splits": self.cow_splits,
            "evictions": pf.evictions,
            "grows": self.grows,
            "reserve_failures": self.reserve_failures,
            "reclaimed_pages": self.reclaimed_pages,
            "squeezed_pages": self.squeezed,
        }
