"""Msgpack + zstd checkpointing for params / optimizer / T-Tamer tables.

Flat key-path encoding keeps the format trivially inspectable and
framework-free; arrays are stored as (dtype, shape, raw bytes).
"""

from __future__ import annotations

import os
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # optional dep — fall back to stdlib zlib
    zstandard = None

_ZLIB_MAGIC = b"ZLB0"        # our zlib frames; zstd frames self-identify


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return _ZLIB_MAGIC + zlib.compress(raw, 6)


def _decompress(buf: bytes) -> bytes:
    if buf[:4] == _ZLIB_MAGIC:
        return zlib.decompress(buf[4:])
    if zstandard is None:
        raise ImportError("checkpoint was written with zstd but the "
                          "zstandard module is not installed")
    return zstandard.ZstdDecompressor().decompress(buf)

__all__ = ["save", "load", "latest_step"]


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}"))
    else:
        out[prefix] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, val in flat.items():
        keys = path.strip("/").split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            idx = sorted(node, key=lambda s: int(s[1:]))
            return [rebuild(node[i]) for i in idx]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save(path: str, tree, step: int | None = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    payload = {
        "step": step,
        "arrays": {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                       "data": v.tobytes()}
                   for k, v in flat.items()},
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    with open(path, "wb") as f:
        f.write(_compress(raw))
    return path


def load(path: str):
    with open(path, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    flat = {}
    for k, meta in payload["arrays"].items():
        dt = meta["dtype"]
        if dt == "bfloat16":
            arr = np.frombuffer(meta["data"], np.uint16).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(meta["data"], np.dtype(dt))
        flat[k] = arr.reshape(meta["shape"])
    return _unflatten(flat), payload.get("step")


def latest_step(ckpt_dir: str) -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    cks = [f for f in os.listdir(ckpt_dir) if f.endswith(".ckpt")]
    if not cks:
        return None
    cks.sort(key=lambda f: int(f.split("_")[-1].split(".")[0]))
    return os.path.join(ckpt_dir, cks[-1])
