"""AdamW with decoupled weight decay + global-norm clipping, implemented
directly (no optax offline) as pure pytree transforms so optimizer state
inherits the parameter shardings under pjit."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update",
           "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params) -> dict:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * g32 * g32
        mhat = mu / b1c
        nhat = nu / b2c
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.
        newp = p.astype(jnp.float32) - lr * (step_ + decay)
        return newp.astype(p.dtype), mu, nu

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_mu = jax.tree.unflatten(td, [o[1] for o in out])
    new_nu = jax.tree.unflatten(td, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
