"""repro.training"""
