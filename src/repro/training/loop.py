"""Training loop: EE multi-ramp objective, AdamW, sharded train_step.

``make_train_step`` builds the pure step function used three ways:
  * examples/train_ee.py      — real steps on CPU (small model),
  * launch/train.py           — pjit-sharded production launcher,
  * launch/dryrun.py          — .lower().compile() only (deliverable e).
"""

from __future__ import annotations

import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

__all__ = ["make_train_step", "train"]


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    ramp_loss_weight: float = 0.3, remat: bool = True,
                    num_microbatches: int = 1,
                    mixed_precision: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).

    ``num_microbatches`` > 1 enables gradient accumulation: the global
    batch is split along dim 0 and scanned, bounding live activations to
    one microbatch (the production activation-memory lever for train_4k
    at 1M tokens/step — EXPERIMENTS.md §Dry-run).

    ``mixed_precision`` keeps f32 master weights / moments but runs the
    forward+backward in bf16 (weights cast at use; grads cast back to f32
    and accumulated in f32)."""

    def loss_fn(p, micro):
        return M.forward_train(p, cfg, micro,
                               ramp_loss_weight=ramp_loss_weight,
                               remat=remat)

    def _cast(p):
        if not mixed_precision:
            return p
        return jax.tree.map(
            lambda w: w.astype(jnp.bfloat16)
            if w.dtype == jnp.float32 else w, p)

    def train_step(params, opt_state, batch):
        # bf16 cast OUTSIDE the microbatch scan: the fsdp weight
        # all-gather is loop-invariant and gets hoisted — one gather per
        # step instead of one per microbatch (EXPERIMENTS.md §Perf).
        p_c = _cast(params)
        if num_microbatches <= 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p_c, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            m = num_microbatches
            # Split the batch so the data-sharded factor stays leading in
            # the reshape ((B,) -> (B/m, m) keeps dim-0 sharding local),
            # then transpose to put the scanned microbatch axis first.
            # Microbatch j = rows {i*m + j}; composition is irrelevant to
            # the accumulated gradient.
            micros = jax.tree.map(
                lambda x: x.reshape(x.shape[0] // m, m,
                                    *x.shape[1:]).swapaxes(0, 1), batch)

            def accum(carry, micro):
                g_acc, metr_acc = carry
                (_, metr), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(p_c, micro)
                # accumulate in f32 regardless of compute dtype
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                metr_acc = jax.tree.map(jnp.add, metr_acc, metr)
                return (g_acc, metr_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            metr0 = jax.tree.map(
                lambda _: jnp.zeros((), jnp.float32),
                jax.eval_shape(lambda: loss_fn(p_c, jax.tree.map(
                    lambda x: x[0], micros))[1]))
            (grads, metrics), _ = jax.lax.scan(accum, (g0, metr0), micros)
            grads = jax.tree.map(lambda g: g / m, grads)
            metrics = jax.tree.map(lambda v: v / m, metrics)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, opt_cfg: AdamWConfig, params, data_iter, *,
          steps: int, log_every: int = 10, ckpt_dir: str | None = None,
          ckpt_every: int = 200, jit: bool = True):
    """Single-host training driver (examples / small scale)."""
    step_fn = make_train_step(cfg, opt_cfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    opt_state = init_opt_state(params)
    history = []
    t0 = time.time()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall"] = time.time() - t0
            history.append(m)
            print(f"step {step:5d} loss {m['loss']:.4f} "
                  f"ce_final {m['ce_final']:.4f} "
                  f"gnorm {m['grad_norm']:.3f}", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            checkpoint.save(f"{ckpt_dir}/state_{step + 1}.ckpt",
                            {"params": params}, step + 1)
    return params, opt_state, history
