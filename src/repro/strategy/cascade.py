"""`Cascade` — the calibrated serving spec a strategy is built from.

One object bundles everything the paper's pipeline produces between raw
traces and a deployable policy (DESIGN.md §4): the cascade topology (a
line of n nodes), per-node inspection costs in objective units, the
discrete loss `Support`, the fitted Markov chain, and the solved DP
tables (line and, on demand, skip).  ``strategy.make(name, cascade)``
reads whichever pieces the named strategy needs.

Construction paths:

  * `Cascade.from_traces(losses, costs, ...)`  — offline traces (the
    pareto sweeps and benchmarks).
  * `Cascade.calibrate(params, cfg, key, lam)` — run a model on
    calibration prompts and fit from its ramp losses (the serving
    launcher; formerly a free function in `repro.launch.serve`).
  * `Cascade.uniform(n)`                       — placeholder spec for
    strategies that need no tables (thresholds, fixed endpoints).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import skip_dp
from repro.core.line_dp import LineTables, solve_line
from repro.core.markov import MarkovChain, estimate_chain
from repro.core.skip_dp import SkipTables
from repro.core.support import Support, build_support, quantize

__all__ = ["Cascade"]


@dataclasses.dataclass
class Cascade:
    """Calibrated cascade spec: topology + costs + support + tables."""

    support: Support
    chain: MarkovChain
    costs: jax.Array                       # (n,) objective-unit costs
    lam: float = 1.0                       # loss scale the tables assume
    line_tables: LineTables | None = None
    skip_tables: SkipTables | None = None
    edge_costs: np.ndarray | None = None   # (n+1, n+1), set by solve_skip
    skip_mode: str | None = None

    @property
    def n_nodes(self) -> int:
        return self.chain.n

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_traces(cls, losses: np.ndarray, costs, *, k: int = 32,
                    lam: float = 1.0, min_cost: float = 1e-6,
                    solve: bool = True) -> "Cascade":
        """Fit support + chain from (T, n) raw loss traces and solve.

        ``losses`` are RAW; they are scaled by ``lam`` before support
        fitting so the tables live in the lambda-weighted domain.
        ``costs`` are taken as-is (already objective-weighted) and clamped
        to ``min_cost`` (Assumption 2.1 needs strictly positive costs).
        """
        scaled = lam * np.asarray(losses)
        support = build_support(scaled, k)
        bins = quantize(support, jnp.asarray(scaled))
        chain = estimate_chain(bins, k)
        costs = jnp.maximum(jnp.asarray(costs, jnp.float32), min_cost)
        casc = cls(support=support, chain=chain, costs=costs, lam=lam)
        if solve:
            casc.solve_line()
        return casc

    @classmethod
    def calibrate(cls, params, cfg, key, lam: float, *, k: int = 24,
                  t: int = 512, seq: int = 64, segment_costs=None,
                  solve: bool = True) -> "Cascade":
        """Fit a cascade from a model's own ramp losses on random prompts
        (the serving launcher's calibration step)."""
        from repro.models import model as M   # lazy: keep core import light
        toks = jax.random.randint(key, (t, seq), 0, cfg.vocab)
        _, _, node_losses, _ = M.prefill(params, cfg, {"tokens": toks},
                                         cache_len=seq + 8)
        raw = np.asarray(node_losses)
        n = raw.shape[1]
        if segment_costs is None:
            segment_costs = np.full((n,), 1.0 / n)
        costs = (1.0 - lam) * np.asarray(segment_costs)
        return cls.from_traces(raw, costs, k=k, lam=lam, solve=solve)

    @classmethod
    def uniform(cls, n_nodes: int, *, k: int = 8, lam: float = 1.0,
                costs=None) -> "Cascade":
        """Placeholder spec (uniform chain, linear grid) for strategies
        that consume only the topology and costs."""
        grid = jnp.linspace(0.1, 1.0, k, dtype=jnp.float32)
        support = Support(grid=grid, edges=(grid[1:] + grid[:-1]) / 2)
        p0 = jnp.full((k,), 1.0 / k, jnp.float32)
        trans = jnp.full((max(n_nodes - 1, 0), k, k), 1.0 / k, jnp.float32)
        chain = MarkovChain(p0=p0, trans=trans)
        if costs is None:
            costs = np.full((n_nodes,), 1.0 / n_nodes)
        return cls(support=support, chain=chain,
                   costs=jnp.asarray(costs, jnp.float32), lam=lam)

    # ------------------------------------------------------------------
    # solvers (cached on the spec)
    # ------------------------------------------------------------------

    def solve_line(self) -> LineTables:
        """Solve (and cache) the with-recall line DP (Alg. 2)."""
        if self.line_tables is None:
            self.line_tables = solve_line(self.chain, self.costs,
                                          self.support)
        return self.line_tables

    def solve_skip(self, mode: str = "cumulative") -> SkipTables:
        """Solve (and cache) the transitive-closure DP (§5.2).

        ``mode`` picks the edge-cost semantics: ``"cumulative"`` (intra-
        model early exit — skipped segments still pay backbone compute)
        or ``"skip_free"`` (inter-model cascades — skipped models are
        never run).
        """
        if mode not in ("cumulative", "skip_free"):
            raise ValueError(f"unknown skip mode {mode!r}")
        if self.skip_tables is None or self.skip_mode != mode:
            costs = np.asarray(self.costs, np.float64)
            builder = (skip_dp.edge_costs_cumulative if mode == "cumulative"
                       else skip_dp.edge_costs_skip_free)
            self.edge_costs = builder(costs)
            self.skip_tables = skip_dp.solve_skip(self.chain,
                                                  self.edge_costs,
                                                  self.support)
            self.skip_mode = mode
        return self.skip_tables
