"""`Cascade` — the calibrated serving spec a strategy is built from.

One object bundles everything the paper's pipeline produces between raw
traces and a deployable policy (DESIGN.md §4): the cascade topology (a
line of n nodes), per-node inspection costs in objective units, the
discrete loss `Support`, the fitted Markov chain, and the solved DP
tables (line and, on demand, skip).  ``strategy.make(name, cascade)``
reads whichever pieces the named strategy needs.

Construction paths:

  * `Cascade.from_traces(losses, costs, ...)`  — offline traces (the
    pareto sweeps and benchmarks).
  * `Cascade.calibrate(params, cfg, key, lam)` — run a model on
    calibration prompts and fit from its ramp losses (the serving
    launcher; formerly a free function in `repro.launch.serve`).
  * `Cascade.uniform(n)`                       — placeholder spec for
    strategies that need no tables (thresholds, fixed endpoints).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import skip_dp
from repro.core.line_dp import LineTables, solve_line
from repro.core.markov import MarkovChain, estimate_chain
from repro.core.skip_dp import SkipTables
from repro.core.support import Support, build_support, quantize

__all__ = ["Cascade"]


@dataclasses.dataclass
class Cascade:
    """Calibrated cascade spec: topology + costs + support + tables."""

    support: Support
    chain: MarkovChain
    costs: jax.Array                       # (n,) objective-unit costs
    lam: float = 1.0                       # loss scale the tables assume
    line_tables: LineTables | None = None
    skip_tables: SkipTables | None = None
    edge_costs: np.ndarray | None = None   # (n+1, n+1), set by solve_skip
    skip_mode: str | None = None
    # multi-model cascades: consecutive node counts per model (ladder
    # order) — None means the classic single-model line
    boundaries: tuple | None = None
    entry_costs: tuple | None = None       # per-model escalation charge

    @property
    def n_nodes(self) -> int:
        return self.chain.n

    @property
    def n_models(self) -> int:
        return 1 if self.boundaries is None else len(self.boundaries)

    def node_model(self, node: int) -> int:
        """Which ladder model owns global node ``node``."""
        if self.boundaries is None:
            return 0
        acc = 0
        for m, b in enumerate(self.boundaries):
            acc += b
            if node < acc:
                return m
        raise ValueError(f"node {node} out of range ({acc} nodes)")

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_traces(cls, losses: np.ndarray, costs, *, k: int = 32,
                    lam: float = 1.0, min_cost: float = 1e-6,
                    solve: bool = True, boundaries=None,
                    entry_costs=None) -> "Cascade":
        """Fit support + chain from (T, n) raw loss traces and solve.

        ``losses`` are RAW; they are scaled by ``lam`` before support
        fitting so the tables live in the lambda-weighted domain.
        ``costs`` are taken as-is (already objective-weighted) and clamped
        to ``min_cost`` (Assumption 2.1 needs strictly positive costs).

        ``boundaries`` declares a MULTI-MODEL cascade: the n trace
        columns are the concatenated node ladders of several models
        (e.g. a small model's ramps+head followed by a large model's),
        in escalation order.  The fitted chain simply spans the model
        boundary — crossing it is an escalation whose edge-cost
        semantics `solve_skip(mode="cascade")` encodes.
        """
        scaled = lam * np.asarray(losses)
        support = build_support(scaled, k)
        bins = quantize(support, jnp.asarray(scaled))
        chain = estimate_chain(bins, k)
        costs = jnp.maximum(jnp.asarray(costs, jnp.float32), min_cost)
        if boundaries is not None:
            boundaries = tuple(int(b) for b in boundaries)
            if sum(boundaries) != scaled.shape[1]:
                raise ValueError(
                    f"boundaries {boundaries} do not cover the "
                    f"{scaled.shape[1]} trace columns")
        if entry_costs is not None:
            entry_costs = tuple(float(c) for c in entry_costs)
        casc = cls(support=support, chain=chain, costs=costs, lam=lam,
                   boundaries=boundaries, entry_costs=entry_costs)
        if solve:
            casc.solve_line()
        return casc

    @classmethod
    def from_model_traces(cls, model_losses, model_costs, *, k: int = 32,
                          lam: float = 1.0, entry_costs=None,
                          solve: bool = True, **kwargs) -> "Cascade":
        """Multi-model calibration: per-model (T, n_m) loss traces over
        the SAME T calibration inputs, concatenated in ladder order.
        Each model's columns are its own ramps + head; the result is a
        `Cascade` whose ``boundaries`` record where each model's nodes
        start, ready for ``solve_skip(mode="cascade")``."""
        model_losses = [np.asarray(ls) for ls in model_losses]
        t = model_losses[0].shape[0]
        if any(ls.shape[0] != t for ls in model_losses):
            raise ValueError("per-model traces must share the T axis "
                             "(same calibration inputs)")
        boundaries = tuple(ls.shape[1] for ls in model_losses)
        costs = np.concatenate([np.asarray(c, np.float64)
                                for c in model_costs])
        if len(costs) != sum(boundaries):
            raise ValueError(f"model_costs cover {len(costs)} nodes, "
                             f"traces have {sum(boundaries)}")
        return cls.from_traces(np.concatenate(model_losses, axis=1),
                               costs, k=k, lam=lam, solve=solve,
                               boundaries=boundaries,
                               entry_costs=entry_costs, **kwargs)

    @classmethod
    def calibrate(cls, params, cfg, key, lam: float, *, k: int = 24,
                  t: int = 512, seq: int = 64, segment_costs=None,
                  solve: bool = True) -> "Cascade":
        """Fit a cascade from a model's own ramp losses on random prompts
        (the serving launcher's calibration step)."""
        from repro.models import model as M   # lazy: keep core import light
        toks = jax.random.randint(key, (t, seq), 0, cfg.vocab)
        _, _, node_losses, _ = M.prefill(params, cfg, {"tokens": toks},
                                         cache_len=seq + 8)
        raw = np.asarray(node_losses)
        n = raw.shape[1]
        if segment_costs is None:
            segment_costs = np.full((n,), 1.0 / n)
        costs = (1.0 - lam) * np.asarray(segment_costs)
        return cls.from_traces(raw, costs, k=k, lam=lam, solve=solve)

    @classmethod
    def uniform(cls, n_nodes: int, *, k: int = 8, lam: float = 1.0,
                costs=None, boundaries=None) -> "Cascade":
        """Placeholder spec (uniform chain, linear grid) for strategies
        that consume only the topology and costs."""
        grid = jnp.linspace(0.1, 1.0, k, dtype=jnp.float32)
        support = Support(grid=grid, edges=(grid[1:] + grid[:-1]) / 2)
        p0 = jnp.full((k,), 1.0 / k, jnp.float32)
        trans = jnp.full((max(n_nodes - 1, 0), k, k), 1.0 / k, jnp.float32)
        chain = MarkovChain(p0=p0, trans=trans)
        if costs is None:
            costs = np.full((n_nodes,), 1.0 / n_nodes)
        if boundaries is not None:
            boundaries = tuple(int(b) for b in boundaries)
            if sum(boundaries) != n_nodes:
                raise ValueError(f"boundaries {boundaries} do not cover "
                                 f"{n_nodes} nodes")
        return cls(support=support, chain=chain,
                   costs=jnp.asarray(costs, jnp.float32), lam=lam,
                   boundaries=boundaries)

    def refit(self, losses: np.ndarray) -> "Cascade":
        """Re-fit support + chain from NEW raw loss rows at this spec's
        lambda and support size, preserving costs / boundaries / entry
        costs, and re-solve the same table family — the online
        `Recalibrator`'s publish path (DESIGN.md §11).

        Same support size and node count mean the solved tables are
        SHAPE-IDENTICAL to this spec's, so a strategy rebuilt from the
        result can be hot-swapped into a reserved strategy-bank slot
        without retracing the jitted token step.
        """
        losses = np.asarray(losses)
        if losses.ndim != 2 or losses.shape[1] != self.n_nodes:
            raise ValueError(f"refit rows have shape {losses.shape}; "
                             f"this cascade expects (T, {self.n_nodes})")
        casc = Cascade.from_traces(
            losses, np.asarray(self.costs), k=self.support.size,
            lam=self.lam, solve=False, boundaries=self.boundaries,
            entry_costs=self.entry_costs)
        if self.line_tables is not None:
            casc.solve_line()
        if self.skip_tables is not None:
            casc.solve_skip(self.skip_mode)
        return casc

    # ------------------------------------------------------------------
    # solvers (cached on the spec)
    # ------------------------------------------------------------------

    def solve_line(self) -> LineTables:
        """Solve (and cache) the with-recall line DP (Alg. 2)."""
        if self.line_tables is None:
            self.line_tables = solve_line(self.chain, self.costs,
                                          self.support)
        return self.line_tables

    def solve_skip(self, mode: str = "cumulative") -> SkipTables:
        """Solve (and cache) the transitive-closure DP (§5.2).

        ``mode`` picks the edge-cost semantics: ``"cumulative"`` (intra-
        model early exit — skipped segments still pay backbone compute),
        ``"skip_free"`` (idealized inter-model cascades — skipped models
        are never run), or ``"cascade"`` (the multi-model ladder this
        spec's ``boundaries`` declare: cumulative inside each model,
        skip_free-style across model boundaries, plus the per-model
        ``entry_costs`` escalation charge).
        """
        if mode not in ("cumulative", "skip_free", "cascade"):
            raise ValueError(f"unknown skip mode {mode!r}")
        if mode == "cascade" and self.boundaries is None:
            raise ValueError(
                "skip mode 'cascade' needs multi-model boundaries — "
                "calibrate via Cascade.from_model_traces (or pass "
                "boundaries= to from_traces)")
        if self.skip_tables is None or self.skip_mode != mode:
            costs = np.asarray(self.costs, np.float64)
            if mode == "cascade":
                self.edge_costs = skip_dp.edge_costs_cascade(
                    costs, self.boundaries, entry_costs=self.entry_costs)
            else:
                builder = (skip_dp.edge_costs_cumulative
                           if mode == "cumulative"
                           else skip_dp.edge_costs_skip_free)
                self.edge_costs = builder(costs)
            self.skip_tables = skip_dp.solve_skip(self.chain,
                                                  self.edge_costs,
                                                  self.support)
            self.skip_mode = mode
        return self.skip_tables
