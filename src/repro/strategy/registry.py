"""String registry mapping policy names to strategy factories.

``make("recall_index", cascade)`` builds a ready-to-serve strategy from a
calibrated `Cascade`; ``available()`` lists every registered name.  All
eight legacy `core.policies` behaviours are registered, plus the skip-
and tree-table-backed variants that previously never reached serving.

Factories accept a ``lam`` override (default: the cascade's own lambda)
— pass ``lam=1.0`` when the traces you feed are already lambda-scaled
(the offline pareto sweeps do this).
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.strategy.cascade import Cascade
from repro.strategy.line import (FixedNodeStrategy, PatienceStrategy,
                                 RecallIndexStrategy, ThresholdStrategy,
                                 TreeIndexStrategy)
from repro.strategy.oracle import OracleStrategy
from repro.strategy.skip import SkipRecallStrategy

__all__ = ["register", "available", "make", "needs_tables",
           "slot_signature", "reserve_bank"]

_REGISTRY: Dict[str, Callable[..., object]] = {}
_ONLINE: Dict[str, bool] = {}
_NEEDS_TABLES: Dict[str, bool] = {}


def register(name: str, online: bool = True, needs_tables: bool = False):
    """Decorator: register a ``factory(cascade, **kwargs) -> Strategy``.

    ``online=False`` marks hindsight-only strategies (usable with
    `strategy.evaluate` but rejected by the serving engine);
    ``needs_tables=True`` marks strategies whose factory solves DP
    tables, so callers can skip model calibration for the others.
    Both let CLIs filter without instantiating anything.
    """
    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"strategy {name!r} already registered")
        _REGISTRY[name] = factory
        _ONLINE[name] = online
        _NEEDS_TABLES[name] = needs_tables
        return factory
    return deco


def available(online_only: bool = False) -> tuple[str, ...]:
    return tuple(sorted(n for n in _REGISTRY
                        if not online_only or _ONLINE[n]))


def needs_tables(name: str) -> bool:
    """Does the named strategy consume solved DP tables (and therefore
    need a real calibrated cascade rather than a placeholder)?"""
    if name not in _NEEDS_TABLES:
        raise KeyError(f"unknown strategy {name!r}; available: "
                       f"{', '.join(available())}")
    return _NEEDS_TABLES[name]


def make(name: str, cascade: Cascade, **kwargs):
    """Build the named strategy from a `Cascade` spec."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; available: "
                       f"{', '.join(available())}") from None
    return factory(cascade, **kwargs)


def slot_signature(strategy) -> tuple:
    """Structural signature a reserved bank slot must keep across hot
    swaps: strategy class, the pytree structure of its dynamic arrays,
    and every leaf's (shape, dtype).

    Two strategies with equal signatures compile to the SAME jitted
    token step when the bank's arrays are threaded as traced arguments,
    so publishing one over the other is guaranteed retrace-free; the
    control plane's `BankSwap` refuses any publish that changes it.
    """
    import jax as _jax
    import jax.numpy as _jnp

    from repro.strategy.base import dynamic_arrays

    arrays = dynamic_arrays(strategy)
    leaves, treedef = _jax.tree.flatten(arrays)
    shapes = tuple((tuple(_jnp.shape(leaf)), _jnp.asarray(leaf).dtype.name)
                   for leaf in leaves)
    return (type(strategy).__name__, str(treedef), shapes)


def reserve_bank(strategies) -> tuple:
    """Reserve strategy-bank slots for a gear bank.

    Validates that every member is servable online, that all members
    agree on the node count, and records each slot's swap signature.
    Returns ``(strategies, signatures)`` — the fixed-size tuple the
    token step is traced over and the per-slot contract later
    publishes are checked against.
    """
    strategies = tuple(strategies)
    if not strategies:
        raise ValueError("a strategy bank needs at least one slot")
    n = strategies[0].n_nodes
    for i, s in enumerate(strategies):
        if not getattr(s, "online", False):
            raise ValueError(f"slot {i}: {type(s).__name__} is a "
                             "hindsight-only strategy; banks serve online")
        if s.n_nodes != n:
            raise ValueError(f"slot {i} expects {s.n_nodes} nodes, slot 0 "
                             f"expects {n} — one bank serves one ladder")
    return strategies, tuple(slot_signature(s) for s in strategies)


def _lam(cascade: Cascade, lam) -> float:
    return cascade.lam if lam is None else float(lam)


@register("recall_index", needs_tables=True)
def _recall_index(c: Cascade, *, lam=None):
    return RecallIndexStrategy(c.solve_line(), c.support, costs=c.costs,
                               lam=_lam(c, lam))


@register("tree_index", needs_tables=True)
def _tree_index(c: Cascade, *, lam=None):
    return TreeIndexStrategy(c.solve_line(), c.support, costs=c.costs,
                             lam=_lam(c, lam))


@register("norecall_threshold")
def _norecall_threshold(c: Cascade, *, threshold=0.3, lam=None):
    return ThresholdStrategy(c.n_nodes, threshold, recall=False,
                             costs=c.costs, lam=_lam(c, lam))


@register("recall_threshold")
def _recall_threshold(c: Cascade, *, threshold=0.3, lam=None):
    return ThresholdStrategy(c.n_nodes, threshold, recall=True,
                             costs=c.costs, lam=_lam(c, lam))


@register("norecall_patience")
def _norecall_patience(c: Cascade, *, patience=2, lam=None):
    return PatienceStrategy(c.n_nodes, patience, costs=c.costs,
                            lam=_lam(c, lam))


@register("oracle", online=False)
def _oracle(c: Cascade, *, lam=None):
    return OracleStrategy(c.n_nodes, costs=c.costs, recall=True,
                          lam=_lam(c, lam))


@register("oracle_norecall", online=False)
def _oracle_norecall(c: Cascade, *, lam=None):
    return OracleStrategy(c.n_nodes, costs=c.costs, recall=False,
                          lam=_lam(c, lam))


@register("always_last")
def _always_last(c: Cascade, *, lam=None):
    return FixedNodeStrategy(c.n_nodes, c.n_nodes - 1, costs=c.costs,
                             lam=_lam(c, lam))


@register("always_first")
def _always_first(c: Cascade, *, lam=None):
    return FixedNodeStrategy(c.n_nodes, 0, costs=c.costs, lam=_lam(c, lam))


@register("skip_recall", needs_tables=True)
def _skip_recall(c: Cascade, *, mode="cumulative", lam=None):
    tables = c.solve_skip(mode)
    return SkipRecallStrategy(tables, c.support, c.edge_costs,
                              lam=_lam(c, lam))
