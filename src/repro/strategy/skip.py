"""Skip-cascade strategy (§5.2): the transitive-closure NEXT table as a
streaming `Strategy`.

The solved `SkipTables.nxt` table stores, for every (last-probed node,
previous bin, running-min X index), either STOP or the next node to probe
— possibly skipping intermediates.  Streamed over a line of nodes in
order, a lane simply ignores every node that is not its current target,
so the same object drives offline `strategy.evaluate` and the segment
engine (where skipped ramp heads are never consulted; whether the skipped
*backbone* compute is also saved is encoded in the edge-cost matrix:
``skip_free`` for inter-model cascades, ``cumulative`` for intra-model
early exit).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.skip_dp import SkipTables
from repro.core.support import Support
from repro.strategy.line import _bins

__all__ = ["SkipRecallStrategy"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SkipState:
    nxt_node: jax.Array     # (B,) i32 — next node to probe (STOP = -1)
    last: jax.Array         # (B,) i32 — last probed node (-1 = root)
    s_bin: jax.Array        # (B,) i32
    x_idx: jax.Array        # (B,) i32
    best_loss: jax.Array    # (B,) f32
    best_node: jax.Array    # (B,) i32
    explore_cost: jax.Array  # (B,) f32 — edge costs paid
    n_probed: jax.Array      # (B,) i32 — nodes actually probed


class SkipRecallStrategy:
    """Probe the NEXT-table's target node, pay the traversed edge cost,
    serve the argmin probed node (recall)."""

    online = True
    # the walk follows a NEXT table solved from the root — it cannot be
    # floor-pinned mid-line (the cascade's commit policy checks this)
    jumps = True
    swap_attrs = ("tables", "support", "edge_costs")

    def __init__(self, tables: SkipTables, support: Support | None,
                 edge_costs, lam: float = 1.0):
        self.tables = tables
        self.support = support
        self.lam = float(lam)
        self.n_nodes = tables.n
        self.edge_costs = jnp.asarray(edge_costs, jnp.float32)
        if self.edge_costs.shape != (self.n_nodes + 1, self.n_nodes + 1):
            raise ValueError(f"edge_costs shape {self.edge_costs.shape} != "
                             f"({self.n_nodes + 1}, {self.n_nodes + 1})")

    def init(self, batch: int) -> SkipState:
        k = self.tables.k
        first = self.tables.nxt[0, 0, k + 1]   # root decision, s irrelevant
        return SkipState(
            nxt_node=jnp.full((batch,), first, jnp.int32),
            last=jnp.full((batch,), -1, jnp.int32),
            s_bin=jnp.zeros((batch,), jnp.int32),
            x_idx=jnp.full((batch,), k + 1, jnp.int32),
            best_loss=jnp.full((batch,), jnp.inf, jnp.float32),
            best_node=jnp.zeros((batch,), jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: SkipState, node, losses, active, aux=None):
        probe = active & (state.nxt_node == node)
        scaled = self.lam * losses.astype(jnp.float32)
        b = _bins(self.support, scaled, aux)
        edge = self.edge_costs[state.last + 1, node + 1]
        explore = state.explore_cost + probe * edge
        n_probed = state.n_probed + probe
        better = probe & (scaled < state.best_loss)
        best_loss = jnp.where(better, scaled, state.best_loss)
        best_node = jnp.where(better, node, state.best_node)
        x_idx = jnp.where(probe, jnp.minimum(state.x_idx, b + 1),
                          state.x_idx)
        s_bin = jnp.where(probe, b, state.s_bin)
        last = jnp.where(probe, node, state.last)
        nxt_new = self.tables.nxt[node + 1, s_bin, x_idx]
        nxt_node = jnp.where(probe, nxt_new, state.nxt_node)
        # STOP (-1) and exhausted lines both fail `nxt_node > node`
        cont = active & (nxt_node > node)
        return SkipState(nxt_node=nxt_node, last=last, s_bin=s_bin,
                         x_idx=x_idx, best_loss=best_loss,
                         best_node=best_node, explore_cost=explore,
                         n_probed=n_probed), cont

    def serve(self, state: SkipState) -> jax.Array:
        return state.best_node
