"""Line-topology strategies: the paper's table/index policies and the
classic early-exit baselines, all as pure `Strategy` implementations.

Every strategy here folds one node per ``observe`` call over a
pytree-registered state, so the same object drives the offline
``strategy.evaluate`` scan and the segment-wise serving engine.

  * `RecallIndexStrategy`  — Alg. 1 backed by the `LineTables.stop` table
    (O(1) gather per node per lane, Thm 4.5).
  * `TreeIndexStrategy`    — the exact dynamic index sigma(s, i) of
    Def. 4.4, the multi-line/tree form (§5.1): probe while the running
    min X exceeds the next node's interpolated index.
  * `ThresholdStrategy`    — DeeBERT/BranchyNet confidence thresholds,
    with or without recall.
  * `PatienceStrategy`     — PABEE consecutive-agreement stopping (uses
    the ``aux`` prediction channel).
  * `FixedNodeStrategy`    — always_first / always_last static endpoints.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.line_dp import LineTables
from repro.core.support import Support, quantize

__all__ = [
    "RecallIndexStrategy", "TreeIndexStrategy", "ThresholdStrategy",
    "PatienceStrategy", "FixedNodeStrategy",
]


def _as_costs(costs, n: int) -> jax.Array:
    if costs is None:
        return jnp.zeros((n,), jnp.float32)
    costs = jnp.asarray(costs, jnp.float32)
    if costs.shape != (n,):
        raise ValueError(f"costs shape {costs.shape} != ({n},)")
    return costs


def _bins(support: Support | None, scaled: jax.Array, aux) -> jax.Array:
    """Support-quantized bins, or the precomputed ``aux`` bins when the
    strategy was built without a Support (deprecated-wrapper path)."""
    if support is not None:
        return quantize(support, scaled)
    if aux is None:
        raise ValueError("strategy built without a Support needs "
                         "precomputed bins on the aux channel")
    return aux


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RecallState:
    x_idx: jax.Array        # (B,) i32 — running-min X-axis index
    s_bin: jax.Array        # (B,) i32 — previous probed node's bin
    best_loss: jax.Array    # (B,) f32 — running min scaled loss
    best_node: jax.Array    # (B,) i32 — argmin node (recall target)
    explore_cost: jax.Array  # (B,) f32
    n_probed: jax.Array      # (B,) i32


class RecallIndexStrategy:
    """Alg. 1: probe while the if-stop table says continue, serve argmin."""

    online = True
    # hot-swappable decision parameters (control-plane recalibration)
    swap_attrs = ("tables", "support", "costs")

    def __init__(self, tables: LineTables, support: Support | None,
                 costs=None, lam: float = 1.0):
        self.tables = tables
        self.support = support
        self.lam = float(lam)
        self.n_nodes = tables.n
        self.costs = _as_costs(costs, tables.n)

    def init(self, batch: int) -> RecallState:
        k = self.tables.k
        return RecallState(
            x_idx=jnp.full((batch,), k + 1, jnp.int32),
            s_bin=jnp.zeros((batch,), jnp.int32),
            best_loss=jnp.full((batch,), jnp.inf, jnp.float32),
            best_node=jnp.zeros((batch,), jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: RecallState, node, losses, active, aux=None):
        scaled = self.lam * losses.astype(jnp.float32)
        b = _bins(self.support, scaled, aux)
        explore = state.explore_cost + active * self.costs[node]
        n_probed = state.n_probed + active
        better = active & (scaled < state.best_loss)
        best_loss = jnp.where(better, scaled, state.best_loss)
        best_node = jnp.where(better, node, state.best_node)
        x_idx = jnp.where(active, jnp.minimum(state.x_idx, b + 1),
                          state.x_idx)
        s_bin = jnp.where(active, b, state.s_bin)
        # stop table for the NEXT node; row gather clamps at n-1 but the
        # (node + 1 < n) mask forces a stop after the final node anyway.
        stop_next = self.tables.stop[node + 1, s_bin, x_idx]
        cont = active & ~stop_next & (node + 1 < self.n_nodes)
        return RecallState(x_idx=x_idx, s_bin=s_bin, best_loss=best_loss,
                           best_node=best_node, explore_cost=explore,
                           n_probed=n_probed), cont

    def serve(self, state: RecallState) -> jax.Array:
        return state.best_node


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TreeIndexState:
    s_bin: jax.Array
    x_val: jax.Array        # (B,) f32 — exact (unbinned) running min
    best_node: jax.Array
    explore_cost: jax.Array
    n_probed: jax.Array


class TreeIndexStrategy:
    """Exact dynamic-index policy: stop once X <= sigma(next | s).

    ``sigma`` is the off-grid indifference point recovered by linear
    interpolation in the line DP (Def. 4.4); comparing the *continuous*
    running min against it is exactly how the multi-line / tree index
    policies (§5.1, Thm C.7) rank branches, so this strategy is the
    single-line member of the tree-table family.
    """

    online = True
    swap_attrs = ("tables", "support", "costs")

    def __init__(self, tables: LineTables, support: Support | None,
                 costs=None, lam: float = 1.0):
        self.tables = tables
        self.support = support
        self.lam = float(lam)
        self.n_nodes = tables.n
        self.costs = _as_costs(costs, tables.n)

    def init(self, batch: int) -> TreeIndexState:
        return TreeIndexState(
            s_bin=jnp.zeros((batch,), jnp.int32),
            x_val=jnp.full((batch,), jnp.inf, jnp.float32),
            best_node=jnp.zeros((batch,), jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: TreeIndexState, node, losses, active, aux=None):
        scaled = self.lam * losses.astype(jnp.float32)
        b = _bins(self.support, scaled, aux)
        explore = state.explore_cost + active * self.costs[node]
        n_probed = state.n_probed + active
        better = active & (scaled < state.x_val)
        x_val = jnp.where(better, scaled, state.x_val)
        best_node = jnp.where(better, node, state.best_node)
        s_bin = jnp.where(active, b, state.s_bin)
        sigma_next = self.tables.sigma[node + 1, s_bin]
        # ties break toward stopping (Def. 4.4 "smallest solution")
        cont = active & (x_val > sigma_next) & (node + 1 < self.n_nodes)
        return TreeIndexState(s_bin=s_bin, x_val=x_val, best_node=best_node,
                              explore_cost=explore, n_probed=n_probed), cont

    def serve(self, state: TreeIndexState) -> jax.Array:
        return state.best_node


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ThresholdState:
    last_node: jax.Array
    best_loss: jax.Array
    best_node: jax.Array
    explore_cost: jax.Array
    n_probed: jax.Array


class ThresholdStrategy:
    """Stop at the first node whose scaled loss clears its threshold."""

    online = True
    swap_attrs = ("thresholds", "costs")

    def __init__(self, n_nodes: int, thresholds, recall: bool = False,
                 costs=None, lam: float = 1.0):
        self.n_nodes = int(n_nodes)
        self.recall = bool(recall)
        self.lam = float(lam)
        self.costs = _as_costs(costs, self.n_nodes)
        thr = jnp.asarray(thresholds, jnp.float32)
        self.thresholds = jnp.broadcast_to(thr, (self.n_nodes,))

    def init(self, batch: int) -> ThresholdState:
        return ThresholdState(
            last_node=jnp.zeros((batch,), jnp.int32),
            best_loss=jnp.full((batch,), jnp.inf, jnp.float32),
            best_node=jnp.zeros((batch,), jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: ThresholdState, node, losses, active, aux=None):
        scaled = self.lam * losses.astype(jnp.float32)
        explore = state.explore_cost + active * self.costs[node]
        n_probed = state.n_probed + active
        last_node = jnp.where(active, node, state.last_node)
        better = active & (scaled < state.best_loss)
        best_loss = jnp.where(better, scaled, state.best_loss)
        best_node = jnp.where(better, node, state.best_node)
        hit = scaled <= self.thresholds[node]
        cont = active & ~hit & (node + 1 < self.n_nodes)
        return ThresholdState(last_node=last_node, best_loss=best_loss,
                              best_node=best_node, explore_cost=explore,
                              n_probed=n_probed), cont

    def serve(self, state: ThresholdState) -> jax.Array:
        return state.best_node if self.recall else state.last_node


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PatienceState:
    prev_pred: jax.Array
    streak: jax.Array
    last_node: jax.Array
    explore_cost: jax.Array
    n_probed: jax.Array


class PatienceStrategy:
    """PABEE: exit after `patience` consecutive ramps agree (aux = preds)."""

    online = True
    needs_aux = True   # consumes predictions; loss-only replay can't drive it
    swap_attrs = ("costs",)   # patience itself is static control flow

    def __init__(self, n_nodes: int, patience: int, costs=None,
                 lam: float = 1.0):
        self.n_nodes = int(n_nodes)
        self.patience = int(patience)
        self.lam = float(lam)
        self.costs = _as_costs(costs, self.n_nodes)

    def init(self, batch: int) -> PatienceState:
        return PatienceState(
            prev_pred=jnp.full((batch,), -1, jnp.int32),
            streak=jnp.zeros((batch,), jnp.int32),
            last_node=jnp.zeros((batch,), jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: PatienceState, node, losses, active, aux=None):
        if aux is None:
            raise ValueError("PatienceStrategy needs predictions on the "
                             "aux channel")
        explore = state.explore_cost + active * self.costs[node]
        n_probed = state.n_probed + active
        last_node = jnp.where(active, node, state.last_node)
        same = (aux == state.prev_pred) & (node > 0)
        streak = jnp.where(same, state.streak + 1, 0)
        hit = (streak >= self.patience) & (node > 0)
        cont = active & ~hit & (node + 1 < self.n_nodes)
        return PatienceState(prev_pred=aux.astype(jnp.int32), streak=streak,
                             last_node=last_node, explore_cost=explore,
                             n_probed=n_probed), cont

    def serve(self, state: PatienceState) -> jax.Array:
        return state.last_node


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FixedState:
    served: jax.Array
    explore_cost: jax.Array
    n_probed: jax.Array


class FixedNodeStrategy:
    """Static endpoints of the trade-off: always_first / always_last."""

    online = True
    swap_attrs = ("costs",)   # serve_node is static by definition

    def __init__(self, n_nodes: int, serve_node: int, costs=None,
                 lam: float = 1.0):
        self.n_nodes = int(n_nodes)
        self.serve_node = int(serve_node) % self.n_nodes
        self.lam = float(lam)
        self.costs = _as_costs(costs, self.n_nodes)

    def init(self, batch: int) -> FixedState:
        return FixedState(
            served=jnp.full((batch,), self.serve_node, jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: FixedState, node, losses, active, aux=None):
        explore = state.explore_cost + active * self.costs[node]
        n_probed = state.n_probed + active
        cont = active & (node < self.serve_node)
        return FixedState(served=state.served, explore_cost=explore,
                          n_probed=n_probed), cont

    def serve(self, state: FixedState) -> jax.Array:
        return state.served
