"""repro.strategy — ONE pluggable decision layer for trace evaluation,
benchmarks, and the online serving engine (DESIGN.md §3-4).

    from repro import strategy
    casc = strategy.Cascade.from_traces(losses, costs, k=32, lam=0.6)
    strat = strategy.make("recall_index", casc)
    result = strategy.evaluate(strat, losses)      # offline traces
    Engine(params, cfg, strat, cache_len=128)      # online serving
"""

from repro.strategy.base import (PolicyResult, Strategy, dynamic_arrays,
                                 evaluate, init_lane, reset_lanes,
                                 with_arrays)
from repro.strategy.cascade import Cascade
from repro.strategy.line import (FixedNodeStrategy, PatienceStrategy,
                                 RecallIndexStrategy, ThresholdStrategy,
                                 TreeIndexStrategy)
from repro.strategy.oracle import OracleStrategy
from repro.strategy.registry import (available, make, needs_tables,
                                     register, reserve_bank, slot_signature)
from repro.strategy.skip import SkipRecallStrategy

__all__ = [
    "Strategy", "PolicyResult", "evaluate", "reset_lanes", "init_lane",
    "dynamic_arrays", "with_arrays",
    "Cascade",
    "make", "available", "needs_tables", "register",
    "reserve_bank", "slot_signature",
    "RecallIndexStrategy", "TreeIndexStrategy", "ThresholdStrategy",
    "PatienceStrategy", "FixedNodeStrategy", "OracleStrategy",
    "SkipRecallStrategy",
]
