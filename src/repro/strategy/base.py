"""The `Strategy` protocol — ONE decision API for offline trace evaluation,
benchmarks, and the online serving engine (DESIGN.md §3).

A strategy is a *functional* object: all mutable quantities live in a
pytree-registered state dataclass, and the three protocol methods are pure:

  * ``init(batch) -> state``            — fresh per-lane state.
  * ``observe(state, node, losses, active, aux) -> (state, active)``
        — fold in node ``node``'s per-lane losses; returns the updated
        state and the mask of lanes that should CONTINUE past this node.
  * ``serve(state) -> served_node``     — which node's output each lane
        returns if it stops now (with recall this is the argmin node).

``node`` may be a traced int32 scalar, so one ``observe`` implementation
jits, vmaps, and ``lax.scan``s in both the offline evaluator below and the
segment-wise engine (`repro.serving.engine`).  ``aux`` is an optional int32
per-lane side channel: predicted labels for patience-style strategies
(the engine supplies argmax logits there), or precomputed support bins
for table strategies built without a ``Support`` (offline evaluation
against pre-quantized traces).

State contract: every state dataclass carries ``explore_cost`` (f32 per
lane, objective-units inspection cost paid so far) and ``n_probed`` (i32
per lane), which ``evaluate`` reads back together with ``serve`` to build
a ``PolicyResult``.  Strategies that price exploration differently (e.g.
skip strategies paying edge costs) simply maintain these fields their own
way — no isinstance dispatch anywhere downstream.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

__all__ = ["PolicyResult", "Strategy", "evaluate", "reset_lanes",
           "init_lane", "dynamic_arrays", "with_arrays"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PolicyResult:
    """Outcome of running a strategy over a batch of traces."""

    served_node: jax.Array   # (T,) int — node whose prediction is returned
    served_loss: jax.Array   # (T,) float — loss of the served node
    explore_cost: jax.Array  # (T,) float — sum of inspection costs paid
    n_probed: jax.Array      # (T,) int — number of nodes inspected

    @property
    def total(self) -> jax.Array:
        return self.served_loss + self.explore_cost

    def mean_total(self) -> jax.Array:
        return jnp.mean(self.total)


@runtime_checkable
class Strategy(Protocol):
    """Structural protocol — any object with these members qualifies."""

    n_nodes: int
    lam: float       # scale applied to incoming losses inside observe
    online: bool     # False => needs hindsight; engine refuses it

    def init(self, batch: int):
        ...

    def observe(self, state, node, losses: jax.Array, active: jax.Array,
                aux: jax.Array | None = None) -> Tuple[object, jax.Array]:
        ...

    def serve(self, state) -> jax.Array:
        ...


def dynamic_arrays(strategy: Strategy) -> dict:
    """The strategy's hot-swappable parameters, keyed by attribute name.

    A strategy's ``swap_attrs`` class attribute names the attributes
    that parameterize its DECISIONS — solved DP tables, supports,
    thresholds, cost vectors.  Each is a pytree of arrays whose
    structure and shapes are fixed by the cascade's (n, k), so
    republishing a same-shaped pytree changes the policy without
    changing the jitted program: this is the control plane's hot-swap
    contract (DESIGN.md §11).  Strategies without ``swap_attrs``
    (oracles, fixed endpoints with no learned tables) return ``{}``.
    """
    return {name: getattr(strategy, name)
            for name in getattr(strategy, "swap_attrs", ())}


def with_arrays(strategy: Strategy, arrays: dict) -> Strategy:
    """Shallow clone of ``strategy`` with its dynamic arrays replaced.

    Called INSIDE a traced token step, so the swap attributes become
    traced jit ARGUMENTS instead of baked-in closure constants —
    publishing new same-shaped arrays then hits the jit cache instead
    of retracing.  Static decision structure (lam, topology, patience
    ints) stays on the original object and remains compile-time.
    """
    if not arrays:
        return strategy
    clone = copy.copy(strategy)
    for name, value in arrays.items():
        setattr(clone, name, value)
    return clone


def reset_lanes(strategy: Strategy, state, mask: jax.Array):
    """Per-lane state reset — the runtime's lane-recycling primitive.

    Every state leaf is a ``(B, ...)`` per-lane array, so slicing the
    pytree with a broadcast ``where`` re-initializes exactly the lanes
    where ``mask`` is True while leaving the other lanes' carried state
    (running minima, streaks, paid costs) bit-identical.  Pure and
    jittable; the continuous-batching scheduler calls this at every
    admission so a recycled lane can never leak its previous request's
    decisions into the next one (tests/serving/test_runtime.py).
    """
    mask = jnp.asarray(mask)
    b = mask.shape[0]
    fresh = strategy.init(b)

    def sel(f, s):
        return jnp.where(mask.reshape((b,) + (1,) * (s.ndim - 1)), f, s)

    return jax.tree.map(sel, fresh, state)


def init_lane(strategy: Strategy, state, lane) -> object:
    """Reset a single lane (static or traced i32 index) of a batched
    state to its fresh ``init`` value — sugar over `reset_lanes`."""
    b = jax.tree.leaves(state)[0].shape[0]
    return reset_lanes(strategy, state, jnp.arange(b) == lane)


def evaluate(strategy: Strategy, losses: jax.Array,
             aux: jax.Array | None = None) -> PolicyResult:
    """Run ``strategy`` over offline traces with one ``lax.scan`` over nodes.

    Args:
      strategy: any `Strategy`; its internal ``lam`` scaling applies, so
        pass losses in the units the strategy was calibrated for.
      losses: (T, n) per-node losses.
      aux: optional (T, n) int32 side channel (predictions / bins).

    Returns a `PolicyResult`; ``served_loss`` is reported in the
    strategy's scaled units (``lam * losses[served]``) so objectives are
    comparable with the DP value.
    """
    losses = jnp.asarray(losses)
    t, n = losses.shape
    if n != strategy.n_nodes:
        raise ValueError(f"traces have {n} nodes, strategy expects "
                         f"{strategy.n_nodes}")

    state0 = strategy.init(t)
    active0 = jnp.ones((t,), bool)

    # aux=None stays None so aux-requiring strategies (patience, table
    # strategies without a Support) raise instead of seeing zeros.
    def step(carry, inp):
        state, active = carry
        node, loss_col = inp[0], inp[1]
        aux_col = inp[2] if len(inp) > 2 else None
        state, active = strategy.observe(state, node, loss_col, active,
                                         aux=aux_col)
        return (state, active), None

    xs = (jnp.arange(n, dtype=jnp.int32), losses.T)
    if aux is not None:
        xs = xs + (jnp.asarray(aux, jnp.int32).T,)
    (state, _), _ = jax.lax.scan(step, (state0, active0), xs)

    served = strategy.serve(state)
    served_loss = strategy.lam * jnp.take_along_axis(
        losses, served[:, None], axis=1)[:, 0]
    return PolicyResult(
        served_node=served,
        served_loss=served_loss,
        explore_cost=state.explore_cost,
        n_probed=state.n_probed,
    )
