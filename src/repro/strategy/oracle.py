"""Offline oracle strategies (hindsight baselines, Def. 3.2 analogues).

These need the whole trace before committing to a stop point, so they are
``online = False``: `strategy.evaluate` scans them over every node and the
state tracks the best prefix seen so far, but the serving engine refuses
them (it cannot un-run segments).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["OracleStrategy"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OracleState:
    pmin_val: jax.Array      # (B,) f32 — prefix min of scaled losses
    pmin_node: jax.Array     # (B,) i32 — prefix argmin (first occurrence)
    prefix_cost: jax.Array   # (B,) f32 — cumulative inspection cost
    best_total: jax.Array    # (B,) f32 — best prefix objective so far
    best_served: jax.Array   # (B,) i32 — served node at the best prefix
    explore_cost: jax.Array  # (B,) f32 — cost paid at the best prefix
    n_probed: jax.Array      # (B,) i32 — prefix length at the best prefix


class OracleStrategy:
    """Best stopping prefix under full foresight.

    With ``recall`` the served node is the prefix argmin (offline optimum
    with recall); without, the policy must serve the node it stops at
    (``oracle_norecall``).
    """

    online = False

    def __init__(self, n_nodes: int, costs=None, recall: bool = True,
                 lam: float = 1.0):
        from repro.strategy.line import _as_costs
        self.n_nodes = int(n_nodes)
        self.recall = bool(recall)
        self.lam = float(lam)
        self.costs = _as_costs(costs, self.n_nodes)

    def init(self, batch: int) -> OracleState:
        return OracleState(
            pmin_val=jnp.full((batch,), jnp.inf, jnp.float32),
            pmin_node=jnp.zeros((batch,), jnp.int32),
            prefix_cost=jnp.zeros((batch,), jnp.float32),
            best_total=jnp.full((batch,), jnp.inf, jnp.float32),
            best_served=jnp.zeros((batch,), jnp.int32),
            explore_cost=jnp.zeros((batch,), jnp.float32),
            n_probed=jnp.zeros((batch,), jnp.int32),
        )

    def observe(self, state: OracleState, node, losses, active, aux=None):
        scaled = self.lam * losses.astype(jnp.float32)
        better = scaled < state.pmin_val
        pmin_val = jnp.where(better, scaled, state.pmin_val)
        pmin_node = jnp.where(better, node, state.pmin_node)
        prefix_cost = state.prefix_cost + self.costs[node]
        cand = pmin_val if self.recall else scaled
        total = cand + prefix_cost
        improve = total < state.best_total    # strict: first argmin, as
        best_total = jnp.where(improve, total, state.best_total)
        served_here = pmin_node if self.recall else \
            jnp.full_like(pmin_node, node)
        best_served = jnp.where(improve, served_here, state.best_served)
        explore = jnp.where(improve, prefix_cost, state.explore_cost)
        n_probed = jnp.where(improve, node + 1, state.n_probed)
        # hindsight: keep scanning every node regardless of `active`
        return OracleState(pmin_val=pmin_val, pmin_node=pmin_node,
                           prefix_cost=prefix_cost, best_total=best_total,
                           best_served=best_served, explore_cost=explore,
                           n_probed=n_probed), active

    def serve(self, state: OracleState) -> jax.Array:
        return state.best_served
