"""Paged-attention decode — Pallas TPU kernel (DESIGN.md §8).

One query token per lane attends over the lane's page-table slice of the
global KV pool (serving.kvpool).  The page INDIRECTION happens inside
the grid: grid (B*Hkv, lane_pages) with the page axis innermost, and the
k/v/pos BlockSpec index maps read ``table[lane, j]`` through scalar
prefetch — Mosaic streams exactly the pages the lane owns from HBM into
VMEM, so the (B, C) gathered cache the jnp path materializes never
exists.  Online softmax scratch (running max / sum / accumulator, per
q-head-group) lives in VMEM across page steps, exactly like
flash_attention.py's kv axis.

Masking is position-driven (matches the paged decode contract in
models/attention.py): a pool slot with stored position -1 is EMPTY
(garbage-sink writes, masked early-exit holes, reset pages) and
positions beyond the lane's query position (stale shared-page tails)
are masked by causality — plus the sliding window if configured.  Pages
past the lane's used count are skipped entirely with pl.when.

Block shapes: the (group x page_size) score tile wants page_size to be
a multiple of 128 on real TPUs (lane alignment; q group is padded to a
sublane multiple by ops.py).  Interpret mode (CPU CI) takes any shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_kernel"]

NEG_INF = -1e30


def _kernel(table_ref, qpos_ref, nused_ref, q_ref, k_ref, v_ref, pos_ref,
            o_ref, m_scr, l_scr, acc_scr, *, scale: float,
            window: int | None, hkv: int):
    bh = pl.program_id(0)           # lane * Hkv + kv_head
    j = pl.program_id(1)            # index into the lane's page table
    nj = pl.num_programs(1)
    lane = bh // hkv

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j < nused_ref[lane])
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)         # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        kpos = pos_ref[0]                           # (ps,) i32
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = qpos_ref[lane]
        valid = (kpos >= 0) & (kpos <= qp)
        if window is not None:
            valid &= kpos > qp - window
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid[None, :], p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        # all-masked lanes (idle / nothing attendable) produce zeros
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                             "interpret"))
def paged_attention_kernel(q, k_pages, v_pages, pos_pages, page_table,
                           q_pos, n_used, *, scale: float,
                           window: int | None = None,
                           interpret: bool = False):
    """q (B, Hkv, G, hd); k/v_pages (P, Hkv, ps, hd); pos_pages (P, ps)
    i32; page_table (B, maxp) i32 (garbage-page padded); q_pos (B,) i32;
    n_used (B,) i32 pages to visit per lane.  hd % 128 == 0
    (ops.paged_attention pads).  Returns (B, Hkv, G, hd)."""
    b, hkv, g, hd = q.shape
    ps = k_pages.shape[2]
    maxp = page_table.shape[1]
    qf = q.reshape(b * hkv, g, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, g, hd),
                         lambda bh, j, t, qp, nu: (bh, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bh, j, t, qp, nu, hkv=hkv:
                         (t[bh // hkv, j], bh % hkv, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bh, j, t, qp, nu, hkv=hkv:
                         (t[bh // hkv, j], bh % hkv, 0, 0)),
            pl.BlockSpec((1, ps),
                         lambda bh, j, t, qp, nu, hkv=hkv:
                         (t[bh // hkv, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd),
                               lambda bh, j, t, qp, nu: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               hkv=hkv)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, hd), q.dtype),
        interpret=interpret,
    )(page_table, q_pos.astype(jnp.int32), n_used.astype(jnp.int32),
      qf, k_pages, v_pages, pos_pages)
    return out.reshape(b, hkv, g, hd)
