"""Chunked-prefill flash attention over the paged KV pool — Pallas TPU
kernel (DESIGN.md §9).

One PREFILL CHUNK of C query tokens per lane attends over (a) the
lane's page-table history — everything earlier chunks already committed
to the pool — and (b) the chunk's own in-flight keys, causally.  This
is the device side of Sarathi-style chunked prefill: the chunk runs
inside the same program as decode, and its history reads go through the
SAME page indirection as the paged decode kernel
(kernels/paged_attention.py) — grid ``(B*Hkv, maxp + 1)`` with the page
axis innermost, k/v/pos BlockSpec index maps reading ``table[lane, j]``
via scalar prefetch, online-softmax scratch in VMEM across the kv axis.
The final grid step (``j == maxp``) switches to the chunk's in-flight
k/v block (resident in VMEM for every j — it is small), so the kernel
never materializes the (B, C_hist + C) gathered tensor the jnp path
builds.

Masking contract (matches models/attention.py `attn_prefill_chunk`):

  * pool slots with stored position -1 are EMPTY (garbage-sink writes,
    masked early-exit holes, freshly reset pages) — never attended;
  * pool history is clipped to ``kpos < chunk_start[lane]`` — the
    chunk's OWN positions may already have been scattered into the pool
    before the kernel runs (commit order is scatter-then-attend), and
    they must come from the in-flight block instead, exactly once;
  * the in-flight block is causal per query row (``ckpos <= qpos``);
    query rows padded with position -1 (ragged final chunks, idle
    prefill slots) have nothing attendable and return zeros;
  * a sliding window drops keys at ``kpos <= qpos - window`` on both
    sides.

Pages past ``ceil(chunk_start / ps)`` are skipped with pl.when.  Block
shapes: the (C*G, ps) score tile wants ps and the chunk-key axis padded
to 128 on real TPUs and C*G to a sublane multiple (ops.py pads);
interpret mode (CPU CI) takes any shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_prefill_kernel"]

NEG_INF = -1e30


def _kernel(table_ref, start_ref, nhist_ref, q_ref, qpos_ref, k_ref, v_ref,
            pos_ref, ck_ref, cv_ref, cpos_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, window: int | None, hkv: int, g: int):
    bh = pl.program_id(0)           # lane * Hkv + kv_head
    j = pl.program_id(1)            # page index; j == nj-1 = in-chunk block
    nj = pl.num_programs(1)
    lane = bh // hkv

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _accumulate(k, v, valid):
        """One online-softmax block update.  k/v (T, hd) f32, valid
        (C, G?, T) broadcastable to the (C, G, T) score tile."""
        q = q_ref[0].astype(jnp.float32)                  # (C*G, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        c = q.shape[0] // g
        s = s.reshape(c, g, k.shape[0])
        s = jnp.where(valid, s, NEG_INF).reshape(c * g, k.shape[0])
        pv = jnp.broadcast_to(valid, (c, g, k.shape[0])).reshape(
            c * g, k.shape[0])

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(pv, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when((j < nj - 1) & (j < nhist_ref[lane]))
    def _history_page():
        kpos = pos_ref[0]                                 # (ps,) i32
        qp = qpos_ref[0]                                  # (C,) i32
        valid = (kpos[None, :] >= 0) \
            & (kpos[None, :] < start_ref[lane]) \
            & (kpos[None, :] <= qp[:, None])
        if window is not None:
            valid &= kpos[None, :] > qp[:, None] - window
        _accumulate(k_ref[0, 0].astype(jnp.float32),
                    v_ref[0, 0].astype(jnp.float32),
                    valid[:, None, :])

    @pl.when(j == nj - 1)
    def _in_chunk():
        ckpos = cpos_ref[0]                               # (Cp,) i32
        qp = qpos_ref[0]                                  # (C,) i32
        valid = (ckpos[None, :] >= 0) & (qp[:, None] >= 0) \
            & (ckpos[None, :] <= qp[:, None])
        if window is not None:
            valid &= ckpos[None, :] > qp[:, None] - window
        _accumulate(ck_ref[0].astype(jnp.float32),
                    cv_ref[0].astype(jnp.float32),
                    valid[:, None, :])
        # all-masked rows (position -1 padding) produce zeros
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                             "interpret"))
def paged_prefill_kernel(q, q_pos, k_pages, v_pages, pos_pages, page_table,
                         chunk_start, n_hist, ck, cv, c_pos, *,
                         scale: float, window: int | None = None,
                         interpret: bool = False):
    """q (B, Hkv, C*G, hd) chunk queries (rows grouped by position: row
    ``c*G + g``); q_pos (B, C) i32 per-row positions (-1 = padded row);
    k/v_pages (P, Hkv, ps, hd) pool; pos_pages (P, ps) i32; page_table
    (B, maxp) i32 garbage-padded; chunk_start (B,) i32 (history reads
    are clipped to kpos < start); n_hist (B,) i32 history pages to
    visit; ck/cv (B, Hkv, Cp, hd) in-flight chunk keys/values; c_pos
    (B, Cp) i32 their positions (-1 = padding).  Returns
    (B, Hkv, C*G, hd)."""
    b, hkv, cg, hd = q.shape
    c = q_pos.shape[1]
    g = cg // c
    ps = k_pages.shape[2]
    maxp = page_table.shape[1]
    cp = ck.shape[2]
    qf = q.reshape(b * hkv, cg, hd)
    ckf = ck.reshape(b * hkv, cp, hd)
    cvf = cv.reshape(b * hkv, cp, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * hkv, maxp + 1),
        in_specs=[
            pl.BlockSpec((1, cg, hd),
                         lambda bh, j, t, st, nh: (bh, 0, 0)),
            pl.BlockSpec((1, c),
                         lambda bh, j, t, st, nh, hkv=hkv:
                         (bh // hkv, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bh, j, t, st, nh, hkv=hkv, maxp=maxp:
                         (t[bh // hkv, jnp.minimum(j, maxp - 1)],
                          bh % hkv, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda bh, j, t, st, nh, hkv=hkv, maxp=maxp:
                         (t[bh // hkv, jnp.minimum(j, maxp - 1)],
                          bh % hkv, 0, 0)),
            pl.BlockSpec((1, ps),
                         lambda bh, j, t, st, nh, maxp=maxp, hkv=hkv:
                         (t[bh // hkv, jnp.minimum(j, maxp - 1)], 0)),
            pl.BlockSpec((1, cp, hd),
                         lambda bh, j, t, st, nh: (bh, 0, 0)),
            pl.BlockSpec((1, cp, hd),
                         lambda bh, j, t, st, nh: (bh, 0, 0)),
            pl.BlockSpec((1, cp),
                         lambda bh, j, t, st, nh, hkv=hkv:
                         (bh // hkv, 0)),
        ],
        out_specs=pl.BlockSpec((1, cg, hd),
                               lambda bh, j, t, st, nh: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((cg,), jnp.float32),
            pltpu.VMEM((cg,), jnp.float32),
            pltpu.VMEM((cg, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               hkv=hkv, g=g)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, cg, hd), q.dtype),
        interpret=interpret,
    )(page_table, chunk_start.astype(jnp.int32), n_hist.astype(jnp.int32),
      qf, q_pos.astype(jnp.int32), k_pages, v_pages, pos_pages,
      ckf, cvf, c_pos.astype(jnp.int32))
    return out.reshape(b, hkv, cg, hd)
