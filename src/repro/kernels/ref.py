"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each function mirrors its kernel's EXACT contract (shapes, dtypes,
masking, accumulation order is allowed to differ within float tolerance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "paged_attention_ref",
           "paged_prefill_ref", "bellman_backup_ref", "ssd_chunk_ref",
           "ramp_exit_ref"]


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True,
                        window: int | None = None):
    """q (B,H,S,hd), k/v (B,Hkv,S,hd), H = G*Hkv.  Returns (B,H,S,hd)."""
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, s, hd)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                        n_used, *, scale: float, window: int | None = None):
    """Paged single-token decode attention (paged_attention.py contract).

    q (B, Hkv, G, hd); k/v_pages (P, Hkv, ps, hd); pos_pages (P, ps) i32
    (-1 = empty slot); page_table (B, maxp) i32; q_pos (B,) i32;
    n_used (B,) i32 — table entries at index >= n_used are ignored.
    Returns (B, Hkv, G, hd); lanes with nothing attendable return zeros.
    """
    b, hkv, g, hd = q.shape
    ps = k_pages.shape[2]
    maxp = page_table.shape[1]
    k = k_pages[page_table].astype(jnp.float32)     # (B, maxp, Hkv, ps, hd)
    v = v_pages[page_table].astype(jnp.float32)
    kpos = pos_pages[page_table]                    # (B, maxp, ps)
    k = k.transpose(0, 2, 1, 3, 4).reshape(b, hkv, maxp * ps, hd)
    v = v.transpose(0, 2, 1, 3, 4).reshape(b, hkv, maxp * ps, hd)
    valid = (kpos >= 0) & (kpos <= q_pos[:, None, None])
    if window is not None:
        valid &= kpos > (q_pos[:, None, None] - window)
    valid &= jnp.arange(maxp)[None, :, None] < n_used[:, None, None]
    valid = valid.reshape(b, 1, 1, maxp * ps)
    logits = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32),
                        k) * scale
    # -1e30 (not -inf) keeps all-masked lanes NaN-free; the post-softmax
    # where() then turns their uniform weights into zeros
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid, w, 0.0)
    out = jnp.einsum("bkgt,bktd->bkgd", w, v)
    return out.astype(q.dtype)


def paged_prefill_ref(q, q_pos, k_pages, v_pages, pos_pages, page_table,
                      chunk_start, n_hist, ck, cv, c_pos, *, scale: float,
                      window: int | None = None):
    """Chunked-prefill attention over the paged pool (paged_prefill.py
    contract).

    q (B, Hkv, C, G, hd) chunk queries; q_pos (B, C) i32 (-1 = padded
    row, returns zeros); k/v_pages (P, Hkv, ps, hd); pos_pages (P, ps)
    i32 (-1 empty); page_table (B, maxp) i32; chunk_start (B,) i32 —
    pool history is clipped to kpos < start (the chunk's own positions
    come from the in-flight block, even if already scattered);
    n_hist (B,) i32 — table entries at index >= n_hist are ignored;
    ck/cv (B, Hkv, Cp, hd) in-flight chunk keys/values with positions
    c_pos (B, Cp) i32 (-1 padding), attended causally per query row.
    Returns (B, Hkv, C, G, hd).
    """
    b, hkv, c, g, hd = q.shape
    ps = k_pages.shape[2]
    maxp = page_table.shape[1]
    kh = k_pages[page_table].astype(jnp.float32)    # (B, maxp, Hkv, ps, hd)
    vh = v_pages[page_table].astype(jnp.float32)
    kpos = pos_pages[page_table].reshape(b, maxp * ps)
    kh = kh.transpose(0, 2, 1, 3, 4).reshape(b, hkv, maxp * ps, hd)
    vh = vh.transpose(0, 2, 1, 3, 4).reshape(b, hkv, maxp * ps, hd)
    page_ok = jnp.repeat(jnp.arange(maxp)[None, :] < n_hist[:, None], ps,
                         axis=1)                    # (B, maxp*ps)
    hist_ok = (kpos >= 0) & (kpos < chunk_start[:, None]) & page_ok
    k_all = jnp.concatenate([kh, ck.astype(jnp.float32)], axis=2)
    v_all = jnp.concatenate([vh, cv.astype(jnp.float32)], axis=2)
    pos_all = jnp.concatenate([kpos, c_pos], axis=1)  # (B, T)
    ok_all = jnp.concatenate([hist_ok, c_pos >= 0], axis=1)
    valid = ok_all[:, None, :] & (pos_all[:, None, :]
                                  <= q_pos[:, :, None]) \
        & (q_pos[:, :, None] >= 0)                  # (B, C, T)
    if window is not None:
        valid &= pos_all[:, None, :] > (q_pos[:, :, None] - window)
    logits = jnp.einsum("bkcgd,bktd->bkcgt", q.astype(jnp.float32),
                        k_all) * scale
    valid = valid[:, None, :, None, :]              # (B, 1, C, 1, T)
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid, w, 0.0)
    out = jnp.einsum("bkcgt,bktd->bkcgd", w, v_all)
    return out.astype(q.dtype)


def bellman_backup_ref(phi_next, trans, cost, mi_t):
    """T-Tamer Bellman backup (line_dp._backup contract).

    phi_next (K, X), trans (K, K), cost scalar, mi_t (K, X) int32 with
    mi_t[y, x] = X-axis index of min(xvals[x], grid[y]).
    Returns cont (K, X): cost + trans @ M, M[y,x] = phi_next[y, mi_t[y,x]].
    """
    m = jnp.take_along_axis(phi_next, mi_t, axis=1)
    return cost + trans @ m


def ssd_chunk_ref(xh, dt, da, bb, cc):
    """Within-chunk SSD (ssm.ssd_chunked inner term).

    xh (B,C,Q,H,P), dt/da (B,C,Q,H), bb/cc (B,C,Q,H,N).
    Returns (y_diag (B,C,Q,H,P), states (B,C,H,P,N)).
    """
    seg_a = da.swapaxes(-1, -2)                       # (B,C,H,Q)
    cs = jnp.cumsum(seg_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    q = da.shape[2]
    mask = jnp.tril(jnp.ones((q, q), bool))
    l = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cc, bb)
    m = scores * l * dt.swapaxes(-1, -2)[..., None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", m, xh)
    cum = jnp.cumsum(da, axis=2)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    w = decay_to_end * dt
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, bb, xh)
    return y_diag, states


def ramp_exit_ref(logits, edges, stop_table, s_bin, x_idx, lam: float):
    """Fused T-Tamer exit decision (serving hot path).

    logits (B, V); edges (K-1,) support bucket edges; stop_table
    (K, K+2) int8 (1 = stop); s_bin/x_idx (B,) current policy state.

    Computes: conf = max softmax(logits); loss = lam * (1 - conf);
    bin = searchsorted(edges, loss); new_x = min(x_idx, bin + 1);
    stop = stop_table[bin, new_x].

    Returns (loss (B,), bin (B,) int32, new_x (B,) int32, stop (B,) bool).
    """
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    mx = logits.astype(jnp.float32).max(axis=-1)
    conf = jnp.exp(mx - lse)
    loss = lam * (1.0 - conf)
    b = jnp.searchsorted(edges, loss).astype(jnp.int32)
    new_x = jnp.minimum(x_idx, b + 1)
    stop = stop_table[b, new_x] > 0
    return loss, b, new_x, stop
