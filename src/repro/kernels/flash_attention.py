"""Blockwise (flash) causal GQA attention — Pallas TPU kernel.

TPU adaptation (DESIGN.md §3): online-softmax attention tiled for VMEM.
Grid (B*H, n_q, n_kv) with the kv axis innermost; running max / sum /
accumulator live in VMEM scratch across kv steps (never spilled to HBM),
so HBM traffic is O(S*hd) instead of O(S^2).  Causal + sliding-window
blocks that lie entirely outside the mask are skipped with pl.when — for
a window w only O(S*w) work is executed.

Block shapes: (block_q x head_dim) and (block_kv x head_dim) tiles with
head_dim padded to a multiple of 128 by ops.py (MXU lane alignment); the
q/kv block defaults of 128 keep the score tile (128 x 128) MXU-shaped.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_kv: int, seq: int,
            window: int | None):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv
    # causal: skip blocks fully above the diagonal; window: skip blocks
    # fully left of the window.
    in_range = k_start <= q_start + block_q - 1
    if window is not None:
        in_range &= (k_start + block_kv - 1) > (q_start - window)

    @pl.when(in_range)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "block_q", "block_kv",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, scale: float, causal: bool = True,
                           window: int | None = None, block_q: int = 128,
                           block_kv: int = 128, interpret: bool = False):
    """q (B,H,S,hd), k/v (B,Hkv,S,hd); S % block == 0, hd % 128 == 0
    (ops.flash_attention pads).  Returns (B,H,S,hd)."""
    assert causal, "only causal attention is exposed"
    b, h, s, hd = q.shape
    hkv = k.shape[1]
    g = h // hkv
    n_q = s // block_q
    n_kv = s // block_kv

    grid = (b * h, n_q, n_kv)
    kernel = functools.partial(_kernel, scale=scale, block_q=block_q,
                               block_kv=block_kv, seq=s, window=window)
    qf = q.reshape(b * h, s, hd)
    kf = k.reshape(b * hkv, s, hd)
    vf = v.reshape(b * hkv, s, hd)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)
