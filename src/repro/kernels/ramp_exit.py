"""Fused T-Tamer exit decision — Pallas TPU kernel (serving hot path).

After a ramp head produces logits, the engine needs
    conf  = max softmax(logits)        (one number per lane)
    loss  = lam * (1 - conf)
    bin   = bucket of loss on the calibrated support
    stop  = if-stop table[bin, min(x_idx, bin+1)]
The naive path materializes the (B, V) softmax in HBM.  This kernel
streams the vocab in VMEM tiles with a running (max, sumexp) pair —
one pass over the logits, no softmax materialization — and performs the
bin search + table gather in the same program (the table is a few KiB of
VMEM).  O(1) decision per lane on top of the unavoidable logits read,
matching the Thm 4.5 inference bound.

Grid: (B_tiles, V_tiles), vocab innermost; scratch carries (max, sumexp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ramp_exit_kernel"]

NEG_INF = -1e30


def _kernel(logits_ref, edges_ref, table_ref, s_ref, x_ref,
            loss_ref, bin_ref, newx_ref, stop_ref,
            m_scr, l_scr, *, lam: float, n_edges: int):
    vi = pl.program_id(1)
    n_v = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    tile = logits_ref[...].astype(jnp.float32)       # (bB, bV)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, tile.max(axis=1))
    l_scr[...] = l_scr[...] * jnp.exp(m_prev - m_new) \
        + jnp.exp(tile - m_new[:, None]).sum(axis=1)
    m_scr[...] = m_new

    @pl.when(vi == n_v - 1)
    def _decide():
        conf = 1.0 / jnp.maximum(l_scr[...], 1e-30)  # exp(m - lse)
        loss = lam * (1.0 - conf)
        edges = edges_ref[0]                          # (n_edges,)
        # bin = #edges < loss  (searchsorted on the tiny support)
        b = jnp.sum(edges[None, :] < loss[:, None],
                    axis=1).astype(jnp.int32)
        x_idx = x_ref[...]
        new_x = jnp.minimum(x_idx, b + 1)
        tab = table_ref[...]                          # (K, K+2) i8? i32
        stop = tab[b, new_x]
        loss_ref[...] = loss
        bin_ref[...] = b
        newx_ref[...] = new_x
        stop_ref[...] = stop


@functools.partial(jax.jit, static_argnames=("lam", "block_b", "block_v",
                                             "interpret"))
def ramp_exit_kernel(logits, edges, stop_table, s_bin, x_idx, *,
                     lam: float, block_b: int = 8, block_v: int = 2048,
                     interpret: bool = False):
    """logits (B, V); edges (E,) f32; stop_table (K, K+2) int32;
    s_bin/x_idx (B,) int32.  B % block_b == 0, V % block_v == 0 (ops
    pads; pad logits with -inf).  Returns (loss, bin, new_x, stop)."""
    bsz, v = logits.shape
    n_edges = edges.shape[0]
    k, xdim = stop_table.shape
    grid = (bsz // block_b, v // block_v)
    kernel = functools.partial(_kernel, lam=lam, n_edges=n_edges)
    loss, bins, newx, stop = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_v), lambda bi, vi: (bi, vi)),
            pl.BlockSpec((1, n_edges), lambda bi, vi: (0, 0)),
            pl.BlockSpec((k, xdim), lambda bi, vi: (0, 0)),
            pl.BlockSpec((block_b,), lambda bi, vi: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, vi: (bi,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda bi, vi: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, vi: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, vi: (bi,)),
            pl.BlockSpec((block_b,), lambda bi, vi: (bi,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz,), jnp.float32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, edges[None, :], stop_table.astype(jnp.int32),
      s_bin, x_idx)
    return loss, bins, newx, stop > 0
