"""T-Tamer Bellman backup — Pallas TPU kernel (the DP preprocessing
hot-spot, Thm 4.5 / Alg. 2).

One backward step computes, for every state (s, x):

    cont[s, x] = c_i + sum_y P_i[s, y] * Phi_{i+1}[y, min_idx(x, y)]

TPU mapping (DESIGN.md §3): the min-gather M[y, x] = Phi[y, mi[y, x]] is
built in VMEM from the Phi tile and immediately consumed by the MXU
matmul P @ M — M never round-trips to HBM, which is the point of fusing
(the jnp path materializes it).  Grid tiles the X axis; each program
holds the full (K x K) transition tile and (K x X_blk) Phi tile in VMEM —
K is padded to a multiple of 128 by ops.py for MXU alignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bellman_backup_kernel"]


def _kernel(phi_ref, trans_ref, mi_ref, cost_ref, out_ref):
    phi = phi_ref[...]                              # (K, X) f32
    mi = mi_ref[...]                                # (K, Xblk) i32
    m = jnp.take_along_axis(phi, mi, axis=1)        # (K, Xblk) — in VMEM
    trans = trans_ref[...]                          # (K, K)
    out_ref[...] = cost_ref[0, 0] + jax.lax.dot_general(
        trans, m, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_x", "interpret"))
def bellman_backup_kernel(phi_next, trans, cost, mi_t, *,
                          block_x: int = 128, interpret: bool = False):
    """phi_next (K, X) f32; trans (K, K) f32; cost scalar; mi_t (K, X)
    int32 (mi_t[y, x] = X-index of min(xvals[x], grid[y])).
    X % block_x == 0 (ops pads).  Returns cont (K, X) f32."""
    k, x = phi_next.shape
    grid = (x // block_x,)
    cost_arr = jnp.asarray(cost, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, x), lambda i: (0, 0)),          # full Phi
            pl.BlockSpec((k, k), lambda i: (0, 0)),          # full P_i
            pl.BlockSpec((k, block_x), lambda i: (0, i)),    # mi tile
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # cost scalar
        ],
        out_specs=pl.BlockSpec((k, block_x), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, x), jnp.float32),
        interpret=interpret,
    )(phi_next.astype(jnp.float32), trans.astype(jnp.float32),
      mi_t, cost_arr)
