"""Mamba2 SSD within-chunk kernel — Pallas TPU.

Computes, per (batch, chunk, head) grid cell, the quadratic "dual form"
of the chunk (the MXU-heavy part of SSD) plus the chunk's contribution to
the inter-chunk state:

    seg     = cumsum(dA)                       (Q,)
    L[i,j]  = exp(seg_i - seg_j) * [i >= j]    (Q, Q)
    Y       = ((C B^T) * L * dt_j) X           (Q, P)
    S_chunk = (exp(seg_Q - seg) * dt * B)^T X  (N, P) -> stored (P, N)

All Q x Q intermediates live in VMEM; HBM sees only the (Q, P) output and
the (P, N) state.  The inter-chunk recurrence (a tiny scan over nc) stays
in jnp — it's O(nc * P * N) and bandwidth-trivial.

VMEM budget per program: Q=256, N=128, P=64 f32 -> L (256 KiB) +
CB (256 KiB) + operands (~320 KiB) — comfortably under 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_kernel"]


def _kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,)
    da = da_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,)
    bb = b_ref[0, 0, :, 0, :].astype(jnp.float32)   # (Q, N)
    cc = c_ref[0, 0, :, 0, :].astype(jnp.float32)   # (Q, N)
    q = x.shape[0]

    seg = jnp.cumsum(da)
    diff = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l = jnp.where(ii >= jj, jnp.exp(diff), 0.0)     # (Q, Q) in VMEM

    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m = cb * l * dt[None, :]
    y_ref[0, 0, :, 0, :] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    w = jnp.exp(seg[-1] - seg) * dt                 # (Q,)
    wb = bb * w[:, None]                            # (Q, N)
    state = jax.lax.dot_general(x, wb, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s_ref[0, 0, 0, :, :] = state.astype(s_ref.dtype)  # (P, N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_kernel(xh, dt, da, bb, cc, *, interpret: bool = False):
    """xh (B,C,Q,H,P); dt/da (B,C,Q,H); bb/cc (B,C,Q,H,N).
    Returns (y_diag (B,C,Q,H,P) f32, states (B,C,H,P,N) f32)."""
    b, c, q, h, p = xh.shape
    n = bb.shape[-1]
    grid = (b, c, h)
    y, s = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, q, 1, n), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, c, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xh, dt, da, bb, cc)
    return y, s
