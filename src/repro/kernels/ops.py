"""jit'd public wrappers around the Pallas kernels: shape padding to tile
boundaries, dtype plumbing, and CPU dispatch (interpret=True executes the
kernel bodies in Python on CPU for correctness validation; on TPU the
same calls compile to Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import (bellman_backup as _bb, flash_attention as _fa,
                           paged_attention as _pa,
                           paged_prefill as _pp, ramp_exit as _re,
                           ssd_chunk as _sc)

__all__ = ["flash_attention", "paged_attention", "paged_prefill",
           "bellman_backup", "ssd_chunk", "ramp_exit", "on_cpu"]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, axis, mult, value=0.0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_kv: int = 128, interpret: bool | None = None):
    """q (B,S,H,hd), k/v (B,S,Hkv,hd) — model layout; returns same.

    Pads hd to 128 and S to the block size (padded kv is masked out by
    the causal mask since padded queries/keys sit at the tail)."""
    interpret = on_cpu() if interpret is None else interpret
    b, s, h, hd = q.shape
    qt = _pad_to(q.transpose(0, 2, 1, 3), 3, 128)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 3, 128)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 3, 128)
    s_pad = max(block_q, block_kv)
    qt = _pad_to(qt, 2, s_pad)
    kt = _pad_to(kt, 2, s_pad)
    vt = _pad_to(vt, 2, s_pad)
    out = _fa.flash_attention_kernel(qt, kt, vt, scale=scale, causal=causal,
                                     window=window, block_q=block_q,
                                     block_kv=block_kv, interpret=interpret)
    return out[:, :, :s, :hd].transpose(0, 2, 1, 3)


def paged_attention(q, k_pages, v_pages, pos_pages, page_table, q_pos, *,
                    scale: float, window: int | None = None,
                    interpret: bool | None = None):
    """Paged single-token decode attention — model layout in/out.

    q (B, H, hd) with H = G * Hkv; k/v_pages (P, page, Hkv, hd) — the
    pool layout models/attention.py scatters into; pos_pages (P, page)
    i32 (-1 empty); page_table (B, maxp) i32 garbage-page padded; q_pos
    (B,) i32.  Pads hd to 128 and the q group to a sublane multiple of
    8, derives the per-lane visited-page count from q_pos, and hands the
    kernel the (P, Hkv, page, hd) transpose.  Returns (B, H, hd).
    """
    interpret = on_cpu() if interpret is None else interpret
    b, h, hd = q.shape
    ps = k_pages.shape[1]
    hkv = k_pages.shape[2]
    g = h // hkv
    gp = -(-g // 8) * 8
    qg = q.reshape(b, hkv, g, hd)
    qg = _pad_to(_pad_to(qg, 3, 128), 2, gp)
    kt = _pad_to(k_pages.transpose(0, 2, 1, 3), 3, 128)
    vt = _pad_to(v_pages.transpose(0, 2, 1, 3), 3, 128)
    q_pos = q_pos.astype(jnp.int32)
    n_used = jnp.minimum(q_pos // ps + 1, page_table.shape[1])
    out = _pa.paged_attention_kernel(
        qg, kt, vt, pos_pages.astype(jnp.int32),
        page_table.astype(jnp.int32), q_pos, n_used, scale=scale,
        window=window, interpret=interpret)
    return out[:, :, :g, :hd].reshape(b, h, hd)


def paged_prefill(q, k_pages, v_pages, pos_pages, page_table, q_pos,
                  chunk_start, ck, cv, c_pos, *, scale: float,
                  window: int | None = None,
                  interpret: bool | None = None):
    """Chunked-prefill attention over the paged pool — model layout.

    q (B, C, H, hd) chunk queries with H = G * Hkv and per-row positions
    q_pos (B, C) i32 (-1 = padded row); k/v_pages (P, page, Hkv, hd) —
    the pool layout models/attention.py scatters into; pos_pages
    (P, page) i32; page_table (B, maxp) i32; chunk_start (B,) i32
    (history clipped to kpos < start); ck/cv (B, C, Hkv, hd) the chunk's
    own in-flight keys/values at positions c_pos (B, C).  Pads hd to
    128, the q group to a sublane multiple of 8, and the chunk-key axis
    to 128, derives the history page count from chunk_start, and hands
    the kernel the (P, Hkv, page, hd) transpose.  Returns (B, C, H, hd).
    """
    interpret = on_cpu() if interpret is None else interpret
    b, c, h, hd = q.shape
    ps = k_pages.shape[1]
    hkv = k_pages.shape[2]
    g = h // hkv
    gp = -(-g // 8) * 8
    # (B, C, H, hd) -> (B, Hkv, C, G, hd): row c*G + g is query (c, g)
    qg = q.reshape(b, c, hkv, g, hd).transpose(0, 2, 1, 3, 4)
    qg = _pad_to(_pad_to(qg, 4, 128), 3, gp)
    qg = qg.reshape(b, hkv, c * gp, hd + (-hd) % 128)
    kt = _pad_to(k_pages.transpose(0, 2, 1, 3), 3, 128)
    vt = _pad_to(v_pages.transpose(0, 2, 1, 3), 3, 128)
    cp = -(-c // 128) * 128
    ckt = _pad_to(_pad_to(ck.transpose(0, 2, 1, 3), 3, 128), 2, cp)
    cvt = _pad_to(_pad_to(cv.transpose(0, 2, 1, 3), 3, 128), 2, cp)
    c_pos_p = _pad_to(c_pos.astype(jnp.int32), 1, cp, value=-1)
    chunk_start = chunk_start.astype(jnp.int32)
    n_hist = jnp.clip(-(-chunk_start // ps), 0, page_table.shape[1])
    out = _pp.paged_prefill_kernel(
        qg, q_pos.astype(jnp.int32), kt, vt, pos_pages.astype(jnp.int32),
        page_table.astype(jnp.int32), chunk_start, n_hist, ckt, cvt,
        c_pos_p, scale=scale, window=window, interpret=interpret)
    out = out.reshape(b, hkv, c, gp, hd + (-hd) % 128)[:, :, :, :g, :hd]
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, h, hd)


def bellman_backup(phi_next, trans, cost, mi_t, *,
                   interpret: bool | None = None):
    """Drop-in for line_dp._backup's fused path: returns cont (K, X)."""
    interpret = on_cpu() if interpret is None else interpret
    k, x = phi_next.shape
    # pad X to 128 with repeats of the last column (harmless: extra states)
    xp = (-x) % 128
    if xp:
        phi_next = jnp.pad(phi_next, ((0, 0), (0, xp)), mode="edge")
        mi_t = jnp.pad(mi_t, ((0, 0), (0, xp)), mode="edge")
    cont = _bb.bellman_backup_kernel(phi_next, trans, cost, mi_t,
                                     interpret=interpret)
    return cont[:, :x]


def ssd_chunk(xh, dt, da, bb, cc, *, interpret: bool | None = None):
    """Within-chunk SSD; see ssd_chunk.py.  Shapes pass through."""
    interpret = on_cpu() if interpret is None else interpret
    y, s = _sc.ssd_chunk_kernel(xh, dt, da, bb, cc, interpret=interpret)
    return y.astype(xh.dtype), s.astype(xh.dtype)


def ramp_exit(logits, edges, stop_table, s_bin, x_idx, *, lam: float,
              interpret: bool | None = None):
    """Fused exit decision; logits (B, V).  Returns (loss, bin, new_x,
    stop) per lane."""
    interpret = on_cpu() if interpret is None else interpret
    b, v = logits.shape
    logits_p = _pad_to(logits, 1, 2048, value=-1e30)
    bb_pad = (-b) % 8
    if bb_pad:
        logits_p = jnp.pad(logits_p, ((0, bb_pad), (0, 0)),
                           constant_values=-1e30)
        s_bin = jnp.pad(s_bin, (0, bb_pad))
        x_idx = jnp.pad(x_idx, (0, bb_pad))
    loss, bins, newx, stop = _re.ramp_exit_kernel(
        logits_p, edges, stop_table, s_bin, x_idx, lam=lam,
        interpret=interpret)
    return loss[:b], bins[:b], newx[:b], stop[:b]
