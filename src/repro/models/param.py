"""Parameter definition system: single source of truth for shapes, init,
and logical sharding axes.

Modules declare a pytree of ``ParamDef``s; ``materialize`` turns it into
arrays (for smoke tests / real training) and ``abstract`` into
ShapeDtypeStructs (for the multi-pod dry-run — no allocation), while
``logical_specs`` extracts the logical-axis tree that
``repro.sharding.rules`` lowers to mesh PartitionSpecs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ParamDef", "materialize", "abstract", "logical_specs",
           "count_params"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + init recipe."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "fan_in"          # fan_in | zeros | ones | normal | embed
    scale: float = 1.0
    fan_axis: int = 0             # axis treated as fan-in for scaling

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} / axes {self.axes} mismatch")


def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(d: ParamDef, key: jax.Array, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init == "embed":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dtype)
    if d.init == "fan_in":
        fan = d.shape[d.fan_axis] if d.shape else 1
        std = d.scale / math.sqrt(max(fan, 1))
        return (std * jax.random.normal(key, d.shape)).astype(dtype)
    raise ValueError(f"unknown init {d.init}")


def materialize(defs, key: jax.Array, dtype=jnp.float32):
    """Instantiate a ParamDef tree into concrete arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract(defs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (dry-run: shape-only, no device allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def)


def logical_specs(defs):
    """Tree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(math.prod(d.shape) for d in leaves)
