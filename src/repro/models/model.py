"""Model assembly: segments of scanned blocks with early-exit ramps.

Public API (all pure functions over a params pytree):
  * ``model_defs(cfg)``       — ParamDef tree (shapes + logical axes).
  * ``forward_train(...)``    — full pass, EE multi-ramp loss (train_step).
  * ``prefill(...)``          — full pass, builds ring KV caches + per-ramp
                                confidences of the last position.
  * ``decode_step(...)``      — one-token step over all segments.
  * ``decode_segment(...)``   — one segment only (the serving engine's unit
                                of work: run segment, consult T-Tamer
                                if-stop table, maybe exit — DESIGN.md §2).

Ramp heads are a per-ramp RMSNorm + the shared (tied) unembedding — the
"logit lens" ramp, cheap in parameters; per-node cost c_i for T-Tamer is
the segment's FLOPs (benchmarks/flops.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import embed_def, rms_norm, rms_norm_def
from repro.models.config import ModelConfig, Segment
from repro.models.param import ParamDef
from repro.sharding.ctx import constrain_batch

__all__ = ["model_defs", "forward_train", "prefill", "decode_step",
           "decode_segment", "prefill_chunk_segment", "cache_specs",
           "paged_cache_specs", "unembed", "decode_unroll", "ramp_readout"]

# Decode-layer execution (perf hillclimb lever, EXPERIMENTS.md §Perf):
# scan (default) keeps HLO small; unrolled decode removes the per-step
# dynamic-slice copies of the stacked layer weights — the standard
# production choice for serving steps.
import contextlib
import contextvars

_DECODE_UNROLL = contextvars.ContextVar("repro_decode_unroll", default=False)


@contextlib.contextmanager
def decode_unroll(on: bool = True):
    tok = _DECODE_UNROLL.set(on)
    try:
        yield
    finally:
        _DECODE_UNROLL.reset(tok)


# --------------------------------------------------------------------------
# Parameter definitions
# --------------------------------------------------------------------------

def _stack_defs(defs, n: int):
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=(n,) + d.shape,
                                      axes=("layers",) + d.axes,
                                      fan_axis=d.fan_axis + 1),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def model_defs(cfg: ModelConfig) -> dict:
    defs: dict = {}
    if cfg.input_mode in ("tokens", "multimodal"):
        defs["embed"] = embed_def(cfg.vocab, cfg.d_model)
    elif cfg.tie_embeddings:
        # embeds-in models still need the output table
        defs["embed"] = embed_def(cfg.vocab, cfg.d_model)
    segs = []
    for seg in cfg.segments:
        sd: dict = {"blocks": _stack_defs(
            blocks.block_defs(seg.block, cfg.d_model), seg.n_layers)}
        if seg.ramp:
            sd["ramp"] = {"norm": rms_norm_def(cfg.d_model)}
        segs.append(sd)
    defs["segments"] = segs
    defs["final_norm"] = rms_norm_def(cfg.d_model)
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.d_model, cfg.vocab),
                                   ("embed", "vocab"))
    return defs


def unembed(params: dict, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return h @ params["embed"]["table"].T.astype(h.dtype)
    return h @ params["unembed"].astype(h.dtype)


def ramp_readout(params, cfg: ModelConfig, h: jax.Array,
                 segment: int | None = None):
    """The shared ramp / final-head readout (DESIGN.md §2): per-node
    RMSNorm, tied unembedding, and the T-Tamer loss proxy
    ``ell = 1 - max softmax prob`` (paper §6 / App. D.2).

    ``h`` is the RAW residual-stream hidden at the readout point, shape
    ``(..., D)``; ``segment`` selects that segment's ramp norm (``None``
    -> the final head norm).  Returns ``(logits (..., V), ell (...))``.
    One implementation feeds training (ramp CE), calibration (prefill
    node losses), and both serving engines, so the calibrated tables see
    exactly the quantity the online loop measures.
    """
    if segment is None:
        norm = params["final_norm"]
    else:
        norm = params["segments"][segment]["ramp"]["norm"]
    hn = rms_norm(norm, h, cfg.norm_eps)
    logits = unembed(params, cfg, hn)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return logits, 1.0 - p.max(axis=-1)


def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Returns (x (B,S,D), positions (B,S))."""
    if cfg.input_mode == "tokens":
        x = params["embed"]["table"][batch["tokens"]]
    elif cfg.input_mode == "embeds":
        x = batch["embeds"]
    elif cfg.input_mode == "multimodal":
        tok = params["embed"]["table"][batch["tokens"]]
        x = jnp.concatenate([batch["image_embeds"].astype(tok.dtype), tok],
                            axis=1)
    else:
        raise ValueError(cfg.input_mode)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return constrain_batch(x), positions


def _merge_aux(total: dict, aux_stack: dict) -> dict:
    for k, v in aux_stack.items():
        total[k] = total.get(k, 0.0) + jnp.sum(v)
    return total


# --------------------------------------------------------------------------
# Full-sequence passes
# --------------------------------------------------------------------------

def _run_segments(params, cfg: ModelConfig, x, positions, *,
                  want_cache: bool, cache_len: int | None,
                  remat: bool, use_flash: bool, use_ssd_kernel: bool):
    """Returns (final_hidden, ramp_hiddens, caches, aux)."""
    ramp_hiddens = []
    caches = []
    aux: dict = {}
    for si, seg in enumerate(cfg.segments):
        p_seg = params["segments"][si]["blocks"]

        if want_cache:
            def body(h, p_layer, seg=seg):
                y, cache, a = blocks.block_forward(
                    p_layer, h, positions, seg.block, cfg.norm_eps,
                    use_flash, use_ssd_kernel)
                ring = blocks.build_ring_cache(cache, positions, seg.block,
                                               cache_len)
                return y, (ring, a)
        else:
            def body(h, p_layer, seg=seg):
                y, _, a = blocks.block_forward(
                    p_layer, h, positions, seg.block, cfg.norm_eps,
                    use_flash, use_ssd_kernel)
                return y, a

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if want_cache:
            x, (ring_stack, aux_stack) = jax.lax.scan(body, x, p_seg)
            caches.append(ring_stack)
        else:
            x, aux_stack = jax.lax.scan(body, x, p_seg)
        x = constrain_batch(x)  # re-anchor residual-stream sharding
        aux = _merge_aux(aux, aux_stack)
        if seg.ramp:
            # RAW hidden; `ramp_readout` applies the per-ramp norm + head
            ramp_hiddens.append((si, x))
    return x, ramp_hiddens, caches, aux


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over valid (label >= 0) positions.  logits (B,S,V)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32),
        jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    valid = labels >= 0
    ce = jnp.where(valid, lse - ll, 0.0)
    return ce.sum() / jnp.maximum(valid.sum(), 1)


def forward_train(params, cfg: ModelConfig, batch: dict, *,
                  ramp_loss_weight: float = 0.3, remat: bool = True,
                  use_flash: bool = False, use_ssd_kernel: bool = False):
    """EE training objective: CE(final) + w * mean_r CE(ramp_r) + MoE aux.

    batch: {"tokens"/"embeds"/"image_embeds", "labels" (B, S_total)}.
    Returns (loss, metrics dict).
    """
    x, positions = _embed_inputs(params, cfg, batch)
    final, ramps, _, aux = _run_segments(
        params, cfg, x, positions, want_cache=False, cache_len=None,
        remat=remat, use_flash=use_flash, use_ssd_kernel=use_ssd_kernel)
    labels = batch["labels"]
    loss = _xent(ramp_readout(params, cfg, final)[0], labels)
    metrics = {"ce_final": loss}
    if ramps:
        ramp_ce = 0.0
        for ri, (si, h) in enumerate(ramps):
            ce = _xent(ramp_readout(params, cfg, h, segment=si)[0], labels)
            metrics[f"ce_ramp{ri}"] = ce
            ramp_ce += ce
        loss = loss + ramp_loss_weight * ramp_ce / len(ramps)
    for k, v in aux.items():
        metrics[k] = v
        loss = loss + v
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, cfg: ModelConfig, batch: dict, cache_len: int, *,
            use_flash: bool = False, use_ssd_kernel: bool = False):
    """Serving prefill: returns (last_logits (B,V), caches, ramp_losses
    (B, n_nodes), next_pos (B,)).  n_nodes = ramps + final (the T-Tamer
    line; the final head is the last node)."""
    x, positions = _embed_inputs(params, cfg, batch)
    final, ramps, caches, _ = _run_segments(
        params, cfg, x, positions, want_cache=True, cache_len=cache_len,
        remat=False, use_flash=use_flash, use_ssd_kernel=use_ssd_kernel)
    node_losses = [ramp_readout(params, cfg, h[:, -1, :], segment=si)[1]
                   for si, h in ramps]
    logits, final_loss = ramp_readout(params, cfg, final[:, -1, :])
    node_losses.append(final_loss)
    next_pos = positions[:, -1] + 1
    return logits, caches, jnp.stack(node_losses, axis=1), next_pos


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def decode_segment(params, cfg: ModelConfig, si: int, x: jax.Array,
                   cache_seg, pos: jax.Array, paged=None, write_mask=None):
    """Run segment `si` for one token.  x (B,1,D) -> (x', new_cache,
    readout) where readout is None for ramp-less segments and otherwise
    the full `ramp_readout` pair (logits (B,V), loss proxy (B,)) — the
    serving engine consumes both, so the head matmul runs exactly once.

    ``paged`` (attention.PagedKV) + ``write_mask`` route the attention
    layers at the paged KV pool; the per-lane page table and write
    target are shared by every layer (page ids are global)."""
    seg = cfg.segments[si]
    p_seg = params["segments"][si]["blocks"]

    if _DECODE_UNROLL.get():
        layer_caches = []
        for li in range(seg.n_layers):
            p_layer = jax.tree.map(lambda a, li=li: a[li], p_seg)
            cache_layer = jax.tree.map(lambda a, li=li: a[li], cache_seg)
            x, nc, _ = blocks.block_decode(p_layer, x, cache_layer, pos,
                                           seg.block, cfg.norm_eps,
                                           paged=paged,
                                           write_mask=write_mask)
            layer_caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *layer_caches)
    else:
        def body(h, xs):
            p_layer, cache_layer = xs
            y, new_cache, _ = blocks.block_decode(
                p_layer, h, cache_layer, pos, seg.block, cfg.norm_eps,
                paged=paged, write_mask=write_mask)
            return y, new_cache

        x, new_cache = jax.lax.scan(body, x, (p_seg, cache_seg))
    readout = None
    if seg.ramp:
        readout = ramp_readout(params, cfg, x[:, 0, :], segment=si)
    return x, new_cache, readout


def prefill_chunk_segment(params, cfg: ModelConfig, si: int, x: jax.Array,
                          cache_seg, table: jax.Array, chunk):
    """Run segment ``si`` for one PREFILL CHUNK against the paged pool
    (DESIGN.md §9).  x (B, C, D) -> (x', new_cache).  Chunks always run
    full depth (no early exit during prefill: every layer's KV must be
    complete before decode can share the pages), so there is no ramp
    readout here — the engine reads the final head once, on the chunk
    that finishes the prompt."""
    seg = cfg.segments[si]
    p_seg = params["segments"][si]["blocks"]

    def body(h, xs):
        p_layer, cache_layer = xs
        y, new_cache = blocks.block_prefill_chunk(
            p_layer, h, cache_layer, seg.block, cfg.norm_eps, table,
            chunk)
        return y, new_cache

    x, new_cache = jax.lax.scan(body, x, (p_seg, cache_seg))
    return constrain_batch(x), new_cache


def decode_step(params, cfg: ModelConfig, batch: dict, caches, pos):
    """Full-depth one-token step (the dry-run `serve_step` for decode
    shapes — worst case, no early exit).

    batch: {"tokens": (B,)} or {"embeds": (B, D)}.
    Returns (logits (B,V), new_caches, node_losses (B, n_nodes)).
    """
    if cfg.input_mode in ("tokens", "multimodal"):
        x = params["embed"]["table"][batch["tokens"]][:, None, :]
    else:
        x = batch["embeds"][:, None, :]
    x = constrain_batch(x)
    new_caches = []
    node_losses = []
    for si in range(len(cfg.segments)):
        x, nc, ro = decode_segment(params, cfg, si, x, caches[si], pos)
        new_caches.append(nc)
        if ro is not None:
            node_losses.append(ro[1])
    logits, final_loss = ramp_readout(params, cfg, x[:, 0, :])
    node_losses.append(final_loss)
    return logits, new_caches, jnp.stack(node_losses, axis=1)


def _stack_specs(cd: dict, n_layers: int):
    return jax.tree.map(
        lambda sd: ((n_layers,) + sd[0], sd[1]),
        cd, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> list:
    """(shape, dtype) spec tree for the whole decode cache (per segment,
    stacked over the segment's layers)."""
    return [_stack_specs(
        blocks.cache_defs(seg.block, cfg.d_model, batch, cache_len),
        seg.n_layers) for seg in cfg.segments]


def paged_cache_specs(cfg: ModelConfig, n_lanes: int, n_pages: int,
                      page_size: int) -> list:
    """Spec tree for the PAGED decode cache (DESIGN.md §8): attention
    leaves swap the lane axis for the global page pool — ``(L, P,
    page_size, ...)`` — while SSM state (no sequence axis to page) stays
    lane-indexed ``(L, n_lanes, ...)``.  Leaf names match `cache_specs`
    so the quant/dtype plumbing is shared."""
    out = []
    for seg in cfg.segments:
        pooled = blocks.cache_defs(seg.block, cfg.d_model, n_pages,
                                   page_size)
        laned = blocks.cache_defs(seg.block, cfg.d_model, n_lanes, 1)
        entry = {}
        if "attn" in pooled:
            entry["attn"] = pooled["attn"]
        if "ssm" in laned:
            entry["ssm"] = laned["ssm"]
        out.append(_stack_specs(entry, seg.n_layers))
    return out
