"""Shared low-level layers: RMSNorm, RoPE, embeddings, masks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef

__all__ = ["rms_norm", "rms_norm_def", "rope", "rope_cos_sin",
           "causal_mask", "embed_def"]


def rms_norm_def(dim: int, axis: str = "embed") -> dict:
    return {"scale": ParamDef((dim,), (axis,), init="ones")}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def embed_def(vocab: int, d_model: int) -> dict:
    return {"table": ParamDef((vocab, d_model), ("vocab", "embed"),
                              init="embed", scale=0.02)}


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Apply rotary embedding.  x: (..., seq, heads, head_dim);
    cos/sin: (..., seq, head_dim//2) — broadcast over the heads axis."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def causal_mask(q_pos: jax.Array, kv_pos: jax.Array,
                window: int | None = None) -> jax.Array:
    """Boolean (..., q, kv) mask: True = attend.

    q_pos (..., q), kv_pos (..., kv) are absolute positions; a sliding
    window additionally requires kv_pos > q_pos - window.
    """
    m = kv_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= kv_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m
