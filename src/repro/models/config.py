"""Model configuration dataclasses covering every assigned architecture
family (dense GQA, MLA+MoE, GShard-style MoE, Mamba2 SSD, Hymba hybrid,
audio/VLM backbones) plus early-exit ramp placement.

A model is a sequence of ``Segment``s.  Each segment is a scanned stack of
identical blocks optionally followed by an early-exit ramp — segment
boundaries ARE the T-Tamer nodes (DESIGN.md §2), so the serving engine can
execute segment-by-segment and consult the if-stop table between segments.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["MLAConfig", "AttnConfig", "SSMConfig", "MoEConfig",
           "BlockConfig", "Segment", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int | None = None   # V2-Lite projects q directly


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: int | None = None        # sliding-window size (None = full)
    mla: MLAConfig | None = None
    softmax_scale: float | None = None

    @property
    def q_dim(self) -> int:
        if self.mla:
            return self.n_heads * (self.mla.qk_nope_head_dim
                                   + self.mla.qk_rope_head_dim)
        return self.n_heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One transformer/SSM/hybrid block."""
    mixer: Literal["attn", "ssm", "hybrid"]
    attn: AttnConfig | None = None
    ssm: SSMConfig | None = None
    mlp: Literal["dense", "moe", "none"] = "dense"
    d_ff: int = 0                    # dense MLP hidden size
    moe: MoEConfig | None = None
    act: Literal["swiglu", "gelu"] = "swiglu"


@dataclasses.dataclass(frozen=True)
class Segment:
    """A scanned stack of `n_layers` identical blocks; if `ramp`, an
    early-exit ramp head is attached after the stack (a T-Tamer node)."""
    block: BlockConfig
    n_layers: int
    ramp: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    segments: tuple[Segment, ...]
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    input_mode: Literal["tokens", "embeds", "multimodal"] = "tokens"
    image_tokens: int = 0            # VLM: #patch embeddings per sample
    max_seq: int = 32_768
    # Long-context variant: when set, overrides every attention window for
    # the `long_500k` shape (DESIGN.md §4 sliding-window carve-out).
    long_context_window: int | None = 8_192

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    @property
    def n_ramps(self) -> int:
        """Number of T-Tamer nodes (final head counts as the last node)."""
        return sum(1 for s in self.segments if s.ramp)

    @property
    def is_subquadratic(self) -> bool:
        """True if every mixer is O(seq) at decode: SSM or windowed attn."""
        for s in self.segments:
            b = s.block
            if b.mixer == "attn" and b.attn.window is None:
                return False
            if b.mixer == "hybrid" and b.attn.window is None:
                return False
        return True

    def with_window(self, window: int) -> "ModelConfig":
        """Sliding-window override used for the long_500k decode shape."""
        segs = []
        for s in self.segments:
            b = s.block
            if b.mixer in ("attn", "hybrid") and b.attn is not None:
                w = min(window, b.attn.window) if b.attn.window else window
                b = dataclasses.replace(b, attn=dataclasses.replace(
                    b.attn, window=w))
            segs.append(dataclasses.replace(s, block=b))
        return dataclasses.replace(self, segments=tuple(segs),
                                   name=self.name + f"-sw{window}")
