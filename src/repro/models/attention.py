"""Attention mixers: GQA (with qk-norm / sliding window) and MLA
(DeepSeek-V2 Multi-head Latent Attention), each with a training/prefill
path and a single-token decode path against EITHER a per-lane
ring-buffer KV cache or the paged KV pool (DESIGN.md §3, §8).

Ring cache layout (fixed shapes — TPU-friendly):
  GQA:  k, v: (B, C, Hkv, hd); pos: (B, C) absolute positions (-1 empty).
  MLA:  c_kv: (B, C, lora); k_rope: (B, C, rope_dim); pos: (B, C).
C = min(seq_len, window) — sliding windows bound the decode cache.

Paged layout (serving.kvpool): the SAME leaf names with the lane axis
replaced by a global page pool — ``k, v: (P, page, Hkv, hd)``, ``pos:
(P, page)`` — plus a per-lane `PagedKV` handle carrying the page table
and this token's (page, slot) write target.  Page 0 is the reserved
garbage sink: lanes masked out by ``write_mask`` (early-exited or
unoccupied) write their K/V there with position -1, so gathered garbage
is never attended; unused page-table entries also point at page 0.  The
holes a masked write leaves behind are therefore hidden by the SAME
stored-position mask the ring path uses.

The einsum/jnp path is what the multi-pod dry-run lowers (XLA fuses it and
GSPMD shards it); the Pallas flash kernel (repro.kernels.flash_attention)
is the TPU hot-path for prefill, the paged-attention kernel
(repro.kernels.paged_attention, enabled via ``paged_kernel(True)``) the
hot-path for paged decode — both validated against `kernels.ref` in
interpret mode.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import causal_mask, rms_norm, rope, rope_cos_sin
from repro.models.config import AttnConfig
from repro.models.param import ParamDef

__all__ = ["attn_defs", "attn_forward", "attn_decode",
           "attn_prefill_chunk", "init_cache_defs", "PagedKV",
           "PrefillChunk", "paged_kernel"]

# must agree with serving.kvpool.alloc.GARBAGE_PAGE (kept as a literal so
# the model layer never imports the serving layer)
_GARBAGE_PAGE = 0


class PagedKV(NamedTuple):
    """Per-token device view of a lane's paged-KV state (a pytree; the
    host-side planner is serving.kvpool.KVPool)."""

    page_table: jax.Array   # (B, lane_pages) i32, garbage-page padded
    write_page: jax.Array   # (B,) i32 page receiving this token's KV
    write_slot: jax.Array   # (B,) i32 slot within that page


class PrefillChunk(NamedTuple):
    """Per-step device view of the prefill chunks co-scheduled with
    decode (DESIGN.md §9): up to C prompt tokens per admitting lane,
    planned host-side by the scheduler's chunk planner.  All arrays are
    (B, C) / (B,) with idle lanes and ragged tails padded: position -1
    rows are inert, garbage-page destinations swallow their writes."""

    tok: jax.Array          # (B, C) i32 chunk tokens (0 for padding)
    pos: jax.Array          # (B, C) i32 absolute positions (-1 = pad)
    dest_page: jax.Array    # (B, C) i32 pool page per token (garbage =
                            #   prefix-cache hit / padding: no write)
    dest_slot: jax.Array    # (B, C) i32 slot within the page
    start: jax.Array        # (B,) i32 chunk-start position (pool
                            #   history is read strictly below this)
    last_idx: jax.Array     # (B,) i32 row of the chunk's final valid
                            #   token (the readout position when emit)
    emit: jax.Array         # (B,) bool final chunk: emit first token
    active: jax.Array       # (B,) bool lanes prefilling this step


# --------------------------------------------------------------------------
# Parameter definitions
# --------------------------------------------------------------------------

def attn_defs(cfg: AttnConfig, d_model: int) -> dict:
    if cfg.mla is not None:
        m = cfg.mla
        h = cfg.n_heads
        defs = {
            "wq": ParamDef((d_model, h * (m.qk_nope_head_dim
                                          + m.qk_rope_head_dim)),
                           ("embed", "heads")),
            "w_dkv": ParamDef((d_model, m.kv_lora_rank + m.qk_rope_head_dim),
                              ("embed", None)),
            "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones"),
            "w_uk": ParamDef((m.kv_lora_rank, h * m.qk_nope_head_dim),
                             (None, "heads")),
            "w_uv": ParamDef((m.kv_lora_rank, h * m.v_head_dim),
                             (None, "heads")),
            "wo": ParamDef((h * m.v_head_dim, d_model), ("heads", "embed")),
        }
        if m.q_lora_rank:
            defs["w_dq"] = ParamDef((d_model, m.q_lora_rank), ("embed", None))
            defs["q_norm"] = ParamDef((m.q_lora_rank,), (None,), init="ones")
            defs["wq"] = ParamDef(
                (m.q_lora_rank, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
                (None, "heads"))
        return defs

    defs = {
        "wq": ParamDef((d_model, cfg.n_heads * cfg.head_dim),
                       ("embed", "heads")),
        "wk": ParamDef((d_model, cfg.n_kv_heads * cfg.head_dim),
                       ("embed", "kv_heads")),
        "wv": ParamDef((d_model, cfg.n_kv_heads * cfg.head_dim),
                       ("embed", "kv_heads")),
        "wo": ParamDef((cfg.n_heads * cfg.head_dim, d_model),
                       ("heads", "embed")),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((cfg.head_dim,), (None,), init="ones")
        defs["k_norm"] = ParamDef((cfg.head_dim,), (None,), init="ones")
    return defs


def init_cache_defs(cfg: AttnConfig, batch: int, cache_len: int) -> dict:
    """ShapeDtypeStruct-compatible cache spec (used by input_specs).

    Under the `cache_int8` context the K/V (or MLA latent) tensors are
    int8 with per-(position, head) bf16 scales — models.quant."""
    from repro.models.quant import int8_enabled
    i8 = int8_enabled()
    kv_dt = jnp.int8 if i8 else jnp.bfloat16
    if cfg.mla is not None:
        m = cfg.mla
        out = {
            "c_kv": ((batch, cache_len, m.kv_lora_rank), kv_dt),
            "k_rope": ((batch, cache_len, m.qk_rope_head_dim), kv_dt),
            "pos": ((batch, cache_len), jnp.int32),
        }
        if i8:
            out["c_kv_s"] = ((batch, cache_len), jnp.bfloat16)
            out["k_rope_s"] = ((batch, cache_len), jnp.bfloat16)
        return out
    out = {
        "k": ((batch, cache_len, cfg.n_kv_heads, cfg.head_dim), kv_dt),
        "v": ((batch, cache_len, cfg.n_kv_heads, cfg.head_dim), kv_dt),
        "pos": ((batch, cache_len), jnp.int32),
    }
    if i8:
        out["k_s"] = ((batch, cache_len, cfg.n_kv_heads), jnp.bfloat16)
        out["v_s"] = ((batch, cache_len, cfg.n_kv_heads), jnp.bfloat16)
    return out


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------

def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


_CHUNK_THRESHOLD = 16_384  # chunk queries above this seq len
_Q_CHUNK = 2_048

# Prefill attention implementation (perf hillclimb lever, EXPERIMENTS.md
# §Perf): "chunked" = lax.map over query chunks with FULL kv columns
# (paper-faithful baseline); "banded" = per-chunk kv slicing — causal
# chunks only read kv[0 : chunk_end], windowed chunks only the
# [chunk_start - window, chunk_end) band, cutting score traffic ~2x
# (causal) to ~S/(Qc+w) (windowed).
import contextlib
import contextvars

_ATTN_IMPL = contextvars.ContextVar("repro_attn_impl", default="banded")


@contextlib.contextmanager
def attention_impl(name: str):
    assert name in ("chunked", "banded")
    tok = _ATTN_IMPL.set(name)
    try:
        yield
    finally:
        _ATTN_IMPL.reset(tok)


def _sdpa_chunked(q, k, v, q_pos, kv_pos, window, scale):
    """Query-chunked attention: never materializes the (S, S) score matrix
    — the XLA-level analogue of flash attention, used for long prefill
    (the Pallas kernel is the TPU hot path; this keeps the dry-run's
    memory_analysis honest).  q (B,S,H,hd); k,v (B,T,Hkv,*)."""
    if _ATTN_IMPL.get() == "banded":
        return _sdpa_banded(q, k, v, q_pos, kv_pos, window, scale)
    b, s, h, hd = q.shape
    nc = s // _Q_CHUNK
    assert s % _Q_CHUNK == 0, "caller pads to the chunk size"
    qc = q.reshape(b, nc, _Q_CHUNK, h, hd).swapaxes(0, 1)
    pc = q_pos.reshape(b, nc, _Q_CHUNK).swapaxes(0, 1)

    def one(args):
        q_i, p_i = args
        mask = causal_mask(p_i, kv_pos, window)
        return _sdpa(q_i, k, v, mask, scale)

    out = jax.lax.map(one, (qc, pc))
    return out.swapaxes(0, 1).reshape(b, s, h, -1)


_CAUSAL_GROUPS = 4  # causal banding: unroll factor (bounds live buffers)


def _sdpa_banded(q, k, v, q_pos, kv_pos, window, scale):
    """Banded chunked attention (EXPERIMENTS.md §Perf): each query chunk
    reads only the kv it can attend to, with bounded live memory.

    * windowed: constant-size band (window rounded up to a chunk + one
      chunk), gathered with lax.dynamic_slice inside lax.map — buffers are
      reused across chunks, traffic/FLOPs drop ~S/(w+Qc).
    * causal: chunks are processed in _CAUSAL_GROUPS groups; group g's
      chunks run under one lax.map against kv[: group_end] — ~1.6x
      traffic/FLOPs cut at unroll factor 4 (limit 2x), no 16x live set.

    Assumes the standard prefill layout (q_pos == kv_pos, contiguous)."""
    b, s, h, hd = q.shape
    qc = _Q_CHUNK
    nc = s // qc

    if window is not None:
        band = ((window + qc - 1) // qc + 1) * qc      # static band size
        band = min(band, s)
        kp = jnp.pad(k, ((0, 0), (band - qc, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (band - qc, 0), (0, 0), (0, 0)))
        # padded kv position j corresponds to absolute j - (band - qc)
        pad_pos = jnp.pad(kv_pos, ((0, 0), (band - qc, 0)),
                          constant_values=-1)
        qg = q.reshape(b, nc, qc, h, hd).swapaxes(0, 1)
        pg = q_pos.reshape(b, nc, qc).swapaxes(0, 1)
        idx = jnp.arange(nc)

        def one(args):
            q_i, p_i, i = args
            start = i * qc  # band ends at chunk end in padded coords
            k_i = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
            kp_i = jax.lax.dynamic_slice_in_dim(pad_pos, start, band,
                                                axis=1)
            mask = causal_mask(p_i, kp_i, window) & (kp_i >= 0)[:, None, :]
            return _sdpa(q_i, k_i, v_i, mask, scale)

        out = jax.lax.map(one, (qg, pg, idx))
        return out.swapaxes(0, 1).reshape(b, s, h, -1)

    # causal: grouped prefix banding
    groups = min(_CAUSAL_GROUPS, nc)
    assert nc % groups == 0
    per = nc // groups
    outs = []
    for g in range(groups):
        lo, hi = g * per * qc, (g + 1) * per * qc
        qg = q[:, lo:hi].reshape(b, per, qc, h, hd).swapaxes(0, 1)
        pg = q_pos[:, lo:hi].reshape(b, per, qc).swapaxes(0, 1)
        k_g, v_g = k[:, :hi], v[:, :hi]
        kp_g = kv_pos[:, :hi]

        def one(args, k_g=k_g, v_g=v_g, kp_g=kp_g):
            q_i, p_i = args
            mask = causal_mask(p_i, kp_g, None)
            return _sdpa(q_i, k_g, v_g, mask, scale)

        og = jax.lax.map(one, (qg, pg))
        outs.append(og.swapaxes(0, 1).reshape(b, hi - lo, h, -1))
    return jnp.concatenate(outs, axis=1)


def _sdpa(q, k, v, mask, scale):
    """q (B,S,H,hd), k (B,T,Hkv,hd), v (B,T,Hkv,vd) with H = G*Hkv
    (vd may differ from hd, e.g. MLA).  mask (B,S,T) or (S,T)."""
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    g = h // hkv
    q = q.reshape(b, s, hkv, g, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, vd)


def attn_forward(p: dict, x: jax.Array, positions: jax.Array,
                 cfg: AttnConfig, eps: float = 1e-5,
                 use_flash: bool = False):
    """Full self-attention (train / prefill).

    Returns (y, cache_entries) where cache_entries holds what decode needs.
    """
    if cfg.mla is not None:
        return _mla_forward(p, x, positions, cfg, eps)
    b, s, d = x.shape
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm({"scale": p["q_norm"]}, q, eps)
        k = rms_norm({"scale": p["k_norm"]}, k, eps)
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)
    scale = cfg.softmax_scale or 1.0 / math.sqrt(cfg.head_dim)
    if use_flash:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, scale=scale, causal=True,
                                   window=cfg.window)
    elif s >= _CHUNK_THRESHOLD and s % _Q_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, positions, positions, cfg.window, scale)
    else:
        mask = causal_mask(positions, positions, cfg.window)
        out = _sdpa(q, k, v, mask, scale)
    y = out.reshape(b, s, -1) @ p["wo"]
    return y, {"k": k, "v": v}


# Paged-decode attention implementation (DESIGN.md §8): "gather" (the
# default — page-table gather + the same _sdpa as the ring path, what
# XLA lowers everywhere) or the Pallas paged-attention kernel
# (repro.kernels.paged_attention — page indirection inside the grid via
# scalar prefetch; GQA bf16/f32 only, int8 and MLA fall back to gather).
_PAGED_KERNEL = contextvars.ContextVar("repro_paged_kernel", default=False)


@contextlib.contextmanager
def paged_kernel(on: bool = True):
    tok = _PAGED_KERNEL.set(on)
    try:
        yield
    finally:
        _PAGED_KERNEL.reset(tok)


def _gqa_qkv_decode(p: dict, x: jax.Array, pos: jax.Array, cfg: AttnConfig,
                    eps: float):
    """The new token's q/k/v (+ qk-norm + rope), shared by the ring and
    paged decode paths.  x (B,1,D) -> q/k/v (B,1,H*,hd)."""
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm({"scale": p["q_norm"]}, q, eps)
        k = rms_norm({"scale": p["k_norm"]}, k, eps)
    cos, sin = rope_cos_sin(pos[:, None], cfg.head_dim, cfg.rope_theta)
    return rope(q, cos, sin), rope(k, cos, sin), v


def _paged_targets(paged: PagedKV, pos: jax.Array, write_mask):
    """(write_page, write_slot, stored_pos) with masked lanes redirected
    to the garbage page / position -1 — the paged equivalent of the
    engine's per-lane masked ring writes."""
    wp = paged.write_page
    pw = pos.astype(jnp.int32)
    if write_mask is not None:
        wp = jnp.where(write_mask, wp, _GARBAGE_PAGE)
        pw = jnp.where(write_mask, pw, -1)
    return wp, paged.write_slot, pw


def _gqa_decode_paged(p, x, cache, pos, cfg: AttnConfig, eps,
                      paged: PagedKV, write_mask):
    """One-token GQA decode against the paged pool: scatter the new
    token's K/V into the lane's (page, slot) write target, then attend
    over the page-table gather of the pool."""
    b = x.shape[0]
    ps = cache["k"].shape[1]
    q, k, v = _gqa_qkv_decode(p, x, pos, cfg, eps)
    wp, ws, pw = _paged_targets(paged, pos, write_mask)
    new_cache = dict(cache)
    if "k_s" in cache:  # int8 pool path (models.quant)
        from repro.models.quant import dequantize_rows, quantize_rows
        kq, ks = quantize_rows(k[:, 0])
        vq, vs = quantize_rows(v[:, 0])
        new_cache["k"] = cache["k"].at[wp, ws].set(kq)
        new_cache["v"] = cache["v"].at[wp, ws].set(vq)
        new_cache["k_s"] = cache["k_s"].at[wp, ws].set(ks)
        new_cache["v_s"] = cache["v_s"].at[wp, ws].set(vs)
    else:
        new_cache["k"] = cache["k"].at[wp, ws].set(
            k[:, 0].astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[wp, ws].set(
            v[:, 0].astype(cache["v"].dtype))
    new_cache["pos"] = cache["pos"].at[wp, ws].set(pw)

    table = paged.page_table                                  # (B, maxp)
    scale = cfg.softmax_scale or 1.0 / math.sqrt(cfg.head_dim)
    if _PAGED_KERNEL.get() and "k_s" not in cache:
        from repro.kernels import ops as kops
        out = kops.paged_attention(
            q[:, 0], new_cache["k"], new_cache["v"], new_cache["pos"],
            table, pos.astype(jnp.int32), scale=scale, window=cfg.window)
        out = out[:, None]                                    # (B,1,H,hd)
    else:
        if "k_s" in cache:
            from repro.models.quant import dequantize_rows
            k_full = dequantize_rows(new_cache["k"][table],
                                     new_cache["k_s"][table], q.dtype)
            v_full = dequantize_rows(new_cache["v"][table],
                                     new_cache["v_s"][table], q.dtype)
        else:
            k_full = new_cache["k"][table].astype(q.dtype)
            v_full = new_cache["v"][table].astype(q.dtype)
        c = table.shape[1] * ps
        k_full = k_full.reshape(b, c, cfg.n_kv_heads, cfg.head_dim)
        v_full = v_full.reshape(b, c, cfg.n_kv_heads, -1)
        pos_full = new_cache["pos"][table].reshape(b, c)
        mask = causal_mask(pos[:, None], pos_full, cfg.window)
        mask &= pos_full[:, None, :] >= 0
        out = _sdpa(q, k_full, v_full, mask, scale)
    y = out.reshape(b, 1, -1) @ p["wo"]
    return y, new_cache


def attn_prefill_chunk(p: dict, x: jax.Array, cache: dict,
                       cfg: AttnConfig, eps: float, table: jax.Array,
                       chunk: PrefillChunk):
    """One prefill CHUNK against the paged pool (DESIGN.md §9): compute
    the chunk's q/k/v, scatter K/V into the per-token (page, slot)
    targets, then attend over the lane's page-table history (committed
    by earlier chunks — or shared prefix pages, which is why
    prefix-cache hits can skip their chunks entirely) PLUS the chunk's
    own in-flight keys, causally.

    The in-flight self-attention deliberately reads the ACTIVATION-dtype
    k/v (not the pool round-trip): a chunk covering the whole prompt
    then computes exactly what `attn_forward` computes, so the
    stop-the-world admission path stays the bit-reference.  History
    reads are clipped to ``kpos < chunk.start`` so the chunk's own
    just-scattered positions are attended exactly once (in-flight).

    x (B, C, D); table (B, maxp) i32 page table; returns
    (y (B, C, D), new_cache).  MLA segments are not yet chunkable —
    serve them through the stop-the-world admission path.
    """
    if cfg.mla is not None:
        raise NotImplementedError(
            "chunked prefill supports GQA attention only; MLA segments "
            "must admit through the whole-prompt prefill path")
    b, c, _ = x.shape
    ps = cache["k"].shape[1]
    rpos = jnp.maximum(chunk.pos, 0)          # rope of pad rows: masked
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm({"scale": p["q_norm"]}, q, eps)
        k = rms_norm({"scale": p["k_norm"]}, k, eps)
    cos, sin = rope_cos_sin(rpos, cfg.head_dim, cfg.rope_theta)
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)

    # scatter targets: prefix-cache hits / pad rows / inactive lanes are
    # redirected to the garbage sink with stored position -1 (the paged
    # analogue of the engine's masked ring writes)
    live = chunk.active[:, None] & (chunk.pos >= 0) \
        & (chunk.dest_page != _GARBAGE_PAGE)
    dp = jnp.where(live, chunk.dest_page, _GARBAGE_PAGE)
    pw = jnp.where(live, chunk.pos, -1)
    ds = chunk.dest_slot
    new_cache = dict(cache)
    if "k_s" in cache:  # int8 pool path (models.quant)
        from repro.models.quant import dequantize_rows, quantize_rows
        kq, ks = quantize_rows(k)
        vq, vs = quantize_rows(v)
        new_cache["k"] = cache["k"].at[dp, ds].set(kq)
        new_cache["v"] = cache["v"].at[dp, ds].set(vq)
        new_cache["k_s"] = cache["k_s"].at[dp, ds].set(ks)
        new_cache["v_s"] = cache["v_s"].at[dp, ds].set(vs)
    else:
        new_cache["k"] = cache["k"].at[dp, ds].set(
            k.astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[dp, ds].set(
            v.astype(cache["v"].dtype))
    new_cache["pos"] = cache["pos"].at[dp, ds].set(pw)

    scale = cfg.softmax_scale or 1.0 / math.sqrt(cfg.head_dim)
    if _PAGED_KERNEL.get() and "k_s" not in cache:
        from repro.kernels import ops as kops
        out = kops.paged_prefill(
            q, new_cache["k"], new_cache["v"], new_cache["pos"], table,
            chunk.pos, chunk.start, k, v, chunk.pos, scale=scale,
            window=cfg.window)
    else:
        maxp = table.shape[1]
        if "k_s" in cache:
            k_hist = dequantize_rows(new_cache["k"][table],
                                     new_cache["k_s"][table], q.dtype)
            v_hist = dequantize_rows(new_cache["v"][table],
                                     new_cache["v_s"][table], q.dtype)
        else:
            k_hist = new_cache["k"][table].astype(q.dtype)
            v_hist = new_cache["v"][table].astype(q.dtype)
        t = maxp * ps
        k_hist = k_hist.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        v_hist = v_hist.reshape(b, t, cfg.n_kv_heads, -1)
        pos_hist = new_cache["pos"][table].reshape(b, t)
        hist_ok = (pos_hist >= 0) & (pos_hist < chunk.start[:, None])
        k_all = jnp.concatenate([k_hist, k], axis=1)
        v_all = jnp.concatenate([v_hist, v], axis=1)
        pos_all = jnp.concatenate([pos_hist, chunk.pos], axis=1)
        ok_all = jnp.concatenate([hist_ok, chunk.pos >= 0], axis=1)
        mask = causal_mask(chunk.pos, pos_all, cfg.window) \
            & ok_all[:, None, :]
        out = _sdpa(q, k_all, v_all, mask, scale)
    y = out.reshape(b, c, -1) @ p["wo"]
    return y, new_cache


def attn_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                cfg: AttnConfig, eps: float = 1e-5,
                paged: PagedKV | None = None, write_mask=None):
    """One-token decode against the ring-buffer cache, or — when a
    `PagedKV` handle is given — against the paged KV pool.

    Args:
      x: (B, 1, D) current token activations.
      cache: ring {"k","v": (B,C,Hkv,hd), "pos": (B,C)} or paged pool
        {"k","v": (P,page,Hkv,hd), "pos": (P,page)}.
      pos: (B,) absolute position of the new token.
      paged: page table + this token's write target (paged mode only).
      write_mask: (B,) lanes whose write should land (paged mode; masked
        lanes are redirected to the garbage page — ring callers mask via
        the engine's `_mask_lane_writes` instead).

    Returns (y, new_cache).
    """
    if cfg.mla is not None:
        return _mla_decode(p, x, cache, pos, cfg, eps, paged, write_mask)
    if paged is not None:
        return _gqa_decode_paged(p, x, cache, pos, cfg, eps, paged,
                                 write_mask)
    b, _, d = x.shape
    c = cache["k"].shape[1]
    q, k, v = _gqa_qkv_decode(p, x, pos, cfg, eps)

    slot = (pos % c).astype(jnp.int32)                       # ring write
    bidx = jnp.arange(b)
    new_cache = dict(cache)
    if "k_s" in cache:  # int8 cache path (models.quant)
        from repro.models.quant import dequantize_rows, quantize_rows
        kq, ks = quantize_rows(k[:, 0])
        vq, vs = quantize_rows(v[:, 0])
        new_cache["k"] = cache["k"].at[bidx, slot].set(kq)
        new_cache["v"] = cache["v"].at[bidx, slot].set(vq)
        new_cache["k_s"] = cache["k_s"].at[bidx, slot].set(ks)
        new_cache["v_s"] = cache["v_s"].at[bidx, slot].set(vs)
        k_full = dequantize_rows(new_cache["k"], new_cache["k_s"], q.dtype)
        v_full = dequantize_rows(new_cache["v"], new_cache["v_s"], q.dtype)
    else:
        new_cache["k"] = cache["k"].at[bidx, slot].set(
            k[:, 0].astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[bidx, slot].set(
            v[:, 0].astype(cache["v"].dtype))
        k_full = new_cache["k"].astype(q.dtype)
        v_full = new_cache["v"].astype(q.dtype)
    new_pos = cache["pos"].at[bidx, slot].set(pos.astype(jnp.int32))
    new_cache["pos"] = new_pos

    mask = causal_mask(pos[:, None], new_pos, cfg.window)    # (B,1,C)
    mask &= new_pos[:, None, :] >= 0
    scale = cfg.softmax_scale or 1.0 / math.sqrt(cfg.head_dim)
    out = _sdpa(q, k_full, v_full, mask, scale)
    y = out.reshape(b, 1, -1) @ p["wo"]
    return y, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# --------------------------------------------------------------------------

def _mla_q(p, x, cfg: AttnConfig, eps):
    m = cfg.mla
    if m.q_lora_rank:
        cq = rms_norm({"scale": p["q_norm"]}, x @ p["w_dq"], eps)
        q = cq @ p["wq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(*x.shape[:-1], cfg.n_heads,
                  m.qk_nope_head_dim + m.qk_rope_head_dim)
    return q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def _mla_forward(p, x, positions, cfg: AttnConfig, eps):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, eps)
    dkv = x @ p["w_dkv"]
    c_kv = rms_norm({"scale": p["kv_norm"]}, dkv[..., :m.kv_lora_rank], eps)
    k_rope = dkv[..., m.kv_lora_rank:]                       # (B,S,rope)
    cos, sin = rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = rope(q_rope, cos, sin)
    k_rope = rope(k_rope[..., None, :], cos, sin)            # (B,S,1,rope)

    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = cfg.softmax_scale or 1.0 / math.sqrt(
        m.qk_nope_head_dim + m.qk_rope_head_dim)
    if s >= _CHUNK_THRESHOLD and s % _Q_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, positions, positions, cfg.window, scale)
    else:
        mask = causal_mask(positions, positions, cfg.window)
        out = _sdpa(q, k, v, mask, scale)
    y = out.reshape(b, s, -1) @ p["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope[..., 0, :]}


def _mla_decode(p, x, cache, pos, cfg: AttnConfig, eps,
                paged: PagedKV | None = None, write_mask=None):
    """Absorbed-matmul MLA decode: attention runs in the compressed
    kv_lora space — the cache stays (B, C, lora + rope) (ring) or
    (P, page, lora + rope) (paged), which is the whole point of MLA
    (DESIGN.md §4)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, eps)                  # (B,1,H,*)
    dkv = x @ p["w_dkv"]
    c_new = rms_norm({"scale": p["kv_norm"]}, dkv[..., :m.kv_lora_rank], eps)
    k_rope_new = dkv[..., m.kv_lora_rank:]
    cos, sin = rope_cos_sin(pos[:, None], m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = rope(q_rope, cos, sin)
    k_rope_new = rope(k_rope_new[..., None, :], cos, sin)[..., 0, :]

    if paged is not None:
        widx = _paged_targets(paged, pos, write_mask)
    else:
        c = cache["c_kv"].shape[1]
        widx = (jnp.arange(b), (pos % c).astype(jnp.int32),
                pos.astype(jnp.int32))
    wa, wb, pw = widx
    new_cache = dict(cache)
    if "c_kv_s" in cache:  # int8 latent cache (models.quant)
        from repro.models.quant import dequantize_rows, quantize_rows
        cq, cs = quantize_rows(c_new[:, 0])
        rq, rs = quantize_rows(k_rope_new[:, 0])
        new_cache["c_kv"] = cache["c_kv"].at[wa, wb].set(cq)
        new_cache["c_kv_s"] = cache["c_kv_s"].at[wa, wb].set(cs)
        new_cache["k_rope"] = cache["k_rope"].at[wa, wb].set(rq)
        new_cache["k_rope_s"] = cache["k_rope_s"].at[wa, wb].set(rs)
        if paged is None:
            ckv = dequantize_rows(new_cache["c_kv"], new_cache["c_kv_s"])
            krope = dequantize_rows(new_cache["k_rope"],
                                    new_cache["k_rope_s"])
        else:
            ckv = krope = None   # dequantized after the page gather
    else:
        ckv = cache["c_kv"].at[wa, wb].set(
            c_new[:, 0].astype(cache["c_kv"].dtype))
        krope = cache["k_rope"].at[wa, wb].set(
            k_rope_new[:, 0].astype(cache["k_rope"].dtype))
        new_cache["c_kv"] = ckv
        new_cache["k_rope"] = krope
    new_pos = cache["pos"].at[wa, wb].set(pw)
    new_cache["pos"] = new_pos
    if paged is not None:
        # page-table gather back to the per-lane (B, C, ...) layout the
        # absorbed-matmul score path below consumes unchanged; int8
        # pools gather the lane's pages FIRST, then dequantize only
        # those (never the whole pool)
        table = paged.page_table
        c = table.shape[1] * cache["c_kv"].shape[1]
        if "c_kv_s" in cache:
            from repro.models.quant import dequantize_rows as _deq
            ckv = _deq(new_cache["c_kv"][table],
                       new_cache["c_kv_s"][table]).reshape(b, c, -1)
            krope = _deq(new_cache["k_rope"][table],
                         new_cache["k_rope_s"][table]).reshape(b, c, -1)
        else:
            ckv = ckv[table].reshape(b, c, -1)
            krope = krope[table].reshape(b, c, -1)
        new_pos = new_pos[table].reshape(b, c)

    # Absorb W_uk into q: q_c[b,h,r] = sum_n q_nope[b,h,n] W_uk[r, h, n]
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_c = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    scores = jnp.einsum("bhr,btr->bht", q_c, ckv.astype(q_c.dtype))
    scores += jnp.einsum("bhe,bte->bht", q_rope[:, 0],
                         krope.astype(q_rope.dtype))
    scale = cfg.softmax_scale or 1.0 / math.sqrt(
        m.qk_nope_head_dim + m.qk_rope_head_dim)
    mask = causal_mask(pos[:, None], new_pos, cfg.window)[:, 0]  # (B,C)
    mask &= new_pos >= 0
    logits = jnp.where(mask[:, None, :], scores.astype(jnp.float32) * scale,
                       -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx_c = jnp.einsum("bht,btr->bhr", w, ckv.astype(w.dtype))  # (B,H,lora)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", ctx_c, w_uv)
    y = out.reshape(b, 1, h * m.v_head_dim) @ p["wo"]
    return y, new_cache
