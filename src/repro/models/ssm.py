"""Mamba2 (SSD — state-space duality) mixer: chunked scan for train /
prefill, O(1)-state recurrence for decode.

Chunked SSD (arXiv:2405.21060 §6): the sequence is split into chunks of Q
tokens; within a chunk the output is a masked attention-like quadratic
form (the "dual" form — this is the MXU-friendly part the ``ssd_chunk``
Pallas kernel tiles), while chunk-boundary states are propagated by a
linear recurrence (lax.scan over chunks).  Decode carries
(conv_state, ssm_state) explicitly — the cache is O(1) in sequence length,
which is why `long_500k` runs natively on SSM architectures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm
from repro.models.config import SSMConfig
from repro.models.param import ParamDef

__all__ = ["ssm_defs", "ssm_forward", "ssm_decode", "ssm_state_defs",
           "ssd_chunked"]


def _dims(cfg: SSMConfig, d_model: int):
    di = cfg.d_inner(d_model)
    h = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    conv_dim = di + 2 * gn
    return di, h, gn, conv_dim


def ssm_defs(cfg: SSMConfig, d_model: int) -> dict:
    di, h, gn, conv_dim = _dims(cfg, d_model)
    return {
        "in_proj": ParamDef((d_model, 2 * di + 2 * gn + h),
                            ("embed", "heads")),
        "conv_w": ParamDef((cfg.d_conv, conv_dim), (None, "heads"),
                           init="normal", scale=0.1),
        "conv_b": ParamDef((conv_dim,), ("heads",), init="zeros"),
        "a_log": ParamDef((h,), ("heads",), init="ones"),
        "d_skip": ParamDef((h,), ("heads",), init="ones"),
        "dt_bias": ParamDef((h,), ("heads",), init="zeros"),
        "norm": ParamDef((di,), ("heads",), init="ones"),
        "out_proj": ParamDef((di, d_model), ("heads", "embed")),
    }


def ssm_state_defs(cfg: SSMConfig, d_model: int, batch: int) -> dict:
    di, h, gn, conv_dim = _dims(cfg, d_model)
    return {
        "conv": ((batch, cfg.d_conv - 1, conv_dim), jnp.bfloat16),
        "ssm": ((batch, h, cfg.head_dim, cfg.d_state), jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x (B,S,C), w (K,C), b (C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return jax.nn.silu(out + b)


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., Q) -> (..., Q, Q) lower-tri segment sums:
    out[i, j] = sum_{t=j+1..i} a_t for i >= j, -inf otherwise."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, a, bb, cc, chunk: int, *, use_kernel: bool = False):
    """Chunked SSD core.

    Args:
      xh: (B, S, H, P) inputs per head.
      dt: (B, S, H) positive step sizes (already softplus'ed).
      a:  (H,) negative state decay rates.
      bb: (B, S, H, N) input projections (groups already broadcast).
      cc: (B, S, H, N) output projections.
      chunk: chunk length Q (S % Q == 0 after padding by caller).

    Returns: y (B, S, H, P), final_state (B, H, P, N).
    """
    b, s, h, p = xh.shape
    n = bb.shape[-1]
    q = chunk
    nc = s // q
    r = lambda t: t.reshape(b, nc, q, *t.shape[2:])
    xh_, dt_, bb_, cc_ = r(xh), r(dt), r(bb), r(cc)
    da = dt_ * a[None, None, None, :]                    # (B,nc,Q,H)

    if use_kernel:
        from repro.kernels import ops as kops
        y_diag, states = kops.ssd_chunk(xh_, dt_, da, bb_, cc_)
    else:
        seg = _segsum(da.swapaxes(-1, -2))               # (B,nc,H,Q,Q)
        l = jnp.exp(seg)
        scores = jnp.einsum("bcqhn,bckhn->bchqk", cc_, bb_)
        m = scores * l * dt_.swapaxes(-1, -2)[..., None, :]  # decay+step
        y_diag = jnp.einsum("bchqk,bckhp->bcqhp", m, xh_)
        # chunk states: sum_j exp(sum_{t>j} da) dt_j B_j x_j^T
        cum = jnp.cumsum(da, axis=2)
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
        w = decay_to_end * dt_
        states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", w, bb_, xh_)

    # inter-chunk recurrence
    cum = jnp.cumsum(da, axis=2)                         # (B,nc,Q,H)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp                                    # (B,H,P,N),(B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                # emit PREV state

    init = jnp.zeros((b, h, p, n), xh.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)             # (B,nc,H,P,N)

    inner_decay = jnp.exp(cum)                           # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       cc_, prev_states, inner_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_forward(p: dict, x: jax.Array, cfg: SSMConfig,
                eps: float = 1e-5, use_kernel: bool = False):
    """Full-sequence SSD pass.  Returns (y, final_states dict)."""
    b, s, d = x.shape
    di, h, gn, conv_dim = _dims(cfg, d)
    proj = x @ p["in_proj"]
    z, xbc_pre, dt = jnp.split(proj, [di, di + di + 2 * gn], axis=-1)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    xs, bb, cc = jnp.split(xbc, [di, di + gn], axis=-1)
    xh = xs.reshape(b, s, h, cfg.head_dim)
    rep = h // cfg.n_groups
    bb = jnp.repeat(bb.reshape(b, s, cfg.n_groups, cfg.d_state), rep, axis=2)
    cc = jnp.repeat(cc.reshape(b, s, cfg.n_groups, cfg.d_state), rep, axis=2)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32)).astype(x.dtype)

    pad = (-s) % cfg.chunk
    if pad:
        padf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (t.ndim - 2))
        xh, dt, bb, cc = padf(xh), padf(dt), padf(bb), padf(cc)
    y, final = ssd_chunked(xh, dt, a, bb, cc, cfg.chunk,
                           use_kernel=use_kernel)
    y = y[:, :s]
    y = y + xh[:, :s] * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = rms_norm({"scale": p["norm"]}, y * jax.nn.silu(z), eps)
    out = y @ p["out_proj"]
    # decode conv-state = last d_conv-1 PRE-conv xBC rows
    kc = cfg.d_conv - 1
    tail = xbc_pre[:, -kc:, :]
    if tail.shape[1] < kc:
        tail = jnp.pad(tail, ((0, 0), (kc - tail.shape[1], 0), (0, 0)))
    return out, {"conv": tail.astype(jnp.bfloat16),
                 "ssm": final.astype(jnp.float32)}


def ssm_decode(p: dict, x: jax.Array, state: dict, cfg: SSMConfig,
               eps: float = 1e-5):
    """Single-token recurrent step.  x (B,1,D); state {"conv","ssm"}."""
    b, _, d = x.shape
    di, h, gn, conv_dim = _dims(cfg, d)
    proj = x[:, 0] @ p["in_proj"]                        # (B, ...)
    z, xbc, dt = jnp.split(proj, [di, di + di + 2 * gn], axis=-1)
    # conv over the stored window + current token
    win = jnp.concatenate([state["conv"].astype(xbc.dtype),
                           xbc[:, None, :]], axis=1)     # (B, d_conv, C)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    new_conv = win[:, 1:, :].astype(jnp.bfloat16)

    xs, bb, cc = jnp.split(xbc, [di, di + gn], axis=-1)
    xh = xs.reshape(b, h, cfg.head_dim)
    rep = h // cfg.n_groups
    bb = jnp.repeat(bb.reshape(b, cfg.n_groups, cfg.d_state), rep, axis=1)
    cc = jnp.repeat(cc.reshape(b, cfg.n_groups, cfg.d_state), rep, axis=1)
    dt = jax.nn.softplus(dt + p["dt_bias"])              # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    ssm = state["ssm"]                                   # (B,H,P,N) f32
    decay = jnp.exp(dt.astype(jnp.float32) * a[None, :]) # (B,H)
    upd = (dt.astype(jnp.float32)[..., None, None]
           * xh.astype(jnp.float32)[..., :, None]
           * bb.astype(jnp.float32)[..., None, :])       # (B,H,P,N)
    new_ssm = ssm * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm,
                   cc.astype(jnp.float32)).astype(x.dtype)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di)
    y = rms_norm({"scale": p["norm"]}, y * jax.nn.silu(z[:, None, :]), eps)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": new_ssm}
