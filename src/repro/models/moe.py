"""Mixture-of-Experts layer: top-k routing with GROUPED, capacity-bounded,
sort-based dispatch; shared experts (DeepSeek-V2); load-balance + router-z
aux losses.

Why grouped + sort-based (DESIGN.md §3):
  * GShard one-hot dispatch einsums inflate HLO FLOPs by ~E/k and would
    wreck the roofline's useful-compute ratio — we never build them.
  * A single global argsort over B*S*k assignments would force GSPMD to
    emit a distributed sort; instead tokens are routed within GROUPS
    (one group per sequence for full passes, one group for decode).  The
    group axis shards on ("pod","data") so every sort/gather/scatter is
    local to a data shard, and the expert axis of the batched matmuls
    'gecd,edf->gecf' shards on "model" — expert parallelism with zero
    GSPMD surprises.

Pipeline per group:
  router -> top-k -> stable sort by expert -> position-within-expert ->
  capacity drop -> (E, C) token-id buffer -> gather (E, C, D) ->
  per-expert matmuls -> weighted scatter-add back.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.mlp import mlp_defs, mlp_forward
from repro.models.param import ParamDef
from repro.sharding.ctx import constrain_batch

__all__ = ["moe_defs", "moe_forward"]


def moe_defs(cfg: MoEConfig, d_model: int, act: str) -> dict:
    e, f = cfg.num_experts, cfg.d_ff_expert
    defs = {
        "router": ParamDef((d_model, e), ("embed", None), scale=0.1),
        "w_up": ParamDef((e, d_model, f), ("experts", "embed", "mlp"),
                         fan_axis=1),
        "w_down": ParamDef((e, f, d_model), ("experts", "mlp", "embed"),
                           fan_axis=1),
    }
    if act == "swiglu":
        defs["w_gate"] = ParamDef((e, d_model, f),
                                  ("experts", "embed", "mlp"), fan_axis=1)
    if cfg.num_shared > 0:
        shared_ff = cfg.d_ff_shared or cfg.num_shared * f
        defs["shared"] = mlp_defs(d_model, shared_ff, act)
    return defs


def _group_shape(b: int, s: int) -> tuple[int, int]:
    """One routing group per sequence for full passes; a single group for
    decode (S == 1), so routing never crosses data shards on the batch."""
    if s == 1:
        return 1, b
    return b, s


def moe_forward(p: dict, x: jax.Array, cfg: MoEConfig, act: str):
    """x: (B, S, D) -> (y, aux_losses dict)."""
    b, s, d = x.shape
    g, ng = _group_shape(b, s)
    e, k = cfg.num_experts, cfg.top_k
    cap = max(8, min(int(cfg.capacity_factor * k * ng / e), ng * k))
    xg = x.reshape(g, ng, d)

    logits = (xg @ p["router"]).astype(jnp.float32)          # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, assign = jax.lax.top_k(probs, k)              # (G, Ng, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- grouped sort-based dispatch -----------------------------------
    flat_e = assign.reshape(g, ng * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)        # (G, Ng*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # position within expert = rank - start-of-expert (per group)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(
        sorted_e)                                            # (G, E)
    rank = jnp.arange(ng * k, dtype=jnp.int32)[None, :]
    pos = rank - jnp.take_along_axis(starts, sorted_e, axis=-1).astype(
        jnp.int32)
    keep = pos < cap
    tok = (order // k).astype(jnp.int32)                     # source token
    slot_gate = jnp.take_along_axis(gate_vals.reshape(g, ng * k), order,
                                    axis=-1)

    gidx = jnp.broadcast_to(jnp.arange(g)[:, None], (g, ng * k))
    se = jnp.where(keep, sorted_e, e)                        # drop -> OOB
    ps = jnp.where(keep, pos, 0)
    buf_tok = jnp.full((g, e, cap), ng, jnp.int32)           # pad row = ng
    buf_tok = buf_tok.at[gidx, se, ps].set(tok, mode="drop")
    buf_gate = jnp.zeros((g, e, cap), x.dtype)
    buf_gate = buf_gate.at[gidx, se, ps].set(slot_gate.astype(x.dtype),
                                             mode="drop")

    x_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(x_pad[:, :, None, :],
                             buf_tok.reshape(g, -1, 1, 1), axis=1
                             )[:, :, 0, :].reshape(g, e, cap, d)

    # ---- expert-parallel batched matmuls --------------------------------
    # anchor the group dim on the batch mesh axes (other dims replicated;
    # GSPMD otherwise re-gathers G across data inside the expert einsums —
    # explicitly co-sharding the expert dim was tried and REFUTED: Shardy
    # lands on a worse fixed point, wire 5x)
    # (EXPERIMENTS.md §Perf, phi3.5-moe prefill)
    xe = constrain_batch(xe, batch_dim=0)
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * up
    else:
        h = jax.nn.gelu(up)
    h = constrain_batch(h, batch_dim=0)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # (G, E, C, D)
    ye = constrain_batch(ye, batch_dim=0)

    # ---- combine: weighted scatter-add ----------------------------------
    # anchor the scatter OPERAND's group dim — otherwise the expert-partial
    # all-reduce runs on the full unsharded (G, Ng, D) tensor
    ye = ye * buf_gate[..., None]
    y = constrain_batch(jnp.zeros((g, ng + 1, d), x.dtype), batch_dim=0)
    gidx2 = jnp.broadcast_to(jnp.arange(g)[:, None], (g, e * cap))
    y = y.at[gidx2, buf_tok.reshape(g, e * cap)].add(
        ye.reshape(g, e * cap, d), mode="drop")
    y = constrain_batch(y, batch_dim=0)
    y = y[:, :ng].reshape(b, s, d)

    if cfg.num_shared > 0:
        y = y + mlp_forward(p["shared"], x, act)

    # ---- aux losses (GShard load balance + router z) --------------------
    me = probs.mean(axis=(0, 1))                             # (E,)
    one_hot = jax.nn.one_hot(assign, e, dtype=jnp.float32)
    ce = one_hot.sum(axis=(0, 1, 2)) / (g * ng * k)
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"moe_load_balance": cfg.router_aux_weight * lb,
           "moe_router_z": cfg.router_z_weight * z}
    return y, aux
