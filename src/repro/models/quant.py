"""int8 KV-cache quantization (beyond-paper serving feature,
EXPERIMENTS.md §Perf): decode is memory-bound on cache reads, so storing
K/V (or MLA's c_kv latent) as int8 with per-(position, head) scales
halves the dominant traffic term.  Dequantization happens at the
attention consumer (fused on TPU).

Enabled via the `cache_int8` context (dry-run `--variant int8_cache`);
the default bf16 path is untouched.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp

__all__ = ["cache_int8", "int8_enabled", "quantize_rows", "dequantize_rows"]

_INT8 = contextvars.ContextVar("repro_cache_int8", default=False)


@contextlib.contextmanager
def cache_int8(on: bool = True):
    tok = _INT8.set(on)
    try:
        yield
    finally:
        _INT8.reset(tok)


def int8_enabled() -> bool:
    return _INT8.get()


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 over the LAST axis: x (..., d) ->
    (q (..., d) int8, scale (...) bf16)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_rows(q: jax.Array, scale: jax.Array,
                    dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)
