"""Model zoo: composable JAX decoder blocks (GQA/MLA attention, dense &
MoE MLPs, Mamba2 SSD, Hymba hybrid) assembled into early-exit segmented
models — every segment boundary is a T-Tamer node."""

from repro.models.config import (AttnConfig, BlockConfig, MLAConfig,
                                 ModelConfig, MoEConfig, Segment, SSMConfig)
from repro.models import model, param

__all__ = ["AttnConfig", "BlockConfig", "MLAConfig", "ModelConfig",
           "MoEConfig", "Segment", "SSMConfig", "model", "param"]
