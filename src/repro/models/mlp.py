"""Dense MLPs (SwiGLU / GeLU), Megatron column->row parallel layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef

__all__ = ["mlp_defs", "mlp_forward"]


def mlp_defs(d_model: int, d_ff: int, act: str) -> dict:
    defs = {
        "w_up": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }
    if act == "swiglu":
        defs["w_gate"] = ParamDef((d_model, d_ff), ("embed", "mlp"))
    return defs


def mlp_forward(p: dict, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    return h @ p["w_down"]
