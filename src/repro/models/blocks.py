"""Block assembly: pre-norm residual blocks for attn / ssm / hybrid mixers
with dense or MoE MLPs, plus ring-cache construction after prefill."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, moe as moe_lib, mlp as mlp_lib, ssm as ssm_lib
from repro.models.common import rms_norm, rms_norm_def
from repro.models.config import BlockConfig
from repro.models.param import ParamDef

__all__ = ["block_defs", "block_forward", "block_decode",
           "block_prefill_chunk", "cache_defs", "build_ring_cache"]


def block_defs(cfg: BlockConfig, d_model: int) -> dict:
    defs: dict = {"norm1": rms_norm_def(d_model)}
    if cfg.mixer in ("attn", "hybrid"):
        defs["attn"] = attention.attn_defs(cfg.attn, d_model)
    if cfg.mixer in ("ssm", "hybrid"):
        defs["ssm"] = ssm_lib.ssm_defs(cfg.ssm, d_model)
    if cfg.mixer == "hybrid":
        # Hymba: per-branch output norms, fused by averaging (DESIGN.md §4).
        defs["attn_out_norm"] = rms_norm_def(d_model)
        defs["ssm_out_norm"] = rms_norm_def(d_model)
    if cfg.mlp == "dense":
        defs["norm2"] = rms_norm_def(d_model)
        defs["mlp"] = mlp_lib.mlp_defs(d_model, cfg.d_ff, cfg.act)
    elif cfg.mlp == "moe":
        defs["norm2"] = rms_norm_def(d_model)
        defs["moe"] = moe_lib.moe_defs(cfg.moe, d_model, cfg.act)
    return defs


def cache_defs(cfg: BlockConfig, d_model: int, batch: int,
               cache_len: int) -> dict:
    """(shape, dtype) spec tree for one block's decode cache."""
    out: dict = {}
    if cfg.mixer in ("attn", "hybrid"):
        out["attn"] = attention.init_cache_defs(cfg.attn, batch, cache_len)
    if cfg.mixer in ("ssm", "hybrid"):
        out["ssm"] = ssm_lib.ssm_state_defs(cfg.ssm, d_model, batch)
    return out


def _mixer_full(p, xn, positions, cfg: BlockConfig, eps, use_flash,
                use_ssd_kernel):
    """Full-sequence mixer.  Returns (y, cache_entry)."""
    if cfg.mixer == "attn":
        y, kv = attention.attn_forward(p["attn"], xn, positions, cfg.attn,
                                       eps, use_flash)
        return y, {"attn_kv": kv}
    if cfg.mixer == "ssm":
        y, st = ssm_lib.ssm_forward(p["ssm"], xn, cfg.ssm, eps,
                                    use_ssd_kernel)
        return y, {"ssm": st}
    # hybrid: parallel attention + SSD heads on the same normed input
    ya, kv = attention.attn_forward(p["attn"], xn, positions, cfg.attn,
                                    eps, use_flash)
    ys, st = ssm_lib.ssm_forward(p["ssm"], xn, cfg.ssm, eps, use_ssd_kernel)
    y = 0.5 * (rms_norm(p["attn_out_norm"], ya, eps)
               + rms_norm(p["ssm_out_norm"], ys, eps))
    return y, {"attn_kv": kv, "ssm": st}


def block_forward(p: dict, x: jax.Array, positions: jax.Array,
                  cfg: BlockConfig, eps: float = 1e-5,
                  use_flash: bool = False, use_ssd_kernel: bool = False):
    """Train/prefill pass.  Returns (y, cache_entry, aux)."""
    aux: dict = {}
    xn = rms_norm(p["norm1"], x, eps)
    mix, cache = _mixer_full(p, xn, positions, cfg, eps, use_flash,
                             use_ssd_kernel)
    x = x + mix
    if cfg.mlp == "dense":
        x = x + mlp_lib.mlp_forward(p["mlp"], rms_norm(p["norm2"], x, eps),
                                    cfg.act)
    elif cfg.mlp == "moe":
        y, aux = moe_lib.moe_forward(p["moe"], rms_norm(p["norm2"], x, eps),
                                     cfg.moe, cfg.act)
        x = x + y
    return x, cache, aux


def block_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                 cfg: BlockConfig, eps: float = 1e-5,
                 paged=None, write_mask=None):
    """One-token step.  x (B,1,D); returns (y, new_cache, aux).

    ``paged``/``write_mask`` switch the attention cache to the paged KV
    pool (attention.PagedKV); SSM state stays lane-indexed either way —
    its per-lane masking is the engine's job.
    """
    aux: dict = {}
    xn = rms_norm(p["norm1"], x, eps)
    new_cache: dict = {}
    if cfg.mixer == "attn":
        mix, new_cache["attn"] = attention.attn_decode(
            p["attn"], xn, cache["attn"], pos, cfg.attn, eps,
            paged=paged, write_mask=write_mask)
    elif cfg.mixer == "ssm":
        mix, new_cache["ssm"] = ssm_lib.ssm_decode(
            p["ssm"], xn, cache["ssm"], cfg.ssm, eps)
    else:
        ya, new_cache["attn"] = attention.attn_decode(
            p["attn"], xn, cache["attn"], pos, cfg.attn, eps,
            paged=paged, write_mask=write_mask)
        ys, new_cache["ssm"] = ssm_lib.ssm_decode(
            p["ssm"], xn, cache["ssm"], cfg.ssm, eps)
        mix = 0.5 * (rms_norm(p["attn_out_norm"], ya, eps)
                     + rms_norm(p["ssm_out_norm"], ys, eps))
    x = x + mix
    if cfg.mlp == "dense":
        x = x + mlp_lib.mlp_forward(p["mlp"], rms_norm(p["norm2"], x, eps),
                                    cfg.act)
    elif cfg.mlp == "moe":
        y, aux = moe_lib.moe_forward(p["moe"], rms_norm(p["norm2"], x, eps),
                                     cfg.moe, cfg.act)
        x = x + y
    return x, new_cache, aux


def block_prefill_chunk(p: dict, x: jax.Array, cache: dict,
                        cfg: BlockConfig, eps: float, table: jax.Array,
                        chunk) -> tuple[jax.Array, dict]:
    """One prefill CHUNK through a block against the paged pool
    (DESIGN.md §9).  x (B, C, D); returns (y, new_cache).  Only
    attention mixers are chunkable — SSM state is inherently sequential
    over the whole prompt, so ssm/hybrid models admit through the
    stop-the-world prefill path (gated at EngineStepper construction).
    """
    if cfg.mixer != "attn":
        raise NotImplementedError(
            f"chunked prefill supports attention blocks only, not "
            f"{cfg.mixer!r}")
    xn = rms_norm(p["norm1"], x, eps)
    mix, new_attn = attention.attn_prefill_chunk(
        p["attn"], xn, cache["attn"], cfg.attn, eps, table, chunk)
    x = x + mix
    if cfg.mlp == "dense":
        x = x + mlp_lib.mlp_forward(p["mlp"], rms_norm(p["norm2"], x, eps),
                                    cfg.act)
    elif cfg.mlp == "moe":
        y, _ = moe_lib.moe_forward(p["moe"], rms_norm(p["norm2"], x, eps),
                                   cfg.moe, cfg.act)
        x = x + y
    return x, {"attn": new_attn}


def build_ring_cache(cache_entry: dict, positions: jax.Array,
                     cfg: BlockConfig, cache_len: int) -> dict:
    """Convert prefill outputs into the fixed-size ring decode cache.

    Takes the last `cache_len` positions and scatters them at slot
    pos % cache_len — for full prefixes this is the identity layout, for
    windowed attention it reproduces the steady-state ring.
    """
    out: dict = {}
    if "attn_kv" in cache_entry:
        kv = cache_entry["attn_kv"]
        pos_tail = positions[:, -cache_len:]
        slots = (pos_tail % cache_len).astype(jnp.int32)      # (B, C)
        b = pos_tail.shape[0]
        bidx = jnp.arange(b)[:, None]

        def scatter(src):
            tail = src[:, -cache_len:]
            buf = jnp.zeros((b, cache_len) + tail.shape[2:],
                            jnp.bfloat16)
            return buf.at[bidx, slots].set(tail.astype(jnp.bfloat16))

        entry = {k: scatter(v) for k, v in kv.items()}
        from repro.models.quant import int8_enabled, quantize_rows
        if int8_enabled():
            for name in list(entry):
                q, s = quantize_rows(entry[name])
                entry[name] = q
                entry[name + "_s"] = s
        pos_buf = jnp.full((b, cache_len), -1, jnp.int32)
        entry["pos"] = pos_buf.at[bidx, slots].set(
            pos_tail.astype(jnp.int32))
        out["attn"] = entry
    if "ssm" in cache_entry:
        out["ssm"] = cache_entry["ssm"]
    return out
