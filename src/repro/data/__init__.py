"""repro.data"""
