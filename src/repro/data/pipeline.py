"""Synthetic-but-structured data pipeline (offline container: no external
datasets).  Produces deterministic, host-sharded batches for LM training
and the EE calibration traces T-Tamer fits on.

The token stream is a Zipf-distributed Markov source with embedded
"pattern" n-grams of varying difficulty — easy spans are highly
predictable (small models / early ramps nail them), hard spans are
near-uniform.  This gives early-exit workloads a real difficulty spread,
the property the paper's trade-off lives on.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "batches"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    easy_frac: float = 0.6       # fraction of easy (predictable) spans
    span: int = 64               # pattern span length


class SyntheticLM:
    """Deterministic synthetic LM corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # Zipf unigram over vocab + a bank of deterministic patterns.
        ranks = np.arange(1, v + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        n_pat = max(8, min(256, v // 8))
        self.patterns = rng.integers(0, v, size=(n_pat, cfg.span))

    def sample_batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(b, s), p=self.unigram)
        # overwrite easy spans with repeated patterns
        n_spans = s // cfg.span
        for r in range(b):
            for sp in range(n_spans):
                if rng.uniform() < cfg.easy_frac:
                    pat = self.patterns[rng.integers(len(self.patterns))]
                    toks[r, sp * cfg.span:(sp + 1) * cfg.span] = pat
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield ds.sample_batch(step)
        step += 1
