import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Hillclimb diagnostics: lower one (arch x shape) combo and print the
# top-traffic instructions and collectives with their trip-multiplied
# cost (launch/hlo_cost.py cost model).
#
#   PYTHONPATH=src python -m repro.launch.diagnose --arch qwen3-14b \
#       --shape prefill_32k [--multi-pod] [--top 25]

import argparse  # noqa: E402
import re        # noqa: E402

import jax       # noqa: E402

from repro.launch import hlo_cost                      # noqa: E402
from repro.launch.dryrun import build_lowerable        # noqa: E402
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.launch.shapes import SHAPES                 # noqa: E402
from repro.sharding.ctx import activation_sharding     # noqa: E402
from repro.sharding.rules import BASELINE_RULES        # noqa: E402
from repro.launch.dryrun import spec_for               # noqa: E402


def top_traffic(hlo: str, top: int = 25):
    """Approximate per-instruction traffic x trip count."""
    comps = hlo_cost._parse(hlo)
    entry = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo).group(1)

    # compute trip multiplier per computation by walking call graph
    mult = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop()
        m = mult[name]
        for inst in comps.get(name, []):
            sub = hlo_cost._CALL_ATTR_RE.search(inst.rest)
            if not sub or sub.group(1) not in comps:
                continue
            trips = 1.0
            if inst.op == "while":
                mc = hlo_cost._COND_ATTR_RE.search(inst.rest)
                if mc and mc.group(1) in comps:
                    trips = hlo_cost._trip_count(comps[mc.group(1)])
            sname = sub.group(1)
            mult[sname] = max(mult.get(sname, 0.0), m * trips)
            if sname not in seen:
                seen.add(sname)
                order.append(sname)

    rows = []
    for name, instrs in comps.items():
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        table = {i.name: i.result for i in instrs}
        for inst in instrs:
            if inst.op in hlo_cost._NO_TRAFFIC:
                continue
            b = hlo_cost._size(inst.result) + sum(
                hlo_cost._size(table.get(o, ""))
                for o in hlo_cost._operands(inst.rest))
            rows.append((b * m, m, inst.op, inst.result[:60],
                         inst.name[:46]))
    rows.sort(reverse=True)
    return rows[:top]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    fn, fargs = build_lowerable(args.arch, args.shape, mesh, BASELINE_RULES)
    shape = SHAPES[args.shape]
    bspec = spec_for(mesh, BASELINE_RULES, (shape.global_batch,), ("batch",))
    entry = bspec[0] if len(bspec) else None
    axes = entry if isinstance(entry, tuple) else ((entry,) if entry else None)
    with mesh, activation_sharding(axes):
        compiled = jax.jit(fn).lower(*fargs).compile()
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)
    print(f"flops/dev {cost.flops:.3g}  hbm {cost.hbm_bytes / 2**30:.1f} GiB"
          f"  wire {cost.wire_bytes / 2**30:.2f} GiB")
    print(f"{'GiB*trips':>10} {'trips':>6}  op / shape / name")
    for b, m, op, res, name in top_traffic(hlo, args.top):
        print(f"{b / 2**30:10.2f} {m:6.0f}  {op:14s} {res:60s} {name}")


if __name__ == "__main__":
    main()
