"""Assigned input shapes and ShapeDtypeStruct input specs (deliverables
e/f).  No device allocation anywhere — everything here is abstract.

Shapes (assigned):
  train_4k     seq=4096    global_batch=256   -> train_step
  prefill_32k  seq=32768   global_batch=32    -> serve_prefill
  decode_32k   seq=32768   global_batch=128   -> serve_decode (1 token,
                                                 KV cache of seq_len)
  long_500k    seq=524288  global_batch=1     -> serve_decode; requires a
               sub-quadratic mixer — SSM/hybrid/windowed run natively,
               full-attention archs use the sliding-window variant
               (cfg.with_window), DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.sharding.rules import RuleSet, spec_for

__all__ = ["SHAPES", "ShapeSpec", "resolve_config", "input_specs",
           "cache_len_for", "batch_axes"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def resolve_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Apply the long-context sliding-window override when needed."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        assert cfg.long_context_window, \
            f"{cfg.name}: full attention cannot serve 500k decode"
        return cfg.with_window(cfg.long_context_window)
    return cfg


def _min_window(cfg: ModelConfig) -> int | None:
    ws = [s.block.attn.window for s in cfg.segments
          if s.block.mixer in ("attn", "hybrid") and s.block.attn
          and s.block.attn.window]
    return max(ws) if ws else None


def cache_len_for(cfg: ModelConfig, shape: ShapeSpec) -> int:
    w = _min_window(cfg)
    return min(shape.seq_len, w) if w else shape.seq_len


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_axes(mesh: Mesh, rules: RuleSet, batch: int):
    """Mesh axes used for the batch dim (divisibility-gated)."""
    return spec_for(mesh, rules, (batch,), ("batch",))


def _batch_spec(mesh, rules, batch, extra_dims):
    bspec = batch_axes(mesh, rules, batch)
    entry = bspec[0] if len(bspec) else None
    return P(*((entry,) + (None,) * extra_dims))


_CACHE_AXES = {
    # key -> axes chooser given (shape tuple, model-axis size)
    "k": lambda s, m: ("layers", "batch", None, "kv_heads", None)
    if s[3] % m == 0 else ("layers", "batch", "kv_len", None, None),
    "v": lambda s, m: ("layers", "batch", None, "kv_heads", None)
    if s[3] % m == 0 else ("layers", "batch", "kv_len", None, None),
    "pos": lambda s, m: ("layers", "batch", None),
    "c_kv": lambda s, m: ("layers", "batch", "kv_len", None)
    if s[2] % m == 0 else ("layers", "batch", None, None),
    "k_rope": lambda s, m: ("layers", "batch", "kv_len", None)
    if s[2] % m == 0 else ("layers", "batch", None, None),
    "k_s": lambda s, m: ("layers", "batch", None, "kv_heads")
    if s[3] % m == 0 else ("layers", "batch", "kv_len", None),
    "v_s": lambda s, m: ("layers", "batch", None, "kv_heads")
    if s[3] % m == 0 else ("layers", "batch", "kv_len", None),
    "c_kv_s": lambda s, m: ("layers", "batch", "kv_len")
    if s[2] % m == 0 else ("layers", "batch", None),
    "k_rope_s": lambda s, m: ("layers", "batch", "kv_len")
    if s[2] % m == 0 else ("layers", "batch", None),
    "conv": lambda s, m: ("layers", "batch", None, "conv_dim"),
    "ssm": lambda s, m: ("layers", "batch", None, None, None),
}


def cache_specs_sharded(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                        rules: RuleSet):
    """Abstract decode-cache tree with shardings attached."""
    cache_len = cache_len_for(cfg, shape)
    specs = M.cache_specs(cfg, shape.global_batch, cache_len)
    model_size = mesh.shape.get("model", 1)

    def walk(node, key=None):
        if isinstance(node, tuple) and len(node) == 2 \
                and isinstance(node[0], tuple):
            shp, dt = node
            axes = _CACHE_AXES[key](shp, model_size)
            return _sds(shp, dt, mesh, spec_for(mesh, rules, shp, axes))
        return {k: walk(v, k) for k, v in node.items()}

    return [walk(seg) for seg in specs]


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                rules: RuleSet) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b, s = shape.global_batch, shape.seq_len
    out: dict = {}
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "tokens":
            out["tokens"] = _sds((b, s), jnp.int32, mesh,
                                 _batch_spec(mesh, rules, b, 1))
        elif cfg.input_mode == "embeds":
            out["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16, mesh,
                                 _batch_spec(mesh, rules, b, 2))
        else:  # multimodal: stubbed patch embeddings + text tokens
            n_img = cfg.image_tokens
            out["tokens"] = _sds((b, s - n_img), jnp.int32, mesh,
                                 _batch_spec(mesh, rules, b, 1))
            out["image_embeds"] = _sds((b, n_img, cfg.d_model), jnp.bfloat16,
                                       mesh, _batch_spec(mesh, rules, b, 2))
        if shape.kind == "train":
            out["labels"] = _sds((b, s), jnp.int32, mesh,
                                 _batch_spec(mesh, rules, b, 1))
    else:  # decode: ONE new token against a full cache
        if cfg.input_mode in ("tokens", "multimodal"):
            out["tokens"] = _sds((b,), jnp.int32, mesh,
                                 _batch_spec(mesh, rules, b, 0))
        else:
            out["embeds"] = _sds((b, cfg.d_model), jnp.bfloat16, mesh,
                                 _batch_spec(mesh, rules, b, 1))
    return out
