import os

if "XLA_FLAGS" not in os.environ and os.environ.get("REPRO_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FAKE_DEVICES"])

# Production training launcher: builds the mesh, shards params/optimizer
# with the 2-D fsdp x tp rules, and runs the EE multi-ramp training loop
# on the synthetic pipeline.  On this CPU container it is exercised with
# small configs (examples/train_ee.py) or with REPRO_FAKE_DEVICES for
# sharding verification; on a real TPU slice the same entry point drives
# the production mesh.
#
#   PYTHONPATH=src python -m repro.launch.train --arch paper-ee-100m \
#       --steps 200 --batch 8 --seq 256 [--smoke] [--mesh 1x1]

import argparse      # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.data.pipeline import DataConfig, batches      # noqa: E402
from repro.launch.mesh import make_local_mesh            # noqa: E402
from repro.models import model as M                      # noqa: E402
from repro.models.param import materialize               # noqa: E402
from repro.sharding.ctx import activation_sharding       # noqa: E402
from repro.sharding.rules import FSDP_TRAIN_RULES, spec_for  # noqa: E402
from repro.training import checkpoint                    # noqa: E402
from repro.training.loop import make_train_step          # noqa: E402
from repro.training.optimizer import (AdamWConfig,       # noqa: E402
                                      init_opt_state)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-ee-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1x1",
                    help="dataxmodel, e.g. 4x2 (needs that many devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_local_mesh(d, m)
    rules = FSDP_TRAIN_RULES
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1))

    key = jax.random.PRNGKey(0)
    defs = M.model_defs(cfg)
    params = materialize(defs, key)
    opt_state = init_opt_state(params)

    step_fn = make_train_step(cfg, opt_cfg,
                              num_microbatches=args.microbatches)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                          global_batch=args.batch)
    it = batches(data_cfg)

    if mesh.size > 1:
        shard = lambda tree_defs, tree: jax.tree.map(
            lambda df, x: jax.device_put(x, NamedSharding(
                mesh, spec_for(mesh, rules, df.shape, df.axes))),
            tree_defs, tree,
            is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"))
        params = shard(defs, params)
        opt_state = {"mu": shard(defs, opt_state["mu"]),
                     "nu": shard(defs, opt_state["nu"]),
                     "step": opt_state["step"]}
    batch_axes = ("data",) if args.batch % d == 0 and d > 1 else None

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    t0 = time.time()
    with mesh, activation_sharding(batch_axes):
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                mm = {k: float(v) for k, v in metrics.items()}
                print(f"step {step:5d} loss {mm['loss']:.4f} "
                      f"ce_final {mm['ce_final']:.4f} "
                      f"lr {mm['lr']:.2e} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % 100 == 0:
                checkpoint.save(
                    f"{args.ckpt_dir}/state_{step + 1}.ckpt",
                    {"params": params}, step + 1)
    print("done", flush=True)


if __name__ == "__main__":
    main()
