import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run driver (deliverable e).  Lowers + compiles every
# (architecture x input-shape x mesh) combination against the production
# mesh with ShapeDtypeStruct inputs only (no allocation), records
# memory_analysis / cost_analysis / collective schedule to JSON, and
# fails loudly on sharding bugs.  Usage:
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
#       --shape decode_32k [--multi-pod] [--rules baseline] [--force]
#   PYTHONPATH=src python -m repro.launch.dryrun --all
#
# The XLA_FLAGS line above MUST run before any jax import: jax locks the
# device count at first init.  Smoke tests / benches never import this
# module, so they keep seeing 1 device.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED, get_config               # noqa: E402
from repro.launch import flops as flops_lib                  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.shapes import (SHAPES, cache_len_for,      # noqa: E402
                                 cache_specs_sharded, input_specs,
                                 resolve_config)
from repro.models import model as M                          # noqa: E402
from repro.models.param import ParamDef                      # noqa: E402
from repro.sharding.rules import (BASELINE_RULES, FSDP_TRAIN_RULES,  # noqa: E402
                                  RuleSet, spec_for)
from repro.training.loop import make_train_step              # noqa: E402
from repro.training.optimizer import AdamWConfig             # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
          "u64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# wire-byte factor per result byte (ring estimates; DESIGN/EXPERIMENTS note)
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"\b([a-z]+\d+|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective in optimized HLO."""
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            tok = f" {c}("
            tok_start = f" {c}-start("
            if tok in line or tok_start in line:
                lhs = line.split(f"= ", 1)
                shape_part = lhs[1].split(c, 1)[0] if len(lhs) == 2 else line
                out[c]["count"] += 1
                out[c]["bytes"] += _shape_bytes(shape_part)
                break
            if f" {c}-done(" in line:
                break
    out["wire_bytes"] = sum(v["bytes"] * _WIRE_FACTOR[c]
                            for c, v in out.items() if c in _WIRE_FACTOR)
    return out


def abstract_params(defs, mesh, rules: RuleSet, dtype):
    def one(d: ParamDef):
        sh = NamedSharding(mesh, spec_for(mesh, rules, d.shape, d.axes))
        return jax.ShapeDtypeStruct(d.shape, dtype, sharding=sh)
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def build_lowerable(arch: str, shape_name: str, mesh, rules: RuleSet,
                    num_microbatches: int = 16):
    """Returns (fn, abstract_args) ready for jax.jit(fn).lower(*args)."""
    shape = SHAPES[shape_name]
    cfg = resolve_config(get_config(arch), shape)
    defs = M.model_defs(cfg)
    batch = input_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        # f32 master weights + moments need 2-D (fsdp x tp) sharding
        if rules is BASELINE_RULES:
            rules = FSDP_TRAIN_RULES
        params = abstract_params(defs, mesh, rules, jnp.float32)
        opt = {"mu": abstract_params(defs, mesh, rules, jnp.float32),
               "nu": abstract_params(defs, mesh, rules, jnp.float32),
               "step": jax.ShapeDtypeStruct(
                   (), jnp.int32, sharding=NamedSharding(mesh, P()))}
        mb = num_microbatches if shape.global_batch % num_microbatches == 0 \
            else 1
        step = make_train_step(cfg, AdamWConfig(), num_microbatches=mb)
        return step, (params, opt, batch)

    params = abstract_params(defs, mesh, rules, jnp.bfloat16)
    cache_len = cache_len_for(cfg, shape)
    if shape.kind == "prefill":
        def fn(p, b):
            return M.prefill(p, cfg, b, cache_len)
        return fn, (params, batch)

    caches = cache_specs_sharded(cfg, shape, mesh, rules)
    bspec = spec_for(mesh, rules, (shape.global_batch,), ("batch",))
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32,
                               sharding=NamedSharding(mesh, bspec))

    def fn(p, b, c, q):
        return M.decode_step(p, cfg, b, c, q)
    return fn, (params, batch, caches, pos)


def _variant_ctx(variant: str):
    """Perf-variant context managers (EXPERIMENTS.md §Perf).

    baseline       — chunked full-kv attention, scanned decode layers
    banded_attn    — causal/window kv banding in prefill attention
    decode_unroll  — unrolled decode layers (no stacked-weight slicing)
    opt            — all beyond-paper optimizations together
    """
    import contextlib

    from repro.models.attention import attention_impl
    from repro.models.model import decode_unroll

    stack = contextlib.ExitStack()
    if variant == "baseline":
        stack.enter_context(attention_impl("chunked"))
    elif variant == "banded_attn":
        stack.enter_context(attention_impl("banded"))
    elif variant == "decode_unroll":
        stack.enter_context(attention_impl("chunked"))
        stack.enter_context(decode_unroll(True))
    elif variant == "opt":
        stack.enter_context(attention_impl("banded"))
        stack.enter_context(decode_unroll(True))
    elif variant == "int8_cache":
        from repro.models.quant import cache_int8
        stack.enter_context(attention_impl("banded"))
        stack.enter_context(decode_unroll(True))
        stack.enter_context(cache_int8(True))
    elif variant in ("gqa_mesh", "gqa_opt"):
        stack.enter_context(attention_impl(
            "banded" if variant == "gqa_opt" else "chunked"))
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return stack


def run_one(arch: str, shape_name: str, multi_pod: bool,
            rules: RuleSet = BASELINE_RULES, rules_name: str = "baseline",
            force: bool = False, save: bool = True,
            variant: str = "baseline") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = rules_name if variant == "baseline" else f"{rules_name}+{variant}"
    out_path = os.path.join(
        RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}__{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    if variant.startswith("gqa"):
        from repro.sharding.rules import GQA_RULES
        rules = GQA_RULES
        mesh = make_production_mesh(multi_pod=multi_pod, layout="gqa")
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # batch mesh axes for the activation-sharding anchors
    shape = SHAPES[shape_name]
    bspec = spec_for(mesh, rules, (shape.global_batch,), ("batch",))
    entry = bspec[0] if len(bspec) else None
    axes = entry if isinstance(entry, tuple) else (
        (entry,) if entry else None)
    from repro.sharding.ctx import activation_sharding
    # NOTE: 32 microbatches (vs 16) was tried for the train shapes and
    # REFUTED — temp unchanged (the live-set floor is grads + opt state +
    # gathered weights, not per-microbatch activations) while HBM/wire
    # traffic doubled with the extra trips (EXPERIMENTS.md §Perf).
    with _variant_ctx(variant):
        fn, args = build_lowerable(arch, shape_name, mesh, rules)
        with mesh, activation_sharding(axes):
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # Trip-count-aware re-analysis (XLA cost_analysis counts while bodies
    # once — launch/hlo_cost.py docstring).
    from repro.launch import hlo_cost
    cost = hlo_cost.analyze(hlo, pod_stride=256 if multi_pod else None)

    shape = SHAPES[shape_name]
    cfg = resolve_config(get_config(arch), shape)
    useful = flops_lib.model_flops(cfg, kind=shape.kind,
                                   global_batch=shape.global_batch,
                                   seq_len=shape.seq_len)
    n_dev = mesh.size
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "rules": tag, "devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": cost.flops,
        "hbm_bytes_per_device": cost.hbm_bytes,
        "xla_flops_per_device_noloop": xla_cost.get("flops", -1.0),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", -1),
        },
        "collectives": cost.collectives,
        "wire_bytes_per_device": cost.wire_bytes,
        "pod_wire_bytes_per_device": cost.pod_wire_bytes,
        "model_flops": useful,
        "hlo_bytes": len(hlo),
    }
    if save:
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
    temp = result["memory"]["temp_bytes"]
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({rules_name}): "
          f"compile {t_compile:.1f}s, flops/dev {cost.flops:.3g}, "
          f"temp {temp / 2**30:.2f} GiB, "
          f"wire {cost.wire_bytes / 2**30:.3f} GiB", flush=True)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 10 archs x 4 shapes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "banded_attn", "decode_unroll",
                             "opt", "gqa_mesh", "gqa_opt", "int8_cache"])
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, force=args.force,
                            variant=args.variant)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} x {shape} "
                          f"multi_pod={mp}: {e}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all requested combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
