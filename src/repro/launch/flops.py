"""Analytic MODEL_FLOPS for the roofline's useful-compute ratio
(EXPERIMENTS.md §Roofline).

Convention: MODEL_FLOPS = 6*N*D for training and 2*N_active*D for
inference, where N(_active) counts matmul parameters actually touched per
token (MoE: shared + top_k routed experts; embedding lookups excluded,
the unembedding included) and D = tokens processed.  The quadratic
attention term 2*S*ctx per layer per head-dim is added separately so long
-context shapes aren't unfairly penalized in the ratio.
"""

from __future__ import annotations

import math

from repro.models.config import BlockConfig, ModelConfig
from repro.models.param import count_params
from repro.models import model as M

__all__ = ["active_matmul_params", "model_flops"]


def _block_active_params(b: BlockConfig, d: int) -> int:
    n = 0
    if b.mixer in ("attn", "hybrid"):
        a = b.attn
        if a.mla:
            m = a.mla
            qd = a.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            n += d * qd if not m.q_lora_rank else (
                d * m.q_lora_rank + m.q_lora_rank * qd)
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * a.n_heads * (m.qk_nope_head_dim
                                               + m.v_head_dim)
            n += a.n_heads * m.v_head_dim * d
        else:
            n += d * a.n_heads * a.head_dim * 2            # wq, wo
            n += d * a.n_kv_heads * a.head_dim * 2         # wk, wv
    if b.mixer in ("ssm", "hybrid"):
        s = b.ssm
        di = s.d_inner(d)
        gn = s.n_groups * s.d_state
        n += d * (2 * di + 2 * gn + s.n_heads(d))          # in_proj
        n += di * d                                        # out_proj
    if b.mlp == "dense":
        mult = 3 if b.act == "swiglu" else 2
        n += mult * d * b.d_ff
    elif b.mlp == "moe":
        mo = b.moe
        mult = 3 if b.act == "swiglu" else 2
        n += mo.top_k * mult * d * mo.d_ff_expert          # routed (active)
        if mo.num_shared:
            ff = mo.d_ff_shared or mo.num_shared * mo.d_ff_expert
            n += mult * d * ff
        n += d * mo.num_experts                            # router
    return n


def active_matmul_params(cfg: ModelConfig) -> int:
    n = sum(_block_active_params(s.block, cfg.d_model) * s.n_layers
            for s in cfg.segments)
    n += cfg.d_model * cfg.vocab                           # unembed
    return n


def total_params(cfg: ModelConfig) -> int:
    return count_params(M.model_defs(cfg))


def _attn_flops_per_layer(b: BlockConfig, d: int, tokens: int,
                          ctx: int, absorbed: bool = False) -> float:
    """Quadratic attention term: 2 * (qk + av) = 4 * tokens * ctx * h * hd.

    MLA decode runs ABSORBED in the kv_lora latent space (DESIGN.md §4):
    per (token, position) it pays 2*(lora + rope) [scores] + 2*lora
    [context] per head — a deliberate compute-for-memory trade."""
    if b.mixer not in ("attn", "hybrid"):
        return 0.0
    a = b.attn
    eff_ctx = min(ctx, a.window) if a.window else ctx
    if a.mla:
        m = a.mla
        if absorbed:
            hd = 2 * m.kv_lora_rank + m.qk_rope_head_dim
        else:
            hd = m.qk_nope_head_dim + m.qk_rope_head_dim + m.v_head_dim
    else:
        hd = 2 * a.head_dim
    return 2.0 * tokens * eff_ctx * a.n_heads * hd


def model_flops(cfg: ModelConfig, *, kind: str, global_batch: int,
                seq_len: int) -> float:
    """Analytic useful FLOPs for one step of the given shape."""
    n_act = active_matmul_params(cfg)
    if kind == "train":
        tokens = global_batch * seq_len
        base = 6.0 * n_act * tokens
        ctx = seq_len / 2  # average causal context
        mult = 3.0         # fwd + bwd
    elif kind == "prefill":
        tokens = global_batch * seq_len
        base = 2.0 * n_act * tokens
        ctx = seq_len / 2
        mult = 1.0
    elif kind == "decode":
        tokens = global_batch
        base = 2.0 * n_act * tokens
        ctx = seq_len
        mult = 1.0
    else:
        raise ValueError(kind)
    attn = mult * sum(
        _attn_flops_per_layer(s.block, cfg.d_model, tokens, ctx,
                              absorbed=(kind == "decode"))
        * s.n_layers for s in cfg.segments)
    # ramp heads: train computes ramp CE on every token (fwd+bwd); serving
    # paths evaluate ramp confidence on the current/last token only.
    ramp_tokens = tokens if kind == "train" else global_batch
    ramps = (6.0 if kind == "train" else 2.0) \
        * cfg.n_ramps * cfg.d_model * cfg.vocab * ramp_tokens
    return base + attn + ramps
