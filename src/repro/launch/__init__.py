"""repro.launch"""
