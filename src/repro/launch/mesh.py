"""Production mesh construction (deliverable e).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def _mesh(shape, axes):
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=512 before any jax import")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False, layout: str = "2d"):
    """TPU v5e target: 16x16 = 256 chips/pod; 2 pods = 512 chips.

    Axes: "data" shards the batch, "model" shards tensor/expert dims,
    "pod" (multi-pod only) is an outer data axis whose collectives cross
    the inter-pod links.

    layout="gqa" factorizes the model axis 16 -> ("model"=8, "model2"=2)
    so GQA geometries with 8 kv heads shard cleanly: attention uses
    "model" only (no padded heads, no partial-score all-reduces), while
    MLP/vocab dims span both factors (EXPERIMENTS.md §Perf, qwen3-14b).
    """
    if layout == "gqa":
        shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
        axes = (("pod", "data", "model", "model2") if multi_pod
                else ("data", "model", "model2"))
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    return _mesh((data, model), ("data", "model"))
