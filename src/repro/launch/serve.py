"""Serving launcher: loads (or initializes) a checkpoint, calibrates a
`Cascade` from a calibration batch, builds the requested strategy from
the registry, and serves batched greedy generation with per-token early
exit through the segment engine.

  PYTHONPATH=src python -m repro.launch.serve --arch paper-ee-100m \
      --smoke --policy recall_index --lam 0.5 --tokens 32

``--policy`` accepts any online name from ``repro.strategy.available()``
— including the table-backed ``skip_recall`` and ``tree_index``
strategies (§5) that share the line calibration.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategy
from repro.configs import get_config
from repro.models import model as M
from repro.models.param import materialize
from repro.serving.engine import Engine
from repro.training import checkpoint

# aliases kept for muscle memory from the previous CLI
ALIASES = {
    "recall": "recall_index",
    "threshold": "norecall_threshold",
    "none": "always_last",
}
# hindsight-only strategies (online=False in the registry) cannot serve
ONLINE = strategy.available(online_only=True)


def calibrate(params, cfg, key, lam: float, k: int = 24, t: int = 512,
              seq: int = 64, segment_costs=None):
    """DEPRECATED shim — use `strategy.Cascade.calibrate`.

    Returns the legacy (tables, support) pair for one release.
    """
    casc = strategy.Cascade.calibrate(params, cfg, key, lam, k=k, t=t,
                                      seq=seq, segment_costs=segment_costs)
    return casc.solve_line(), casc.support


def build_strategy(name: str, casc: strategy.Cascade, *, threshold: float,
                   patience: int):
    """Registry dispatch with the per-family CLI knobs applied."""
    if name in ("norecall_threshold", "recall_threshold"):
        # thresholds are compared against raw 1-confidence in serving
        return strategy.make(name, casc, threshold=threshold, lam=1.0)
    if name == "norecall_patience":
        return strategy.make(name, casc, patience=patience, lam=1.0)
    if name == "skip_recall":
        # intra-model early exit: skipped segments still pay backbone
        return strategy.make(name, casc, mode="cumulative")
    return strategy.make(name, casc)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-ee-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--policy", default="recall_index",
                    choices=sorted(set(ONLINE) | set(ALIASES)))
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--threshold", type=float, default=0.4)
    ap.add_argument("--patience", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    if args.ckpt:
        state, _ = checkpoint.load(args.ckpt)
        params = jax.tree.map(jnp.asarray, state["params"])
        print(f"loaded checkpoint {args.ckpt}")
    else:
        params = materialize(M.model_defs(cfg), key)
        print("no checkpoint given — serving random init (demo mode)")

    name = ALIASES.get(args.policy, args.policy)
    if strategy.needs_tables(name):
        # table-backed strategies calibrate on real model traces; the
        # line/skip solves are triggered lazily inside make()
        casc = strategy.Cascade.calibrate(params, cfg, key, args.lam,
                                          solve=False)
    else:
        # topology/costs-only strategies skip the calibration prefill
        casc = strategy.Cascade.uniform(cfg.n_ramps + 1, lam=args.lam)
    strat = build_strategy(name, casc, threshold=args.threshold,
                           patience=args.patience)
    if casc.line_tables is not None:
        tables = casc.line_tables
        print(f"calibrated T-Tamer tables: n={tables.n} K={tables.k} "
              f"online-optimal value {float(tables.value):.4f}")
    print(f"strategy: {name} (registry: {', '.join(strategy.available())})")

    engine = Engine(params, cfg, strat, cache_len=args.cache_len)
    prompts = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    t0 = time.time()
    stats = engine.generate(prompts, args.tokens)
    dt = time.time() - t0
    n_seg = len(cfg.segments)
    n_nodes = cfg.n_ramps + 1
    print(f"generated {args.batch}x{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(f"segments: batch-run {stats.segments_run_batch} / "
          f"full {args.tokens * n_seg} per lane-step; "
          f"lane-level saved "
          f"{100 * (1 - stats.segments_run_policy / stats.segments_full):.0f}%")
    print(f"served-node histogram: "
          f"{np.bincount(stats.served_nodes.ravel(), minlength=n_nodes)}")


if __name__ == "__main__":
    main()
