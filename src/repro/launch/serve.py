"""Serving launcher: loads (or initializes) a checkpoint, calibrates a
`Cascade` from a calibration batch, builds the requested strategy from
the registry, and serves through the segment engine — either one batched
generation (default) or a continuous-batching traffic session
(``--server``):

  PYTHONPATH=src python -m repro.launch.serve --arch paper-ee-100m \
      --smoke --policy recall_index --lam 0.5 --tokens 32

  PYTHONPATH=src python -m repro.launch.serve --arch paper-ee-100m \
      --smoke --server --rate 8 --duration 5 --policy recall_index

``--policy`` accepts any online name from ``repro.strategy.available()``
— including the table-backed ``skip_recall`` and ``tree_index``
strategies (§5) that share the line calibration.  ``--server`` replays a
seeded open-loop workload (``--workload poisson|bursty|diurnal``) into
the lane scheduler and reports throughput, latency percentiles, goodput
under ``--slo-ms``, and segments saved (repro.serving.runtime).

``--cascade small:large`` serves a MULTI-MODEL ladder in one process
(repro.serving.cascade, DESIGN.md §10): the strategy's node line spans
every model, escalation chunk-prefills the stream onto deeper models,
and ``--escalate-policy recall`` makes revisiting an earlier model a
page-table re-pin:

  PYTHONPATH=src python -m repro.launch.serve --smoke --server \
      --cascade paper-ee-100m:paper-ee-100m --policy skip_recall \
      --rate 4 --duration 5 --lanes 4 --cascade-lanes 2

``--adaptive`` serves traffic under the CONTROL PLANE (DESIGN.md §11):
``--gears`` names a bank of lambda points, the `GearPlanner` solves
each into a provably-optimal recall strategy and prices its
sustainable rate, and the `AdaptiveController` switches gears from
live telemetry (with ``--recal-interval`` seconds between online
table re-fits — sim steppers only; the engine path gets gear
switching without recalibration):

  PYTHONPATH=src python -m repro.launch.serve --smoke --server \
      --adaptive --gears quality:0.95,balanced:0.92,turbo:0.75 \
      --workload diurnal --rate 8 --duration 10 --recal-interval 2.5

Every ``--server`` mode can be OBSERVED (repro.serving.obs, DESIGN.md
§12): ``--trace-out`` writes a Chrome/Perfetto trace of the request
lifecycle and every per-token decision, ``--metrics-out`` snapshots
the metrics registry the console report renders from,
``--flight-recorder DIR`` arms anomaly post-mortem bundles,
``--regret`` arms the decision-quality regret meter + Pareto frontier
(DESIGN.md §15), and ``--profile-dir`` captures a ``jax.profiler``
trace around the loop.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategy
from repro.configs import get_config
from repro.models import model as M
from repro.models.param import materialize
from repro.serving.engine import Engine
from repro.serving.obs import (FlightRecorder, InvariantLedger,
                               Observability)
from repro.serving.obs.export import (profiler_capture, write_events,
                                      write_trace)
from repro.serving.obs.lossmap import goodput_lossmap
from repro.serving.obs.report import ServeReport, segments_saved_line
from repro.training import checkpoint

# aliases kept for muscle memory from the previous CLI
ALIASES = {
    "recall": "recall_index",
    "threshold": "norecall_threshold",
    "none": "always_last",
}
# hindsight-only strategies (online=False in the registry) cannot serve
ONLINE = strategy.available(online_only=True)


def build_strategy(name: str, casc: strategy.Cascade, *, threshold: float,
                   patience: int, lam: float | None = None):
    """Registry dispatch with the per-family CLI knobs applied.

    ``lam`` is the per-request override the runtime routes through
    `Request.lam`; threshold/patience strategies compare raw
    1-confidence (their lam is pinned to 1.0), so a per-request lam
    there is a contradiction we refuse rather than silently drop.
    """
    if name in ("norecall_threshold", "recall_threshold",
                "norecall_patience"):
        if lam is not None:
            raise ValueError(
                f"{name} serves raw confidences (lam fixed at 1.0); "
                "per-request lam is not supported for this family — "
                "tune --threshold/--patience instead")
        if name == "norecall_patience":
            return strategy.make(name, casc, patience=patience, lam=1.0)
        return strategy.make(name, casc, threshold=threshold, lam=1.0)
    if name == "skip_recall":
        # edge-cost semantics by cascade shape: multi-model ladders pay
        # skip_free-style cross-model edges ("cascade"); a single model
        # pays cumulative backbone for skipped segments
        mode = "cascade" if casc.boundaries is not None else "cumulative"
        if lam is not None:
            return strategy.make(name, casc, mode=mode, lam=lam)
        return strategy.make(name, casc, mode=mode)
    if lam is not None:
        return strategy.make(name, casc, lam=lam)
    return strategy.make(name, casc)


def _build_obs(args, *, policy=None, boundaries=None, casc=None,
               ) -> Observability | None:
    """The observability plane (DESIGN.md §12/§13), built only when
    asked — a ``None`` obs keeps every producer guard dead and the
    serve loop byte-identical to the pre-observability path.

    ``--obs-dir DIR`` is the one-flag bundle: it defaults every sink
    the four separate flags name into DIR (trace.json, events.json,
    metrics.json, flight bundles) and additionally arms the
    `InvariantLedger` (audit contracts + ledger.json); explicit flags
    still win for their own sink.  ``--regret`` arms the decision-
    quality `RegretMeter` against the serve's calibrated `Cascade`
    (DESIGN.md §15) — another pure tracer listener, same discipline
    as the ledger.
    """
    if args.obs_dir:
        os.makedirs(args.obs_dir, exist_ok=True)
        args.trace_out = args.trace_out or \
            os.path.join(args.obs_dir, "trace.json")
        args.metrics_out = args.metrics_out or \
            os.path.join(args.obs_dir, "metrics.json")
        args.flight_recorder = args.flight_recorder or args.obs_dir
    if not (args.trace_out or args.metrics_out or args.flight_recorder
            or args.profile_dir or args.regret):
        return None
    flight = None
    if args.flight_recorder:
        os.makedirs(args.flight_recorder, exist_ok=True)
        flight = FlightRecorder(out_dir=args.flight_recorder)
    ledger = None
    if args.obs_dir:
        ledger = InvariantLedger(policy=policy, boundaries=boundaries,
                                 out_dir=args.obs_dir)
    regret = None
    if args.regret:
        from repro.serving.obs.regret import RegretMeter
        regret = RegretMeter(casc)
    return Observability(flight=flight, ledger=ledger, regret=regret,
                         profile_dir=args.profile_dir)


def _finish_obs(args, obs: Observability | None,
                report: ServeReport, *, faults=None) -> None:
    """Render the report, then the sinks: trace stats fold into the
    report first (so they land in the metrics snapshot too), then the
    Perfetto trace and the registry snapshot, if asked for.  A
    `FaultPlan` the serve ran under is embedded in the trace/events
    artifacts (``faults/v1``) so replay reproduces the chaos."""
    if obs is not None:
        report.add_trace(obs.tracer, obs.flight)
        if obs.ledger is not None:
            report.add_ledger(obs.ledger.report())
        # always rendered, even for an empty or overflowed ring — an
        # explicit zero (or a partial-ring map) over silence, so a
        # bundle consumer never has to guess whether the section was
        # clean or merely missing
        report.add_lossmap(goodput_lossmap(
            obs.tracer.events, slo=args.slo_ms / 1e3))
        if obs.regret is not None:
            # listeners see every emission — a ring overflow does not
            # taint the meter, so the report stays asserted
            report.add_regret(obs.regret.report())
            report.add_pareto(obs.regret.pareto.as_doc())
    report.print()
    if obs is not None and args.trace_out:
        write_trace(obs.tracer, args.trace_out, faults=faults,
                    regret=obs.regret)
        print(f"wrote Perfetto trace to {args.trace_out} "
              "(load in ui.perfetto.dev)")
    if args.metrics_out:
        report.registry.to_json(args.metrics_out)
        print(f"wrote metrics snapshot to {args.metrics_out}")
    if obs is not None and args.obs_dir:
        write_events(obs.tracer, os.path.join(args.obs_dir, "events.json"),
                     faults=faults)
        if obs.ledger is not None:
            with open(os.path.join(args.obs_dir, "ledger.json"), "w") as f:
                json.dump(obs.ledger.report(), f, indent=1, default=float)
        if obs.regret is not None:
            with open(os.path.join(args.obs_dir, "regret.json"), "w") as f:
                json.dump(obs.regret.report(), f, indent=1, default=float)
            with open(os.path.join(args.obs_dir, "pareto.json"), "w") as f:
                json.dump(obs.regret.pareto.as_doc(), f, indent=1,
                          default=float)
        print(f"wrote observability bundle to {args.obs_dir} "
              "(trace + events + metrics + ledger"
              + (" + regret + pareto" if obs.regret is not None else "")
              + ")")
    if obs is not None and obs.flight is not None and obs.flight.bundles:
        print(f"flight recorder: {len(obs.flight.bundles)} anomaly "
              f"bundle(s) in {args.flight_recorder}")


def _fault_plan(args, requests):
    """The fault plane's launch wiring (DESIGN.md §14): load the
    ``--faults`` chaos script and/or draw seeded per-request faults
    from ``--deadline-ms`` / ``--cancel-rate``, then stamp the
    request-borne faults onto the workload.  Returns ``(plan,
    stamped_requests)``; ``(None, requests)`` when no fault flag is
    set, keeping the default serve path byte-identical."""
    from repro.serving.faults import FaultPlan
    plan = None
    if args.faults:
        plan = FaultPlan.load(args.faults)
    if args.cancel_rate or args.deadline_ms is not None:
        gen = FaultPlan.generate(
            requests, seed=args.seed + 7, cancel_rate=args.cancel_rate,
            deadline=(args.deadline_ms / 1e3
                      if args.deadline_ms is not None else None))
        if plan is None:
            plan = gen
        else:
            # a scripted plan wins per rid; flags fill the gaps
            gen.cancel_at.update(plan.cancel_at)
            gen.deadline.update(plan.deadline)
            plan.cancel_at, plan.deadline = gen.cancel_at, gen.deadline
    if plan is not None:
        requests = plan.stamp(requests)
    return plan, requests


def _governor(args, plan):
    """A `DegradeGovernor` when faults are active and not opted out."""
    if plan is None or args.no_governor:
        return None
    from repro.serving.faults import DegradeGovernor
    return DegradeGovernor()


def _set_reclaim(args, *pools) -> None:
    """Arm ``--kv-reclaim`` on every paged pool the stepper built."""
    if args.kv_reclaim is None:
        return
    if not 0.0 < args.kv_reclaim <= 1.0:
        raise SystemExit(f"--kv-reclaim {args.kv_reclaim} outside (0, 1]")
    for pool in pools:
        if pool is not None:
            pool.reclaim_watermark = float(args.kv_reclaim)


def _serve_batch(args, cfg, params, strat) -> None:
    """The original one-shot path: one fixed batch, prefill to done."""
    engine = Engine(params, cfg, strat, cache_len=args.cache_len)
    key = jax.random.PRNGKey(args.seed)
    prompts = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    t0 = time.time()
    stats = engine.generate(prompts, args.tokens)
    dt = time.time() - t0
    n_nodes = cfg.n_ramps + 1
    print(f"generated {args.batch}x{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(segments_saved_line(stats.segments_run_batch,
                              stats.segments_run_policy,
                              steps=args.tokens, n_seg=len(cfg.segments),
                              lane_steps=args.tokens * args.batch))
    print(f"served-node histogram: "
          f"{np.bincount(stats.served_nodes.ravel(), minlength=n_nodes)}")


def _calibrate_multi(cfgs, params_list, key, lam, *, k: int = 16,
                     t: int = 128, seq: int = 32) -> strategy.Cascade:
    """Multi-model calibration: every ladder model prefills the SAME
    random prompts; the concatenated per-node losses become one
    `Cascade` with model boundaries (strategy/cascade.py), per-node
    costs weighted by each model's backbone FLOPs share."""
    toks = jax.random.randint(key, (t, seq), 0, cfgs[0].vocab)
    model_losses, weights = [], []
    for cfg, params in zip(cfgs, params_list):
        _, _, node_losses, _ = M.prefill(params, cfg, {"tokens": toks},
                                         cache_len=seq + 8)
        model_losses.append(np.asarray(node_losses))
        # FLOPs proxy: layers x d_model^2 (dense decode cost order)
        layers = sum(seg.n_layers for seg in cfg.segments)
        weights.append(layers * cfg.d_model ** 2)
    base = weights[0]
    model_costs = [
        (1.0 - lam) * np.full((ls.shape[1],),
                              (w / base) / ls.shape[1])
        for ls, w in zip(model_losses, weights)]
    return strategy.Cascade.from_model_traces(model_losses, model_costs,
                                              k=k, lam=lam, solve=False)


def _serve_cascade(args) -> None:
    """--cascade small:large — a ladder of models in ONE process,
    served as a T-Tamer multi-stage decision process
    (repro.serving.cascade, DESIGN.md §10)."""
    from repro.serving import runtime as rt
    from repro.serving.cascade import CascadeEngineStepper, ModelBank, \
        ModelSpec
    from repro.serving.runtime.workload import WorkloadSpec, make_workload

    arch_names = args.cascade.split(":")
    if len(arch_names) < 2:
        raise SystemExit("--cascade needs at least two ':'-separated "
                         "arch names (e.g. qwen3-4b:qwen3-14b)")
    cfgs = [get_config(a, smoke=args.smoke) for a in arch_names]
    vocabs = {cfg.vocab for cfg in cfgs}
    if len(vocabs) > 1:
        # fail BEFORE the expensive multi-model calibration: JAX clamps
        # out-of-range token ids silently, so a mismatched ladder would
        # burn minutes prefilling garbage before ModelBank errors
        raise SystemExit(
            f"--cascade models must share tokenization (one vocab); "
            f"got {sorted(vocabs)} for {arch_names}")
    key = jax.random.PRNGKey(0)
    params_list = []
    for i, cfg in enumerate(cfgs):
        params_list.append(materialize(M.model_defs(cfg),
                                       jax.random.PRNGKey(i)))
    ladder = " -> ".join(f"{a} ({cfg.n_ramps + 1} nodes)"
                         for a, cfg in zip(arch_names, cfgs))
    print(f"cascade ladder: {ladder} (random init demo — per-model "
          "checkpoints are a ROADMAP item)")

    name = ALIASES.get(args.policy, args.policy)
    if strategy.needs_tables(name):
        casc = _calibrate_multi(cfgs, params_list,
                                jax.random.PRNGKey(args.seed + 1),
                                args.lam)
    else:
        casc = strategy.Cascade.uniform(
            sum(cfg.n_ramps + 1 for cfg in cfgs), lam=args.lam,
            boundaries=tuple(cfg.n_ramps + 1 for cfg in cfgs))

    lanes = [args.lanes] + [args.cascade_lanes] * (len(cfgs) - 1)
    # rung-indexed spec names keep prefix caches isolated even when the
    # same arch appears twice (distinct params = distinct KV bytes)
    bank = ModelBank([
        ModelSpec(f"{i}:{a}", cfg.n_ramps + 1, n_lanes=n, cfg=cfg,
                  params=p)
        for i, (a, cfg, p, n) in enumerate(
            zip(arch_names, cfgs, params_list, lanes))])

    lo = max(1, min(4, args.tokens))
    spec = WorkloadSpec(rate=args.rate, duration=args.duration,
                        prompt_len=args.prompt_len, vocab=cfgs[0].vocab,
                        max_tokens=(lo, args.tokens), seed=args.seed,
                        strategy=name)
    requests = make_workload(args.workload, spec)
    if not requests:
        print("workload produced no arrivals; raise --rate or --duration")
        return

    def make_strategy(sname, lam):
        return build_strategy(sname, casc, threshold=args.threshold,
                              patience=args.patience, lam=lam)

    plan, requests = _fault_plan(args, requests)
    strat_bank, sid_of = rt.build_bank(requests, make_strategy,
                                       (name, None))
    stepper = CascadeEngineStepper(
        bank, strat_bank, cache_len=args.cache_len,
        prompt_len=args.prompt_len, page_size=args.page_size,
        chunk=args.prefill_chunk or 8,
        budgets=([args.prefill_budget] * len(cfgs)
                 if args.prefill_budget else None),
        pages=([args.pages] * len(cfgs) if args.pages else None),
        policy=args.escalate_policy, patience=args.escalate_patience,
        paged_kernel=args.paged_kernel,
        faults=plan, governor=_governor(args, plan))
    _set_reclaim(args, *(st.pool for st in stepper.steppers))
    slo = args.slo_ms / 1e3
    obs = _build_obs(args, policy=args.escalate_policy,
                     boundaries=casc.boundaries, casc=casc)
    server = rt.Server(stepper, rt.LaneScheduler(args.lanes), sid_of,
                       order=args.order, slo=slo, eos=args.eos, obs=obs,
                       enforce_deadlines=bool(plan and plan.deadline))
    print(f"serving {len(requests)} {args.workload} requests "
          f"(rate {args.rate}/s x {args.duration}s) on a "
          f"{'->'.join(arch_names)} cascade "
          f"({'+'.join(str(n) for n in lanes)} lanes), policy {name}, "
          f"escalate-policy {args.escalate_policy} "
          f"(patience {args.escalate_patience}), "
          f"SLO ttft<={args.slo_ms:.0f}ms ...")
    with profiler_capture(args.profile_dir):
        metrics = server.serve(requests)
    cs = stepper.cascade_stats()
    report = ServeReport()
    report.add_runtime(metrics.summary(slo=slo), slo_ms=args.slo_ms)
    report.add_segments(metrics.seg_batch, metrics.seg_policy,
                        steps=metrics.steps, n_seg=bank.n_total,
                        lane_steps=metrics.lane_steps)
    report.add_cascade(cs)
    _finish_obs(args, obs, report, faults=plan)
    if args.json:
        extra = {"policy": name, "rate": args.rate, "lanes": args.lanes,
                 "cascade": args.cascade,
                 "escalate_policy": args.escalate_policy,
                 "cascade_stats": {k: v for k, v in cs.items()
                                   if k != "pools"} | {
                     "pools": {m: dict(p)
                               for m, p in cs["pools"].items()}}}
        metrics.to_json(args.json, slo=slo, extra=extra)
        print(f"wrote metrics JSON to {args.json}")


def parse_gears(text: str):
    """``--gears`` grammar: comma-separated ``name:lam`` pairs (a bare
    ``lam`` gets an auto name), e.g. ``quality:0.95,turbo:0.75``."""
    from repro.serving.control import GearSpec
    specs = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if ":" in part:
            gname, lam = part.split(":", 1)
        else:
            gname, lam = f"g{part}", part
        specs.append(GearSpec(gname.strip(), float(lam)))
    if not specs:
        raise SystemExit(f"--gears {text!r} names no gears")
    return tuple(specs)


def _build_adaptive(args, cfg, params, *, mean_tokens, slo):
    """The --adaptive control plane: calibrate gear traces off the real
    model, solve + price the bank, build the controller.  Capacity is
    priced in the SIM cost model's virtual units (probes per token at
    nominal segment time) — gear ORDER and the relative thresholds are
    what selection runs on."""
    from repro.serving.control import AdaptiveController, GearPlanner
    key = jax.random.PRNGKey(args.seed + 1)
    toks = jax.random.randint(key, (128, 32), 0, cfg.vocab)
    _, _, node_losses, _ = M.prefill(params, cfg, {"tokens": toks},
                                     cache_len=40)
    rows = np.asarray(node_losses, np.float64)
    n = rows.shape[1]
    planner = GearPlanner(rows, np.full(n, 1.0 / n), k=12,
                          seg_time=0.01, overhead=0.002,
                          n_lanes=args.lanes, mean_tokens=mean_tokens)
    gear_bank = planner.plan(parse_gears(args.gears))
    controller = AdaptiveController(
        gear_bank, span=max(2.0, args.duration / 5), slo=slo,
        recal_interval=args.recal_interval, planner=planner)
    print("gear bank (quality-first): " + ", ".join(
        f"{g.name}[slot {g.slot}] lam={g.spec.lam:g} "
        f"work={g.work:.2f} max_rate={g.max_rate:.1f}/s"
        for g in gear_bank))
    return gear_bank, controller


def _serve_traffic(args, cfg, params, casc) -> None:
    """--server: continuous batching over an open-loop workload."""
    from repro.serving import runtime as rt
    from repro.serving.runtime.workload import WorkloadSpec, make_workload

    name = ALIASES.get(args.policy, args.policy)
    lo = max(1, min(4, args.tokens))
    spec = WorkloadSpec(rate=args.rate, duration=args.duration,
                        prompt_len=args.prompt_len, vocab=cfg.vocab,
                        max_tokens=(lo, args.tokens), seed=args.seed,
                        strategy=name)
    requests = make_workload(args.workload, spec)
    if not requests:
        print("workload produced no arrivals; raise --rate or --duration")
        return

    controller = None
    if args.adaptive:
        slo = args.slo_ms / 1e3
        gear_bank, controller = _build_adaptive(
            args, cfg, params, mean_tokens=(lo + args.tokens) / 2,
            slo=slo)
        bank, sid_of = gear_bank.strategies, controller.sid_of
        if args.recal_interval is not None:
            print("note: the engine stepper has no swappable array "
                  "bank — --adaptive serves gear SWITCHING here; "
                  "--recal-interval applies to sim steppers "
                  "(benchmarks.bench_runtime.adaptive_vs_frozen)")
    else:

        def make_strategy(sname, lam):
            return build_strategy(sname, casc, threshold=args.threshold,
                                  patience=args.patience, lam=lam)

        bank, sid_of = rt.build_bank(requests, make_strategy,
                                     (name, None))
    plan, requests = _fault_plan(args, requests)
    stepper = rt.EngineStepper(params, cfg, bank, n_lanes=args.lanes,
                               cache_len=args.cache_len,
                               prompt_len=args.prompt_len,
                               kv=args.kv, page_size=args.page_size,
                               n_pages=args.pages,
                               paged_kernel=args.paged_kernel,
                               prefill_chunk=args.prefill_chunk,
                               prefill_budget=args.prefill_budget)
    if plan is not None:
        # single-model engine: request-borne faults plus page squeezes
        # (the Server reads the plan off the stepper each step)
        stepper.faults = plan
    _set_reclaim(args, stepper.pool)
    slo = args.slo_ms / 1e3
    obs = _build_obs(args, casc=casc)
    server = rt.Server(stepper, rt.LaneScheduler(args.lanes), sid_of,
                       order=args.order, slo=slo, eos=args.eos,
                       controller=controller, obs=obs,
                       enforce_deadlines=bool(plan and plan.deadline))
    kv_desc = args.kv if args.kv == "ring" else (
        f"paged ({stepper.pool.n_pages} pages x {args.page_size} tokens)")
    if args.prefill_chunk:
        kv_desc += (f", chunked prefill ({args.prefill_chunk}-token "
                    f"chunks, {stepper.planner.budget} tokens/step)")
    policy_desc = (f"adaptive gears ({args.gears})" if controller
                   else f"policy {name}")
    print(f"serving {len(requests)} {args.workload} requests "
          f"(rate {args.rate}/s x {args.duration}s) on {args.lanes} lanes, "
          f"{policy_desc}, kv {kv_desc}, "
          f"SLO ttft<={args.slo_ms:.0f}ms ...")
    with profiler_capture(args.profile_dir):
        metrics = server.serve(requests)
    report = ServeReport()
    report.add_runtime(metrics.summary(slo=slo), slo_ms=args.slo_ms)
    if controller is not None:
        report.add_adaptive(controller.stats())
    report.add_segments(metrics.seg_batch, metrics.seg_policy,
                        steps=metrics.steps, n_seg=len(cfg.segments),
                        lane_steps=metrics.lane_steps)
    pool_stats = None
    if stepper.pool is not None:
        pool_stats = stepper.pool.stats()
        report.add_pool(pool_stats)
    if args.prefill_chunk:
        report.add_chunked_prefill(stepper.chunk_stats)
    _finish_obs(args, obs, report, faults=plan)
    if args.json:
        extra = {"policy": name, "rate": args.rate, "lanes": args.lanes,
                 "kv": args.kv, "prefill_chunk": args.prefill_chunk}
        if controller is not None:
            extra["adaptive"] = controller.stats()
        if pool_stats is not None:
            extra["kv_pool"] = pool_stats
        if args.prefill_chunk:
            extra["chunked_prefill"] = stepper.chunk_stats
        metrics.to_json(args.json, slo=slo, extra=extra)
        print(f"wrote metrics JSON to {args.json}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-ee-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--policy", default="recall_index",
                    choices=sorted(set(ONLINE) | set(ALIASES)))
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--threshold", type=float, default=0.4)
    ap.add_argument("--patience", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    # --server traffic mode (repro.serving.runtime)
    ap.add_argument("--server", action="store_true",
                    help="serve an open-loop workload with continuous "
                         "batching instead of one fixed batch")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="mean arrivals/sec")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="arrival window in seconds")
    ap.add_argument("--slo-ms", type=float, default=1000.0,
                    help="TTFT SLO for goodput accounting")
    ap.add_argument("--lanes", type=int, default=None,
                    help="lane count (default: --batch)")
    ap.add_argument("--workload", default="poisson",
                    choices=("poisson", "bursty", "diurnal"))
    ap.add_argument("--order", default="fifo", choices=("fifo", "edf"))
    ap.add_argument("--eos", type=int, default=None,
                    help="token id that ends a stream early (lane is "
                         "recycled immediately)")
    ap.add_argument("--kv", default="ring", choices=("ring", "paged"),
                    help="decode KV memory: per-lane ring caches or the "
                         "paged pool with shared-prefix reuse "
                         "(DESIGN.md §8)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--kv paged)")
    ap.add_argument("--pages", type=int, default=None,
                    help="total pool pages (--kv paged; default: "
                         "lanes x ceil(cache_len/page_size) + 1 — the "
                         "ring-equivalent HBM budget)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="decode through the Pallas paged-attention "
                         "kernel (--kv paged; TPU hot path — on CPU it "
                         "runs in slow interpret mode)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="co-schedule admission prefill with decode in "
                         "chunks of this many prompt tokens instead of "
                         "stop-the-world batch-1 prefill programs "
                         "(--kv paged; DESIGN.md §9).  Also lifts the "
                         "fixed prompt bucket: any prompt that fits a "
                         "lane's pages is admissible")
    ap.add_argument("--cascade", default=None,
                    help="serve a MULTI-MODEL cascade: ':'-separated "
                         "arch names in escalation order (e.g. "
                         "qwen3-4b:qwen3-14b; shared tokenization "
                         "required).  All models live in one process; "
                         "the strategy decides per token which model "
                         "serves (repro.serving.cascade, DESIGN.md "
                         "§10).  Implies --server")
    ap.add_argument("--escalate-policy", default="recall",
                    choices=("recall", "commit"),
                    help="cascade residency policy: 'recall' retains "
                         "the source model (recall = page re-pin; "
                         "deeper rungs released after --escalate-"
                         "patience idle tokens), 'commit' pins the "
                         "stream to the escalated model for good")
    ap.add_argument("--escalate-patience", type=int, default=4,
                    help="recall policy: de-escalate a rung after this "
                         "many consecutive tokens that never probed it")
    ap.add_argument("--cascade-lanes", type=int, default=None,
                    help="decode lanes per deeper cascade rung "
                         "(default: max(1, --lanes // 2))")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prompt tokens prefilled per step across "
                         "all admitting lanes (default: --prefill-"
                         "chunk), split fairly over prompt-length "
                         "buckets")
    ap.add_argument("--adaptive", action="store_true",
                    help="serve under the adaptive control plane "
                         "(DESIGN.md §11): a gear bank of recall "
                         "strategies selected from live load "
                         "telemetry.  Implies --server")
    ap.add_argument("--gears",
                    default="quality:0.95,balanced:0.92,turbo:0.75",
                    help="the --adaptive gear bank: comma-separated "
                         "name:lam pairs (quality-first order is "
                         "derived from solved work, not list order)")
    ap.add_argument("--recal-interval", type=float, default=None,
                    help="seconds of serve time between online table "
                         "re-fits from observed outcomes (--adaptive; "
                         "sim steppers only — the engine path serves "
                         "gear switching without recalibration)")
    ap.add_argument("--json", default=None,
                    help="write runtime metrics JSON here")
    # observability plane (repro.serving.obs, DESIGN.md §12)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace-event JSON of "
                         "the serve here (open in ui.perfetto.dev; "
                         "--server modes only)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot JSON "
                         "here (every number the console report "
                         "shows, as labelled series)")
    ap.add_argument("--flight-recorder", default=None, metavar="DIR",
                    help="arm the anomaly flight recorder: post-mortem "
                         "bundles (triggering request's span history + "
                         "last events + metrics) land in DIR on TTFT-"
                         "SLO breach bursts, page exhaustion, stuck "
                         "escalation waiters, or gear thrash")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="one-flag observability bundle: write the "
                         "Perfetto trace, the lossless obs_trace/v1 "
                         "event log, the metrics snapshot, flight "
                         "bundles, AND the invariant-ledger report "
                         "into DIR (arms the audit ledger; subsumes "
                         "--trace-out/--metrics-out/--flight-recorder, "
                         "which still win for their own sink)")
    ap.add_argument("--regret", action="store_true",
                    help="arm the decision-quality regret meter "
                         "(DESIGN.md §15): per-request regret against "
                         "the offline-optimal walk over the calibrated "
                         "tables, decomposed by cause, plus the "
                         "streaming accuracy-latency Pareto frontier.  "
                         "Report sections always; regret.json + "
                         "pareto.json under --obs-dir; a regret "
                         "counter track in --trace-out")
    ap.add_argument("--profile-dir", default=None,
                    help="jax.profiler logdir captured around the "
                         "serve loop (kernel-level attribution)")
    # fault plane (repro.serving.faults, DESIGN.md §14)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline budget from arrival: "
                         "expired requests are reaped mid-stream "
                         "(pages released, counted timed_out) and "
                         "escalations the deadline cannot afford are "
                         "denied by the degrade governor")
    ap.add_argument("--cancel-rate", type=float, default=0.0,
                    help="seeded per-request probability of a client "
                         "cancellation shortly after arrival (chaos "
                         "input; deterministic in --seed)")
    ap.add_argument("--faults", default=None, metavar="PLAN.json",
                    help="serve under a faults/v1 chaos script "
                         "(FaultPlan.save): scripted cancellations, "
                         "deadlines, rung-stall windows and KV page "
                         "squeezes")
    ap.add_argument("--kv-reclaim", type=float, default=None,
                    metavar="FRAC",
                    help="paged-KV occupancy watermark in (0,1]: above "
                         "it admission pressure clips attention history "
                         "off the longest lanes (sliding-window "
                         "reclamation) instead of refusing admission")
    ap.add_argument("--no-governor", action="store_true",
                    help="serve faults WITHOUT the degrade governor "
                         "(escalations park past their deadlines; the "
                         "chaos baseline the governor is gated against)")
    args = ap.parse_args()
    if args.lanes is None:
        args.lanes = args.batch
    if args.cascade_lanes is None:
        args.cascade_lanes = max(1, args.lanes // 2)
    if args.adaptive:
        args.server = True
        if args.cascade:
            raise SystemExit("--adaptive and --cascade are separate "
                             "serving modes; pick one")

    if args.cascade:
        _serve_cascade(args)
        return

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    if args.ckpt:
        state, _ = checkpoint.load(args.ckpt)
        params = jax.tree.map(jnp.asarray, state["params"])
        print(f"loaded checkpoint {args.ckpt}")
    else:
        params = materialize(M.model_defs(cfg), key)
        print("no checkpoint given — serving random init (demo mode)")

    name = ALIASES.get(args.policy, args.policy)
    if strategy.needs_tables(name):
        # table-backed strategies calibrate on real model traces; the
        # line/skip solves are triggered lazily inside make()
        casc = strategy.Cascade.calibrate(params, cfg, key, args.lam,
                                          solve=False)
    else:
        # topology/costs-only strategies skip the calibration prefill
        casc = strategy.Cascade.uniform(cfg.n_ramps + 1, lam=args.lam)
    strat = build_strategy(name, casc, threshold=args.threshold,
                           patience=args.patience)
    if casc.line_tables is not None:
        tables = casc.line_tables
        print(f"calibrated T-Tamer tables: n={tables.n} K={tables.k} "
              f"online-optimal value {float(tables.value):.4f}")
    print(f"strategy: {name} (registry: {', '.join(strategy.available())})")

    if args.server:
        _serve_traffic(args, cfg, params, casc)
    else:
        if args.kv != "ring":
            print("note: --kv paged applies to --server traffic mode; "
                  "the one-shot batch path always uses ring caches")
        if (args.trace_out or args.metrics_out or args.flight_recorder
                or args.obs_dir or args.regret):
            print("note: --trace-out/--metrics-out/--flight-recorder/"
                  "--obs-dir/--regret observe --server traffic "
                  "sessions; the one-shot batch path has no request "
                  "lifecycle to trace")
        _serve_batch(args, cfg, params, strat)


if __name__ == "__main__":
    main()
