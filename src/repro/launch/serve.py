"""Serving launcher: loads (or initializes) a checkpoint, calibrates a
`Cascade` from a calibration batch, builds the requested strategy from
the registry, and serves through the segment engine — either one batched
generation (default) or a continuous-batching traffic session
(``--server``):

  PYTHONPATH=src python -m repro.launch.serve --arch paper-ee-100m \
      --smoke --policy recall_index --lam 0.5 --tokens 32

  PYTHONPATH=src python -m repro.launch.serve --arch paper-ee-100m \
      --smoke --server --rate 8 --duration 5 --policy recall_index

``--policy`` accepts any online name from ``repro.strategy.available()``
— including the table-backed ``skip_recall`` and ``tree_index``
strategies (§5) that share the line calibration.  ``--server`` replays a
seeded open-loop workload (``--workload poisson|bursty|diurnal``) into
the lane scheduler and reports throughput, latency percentiles, goodput
under ``--slo-ms``, and segments saved (repro.serving.runtime).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import strategy
from repro.configs import get_config
from repro.models import model as M
from repro.models.param import materialize
from repro.serving.engine import Engine
from repro.training import checkpoint

# aliases kept for muscle memory from the previous CLI
ALIASES = {
    "recall": "recall_index",
    "threshold": "norecall_threshold",
    "none": "always_last",
}
# hindsight-only strategies (online=False in the registry) cannot serve
ONLINE = strategy.available(online_only=True)


def build_strategy(name: str, casc: strategy.Cascade, *, threshold: float,
                   patience: int, lam: float | None = None):
    """Registry dispatch with the per-family CLI knobs applied.

    ``lam`` is the per-request override the runtime routes through
    `Request.lam`; threshold/patience strategies compare raw
    1-confidence (their lam is pinned to 1.0), so a per-request lam
    there is a contradiction we refuse rather than silently drop.
    """
    if name in ("norecall_threshold", "recall_threshold",
                "norecall_patience"):
        if lam is not None:
            raise ValueError(
                f"{name} serves raw confidences (lam fixed at 1.0); "
                "per-request lam is not supported for this family — "
                "tune --threshold/--patience instead")
        if name == "norecall_patience":
            return strategy.make(name, casc, patience=patience, lam=1.0)
        return strategy.make(name, casc, threshold=threshold, lam=1.0)
    if name == "skip_recall":
        # intra-model early exit: skipped segments still pay backbone
        if lam is not None:
            return strategy.make(name, casc, mode="cumulative", lam=lam)
        return strategy.make(name, casc, mode="cumulative")
    if lam is not None:
        return strategy.make(name, casc, lam=lam)
    return strategy.make(name, casc)


def _print_segments_saved(seg_batch: int, seg_policy: int, *, steps: int,
                          n_seg: int, lane_steps: int) -> None:
    """One consistent line for both serving modes: each saving is a
    percentage of ITS OWN full-depth reference — batch-level counts
    segment launches (``steps * n_seg``), lane-level counts per-lane
    probes (``lane_steps * n_seg``)."""
    save_b = 100.0 * (1.0 - seg_batch / max(steps * n_seg, 1))
    save_l = 100.0 * (1.0 - seg_policy / max(lane_steps * n_seg, 1))
    print(f"segments saved: batch {save_b:.0f}% "
          f"({seg_batch}/{steps * n_seg} launches) / "
          f"lane {save_l:.0f}% ({seg_policy}/{lane_steps * n_seg} "
          f"per-lane probes)")


def _serve_batch(args, cfg, params, strat) -> None:
    """The original one-shot path: one fixed batch, prefill to done."""
    engine = Engine(params, cfg, strat, cache_len=args.cache_len)
    key = jax.random.PRNGKey(args.seed)
    prompts = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    t0 = time.time()
    stats = engine.generate(prompts, args.tokens)
    dt = time.time() - t0
    n_nodes = cfg.n_ramps + 1
    print(f"generated {args.batch}x{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    _print_segments_saved(stats.segments_run_batch,
                          stats.segments_run_policy,
                          steps=args.tokens, n_seg=len(cfg.segments),
                          lane_steps=args.tokens * args.batch)
    print(f"served-node histogram: "
          f"{np.bincount(stats.served_nodes.ravel(), minlength=n_nodes)}")


def _serve_traffic(args, cfg, params, casc) -> None:
    """--server: continuous batching over an open-loop workload."""
    from repro.serving import runtime as rt
    from repro.serving.runtime.workload import WorkloadSpec, make_workload

    name = ALIASES.get(args.policy, args.policy)
    lo = max(1, min(4, args.tokens))
    spec = WorkloadSpec(rate=args.rate, duration=args.duration,
                        prompt_len=args.prompt_len, vocab=cfg.vocab,
                        max_tokens=(lo, args.tokens), seed=args.seed,
                        strategy=name)
    requests = make_workload(args.workload, spec)
    if not requests:
        print("workload produced no arrivals; raise --rate or --duration")
        return

    def make_strategy(sname, lam):
        return build_strategy(sname, casc, threshold=args.threshold,
                              patience=args.patience, lam=lam)

    bank, sid_of = rt.build_bank(requests, make_strategy, (name, None))
    stepper = rt.EngineStepper(params, cfg, bank, n_lanes=args.lanes,
                               cache_len=args.cache_len,
                               prompt_len=args.prompt_len,
                               kv=args.kv, page_size=args.page_size,
                               n_pages=args.pages,
                               paged_kernel=args.paged_kernel,
                               prefill_chunk=args.prefill_chunk,
                               prefill_budget=args.prefill_budget)
    slo = args.slo_ms / 1e3
    server = rt.Server(stepper, rt.LaneScheduler(args.lanes), sid_of,
                       order=args.order, slo=slo, eos=args.eos)
    kv_desc = args.kv if args.kv == "ring" else (
        f"paged ({stepper.pool.n_pages} pages x {args.page_size} tokens)")
    if args.prefill_chunk:
        kv_desc += (f", chunked prefill ({args.prefill_chunk}-token "
                    f"chunks, {stepper.planner.budget} tokens/step)")
    print(f"serving {len(requests)} {args.workload} requests "
          f"(rate {args.rate}/s x {args.duration}s) on {args.lanes} lanes, "
          f"policy {name}, kv {kv_desc}, "
          f"SLO ttft<={args.slo_ms:.0f}ms ...")
    metrics = server.serve(requests)
    s = metrics.summary(slo=slo)

    def ms(v):
        return "n/a" if v is None else f"{1e3 * v:.0f}ms"

    print(f"completed {s['completed']}/{s['requests']} requests, "
          f"{s['tokens']} tokens in {s['duration']:.2f}s")
    print(f"throughput: {s['throughput_tok_s']:.1f} tok/s "
          f"({s['throughput_req_s']:.2f} req/s)")
    print(f"latency: ttft p50 {ms(s['ttft']['p50'])} "
          f"p95 {ms(s['ttft']['p95'])} p99 {ms(s['ttft']['p99'])}; "
          f"token p50 {ms(s['token_latency']['p50'])} "
          f"p95 {ms(s['token_latency']['p95'])} "
          f"p99 {ms(s['token_latency']['p99'])}")
    att = s["slo_attainment"]
    print(f"goodput (ttft<={args.slo_ms:.0f}ms): "
          f"{s['goodput_tok_s']:.1f} tok/s "
          f"(attainment {100 * att:.0f}%)" if att is not None else
          "goodput: n/a")
    _print_segments_saved(metrics.seg_batch, metrics.seg_policy,
                          steps=metrics.steps, n_seg=len(cfg.segments),
                          lane_steps=metrics.lane_steps)
    pool_stats = None
    if stepper.pool is not None:
        pool_stats = stepper.pool.stats()
        print(f"kv pool: peak {pool_stats['pages_peak']}/"
              f"{pool_stats['n_pages'] - 1} pages, "
              f"prefix hit rate {100 * pool_stats['prefix_hit_rate']:.0f}% "
              f"({pool_stats['shared_tokens']} shared tokens), "
              f"{pool_stats['cow_splits']} COW splits, "
              f"{pool_stats['evictions']} evictions")
    if args.prefill_chunk:
        cs = stepper.chunk_stats
        total = cs["tokens_computed"] + cs["tokens_skipped"]
        print(f"chunked prefill: {cs['tokens_computed']} prompt tokens "
              f"computed over {cs['chunk_steps']} co-scheduled chunk "
              f"steps, {cs['tokens_skipped']}/{max(total, 1)} skipped "
              f"via prefix cache ({cs['prefills']} admissions)")
    if args.json:
        extra = {"policy": name, "rate": args.rate, "lanes": args.lanes,
                 "kv": args.kv, "prefill_chunk": args.prefill_chunk}
        if pool_stats is not None:
            extra["kv_pool"] = pool_stats
        if args.prefill_chunk:
            extra["chunked_prefill"] = stepper.chunk_stats
        metrics.to_json(args.json, slo=slo, extra=extra)
        print(f"wrote metrics JSON to {args.json}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-ee-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--policy", default="recall_index",
                    choices=sorted(set(ONLINE) | set(ALIASES)))
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--threshold", type=float, default=0.4)
    ap.add_argument("--patience", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    # --server traffic mode (repro.serving.runtime)
    ap.add_argument("--server", action="store_true",
                    help="serve an open-loop workload with continuous "
                         "batching instead of one fixed batch")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="mean arrivals/sec")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="arrival window in seconds")
    ap.add_argument("--slo-ms", type=float, default=1000.0,
                    help="TTFT SLO for goodput accounting")
    ap.add_argument("--lanes", type=int, default=None,
                    help="lane count (default: --batch)")
    ap.add_argument("--workload", default="poisson",
                    choices=("poisson", "bursty", "diurnal"))
    ap.add_argument("--order", default="fifo", choices=("fifo", "edf"))
    ap.add_argument("--eos", type=int, default=None,
                    help="token id that ends a stream early (lane is "
                         "recycled immediately)")
    ap.add_argument("--kv", default="ring", choices=("ring", "paged"),
                    help="decode KV memory: per-lane ring caches or the "
                         "paged pool with shared-prefix reuse "
                         "(DESIGN.md §8)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--kv paged)")
    ap.add_argument("--pages", type=int, default=None,
                    help="total pool pages (--kv paged; default: "
                         "lanes x ceil(cache_len/page_size) + 1 — the "
                         "ring-equivalent HBM budget)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="decode through the Pallas paged-attention "
                         "kernel (--kv paged; TPU hot path — on CPU it "
                         "runs in slow interpret mode)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="co-schedule admission prefill with decode in "
                         "chunks of this many prompt tokens instead of "
                         "stop-the-world batch-1 prefill programs "
                         "(--kv paged; DESIGN.md §9).  Also lifts the "
                         "fixed prompt bucket: any prompt that fits a "
                         "lane's pages is admissible")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prompt tokens prefilled per step across "
                         "all admitting lanes (default: --prefill-"
                         "chunk), split fairly over prompt-length "
                         "buckets")
    ap.add_argument("--json", default=None,
                    help="write runtime metrics JSON here")
    args = ap.parse_args()
    if args.lanes is None:
        args.lanes = args.batch

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    if args.ckpt:
        state, _ = checkpoint.load(args.ckpt)
        params = jax.tree.map(jnp.asarray, state["params"])
        print(f"loaded checkpoint {args.ckpt}")
    else:
        params = materialize(M.model_defs(cfg), key)
        print("no checkpoint given — serving random init (demo mode)")

    name = ALIASES.get(args.policy, args.policy)
    if strategy.needs_tables(name):
        # table-backed strategies calibrate on real model traces; the
        # line/skip solves are triggered lazily inside make()
        casc = strategy.Cascade.calibrate(params, cfg, key, args.lam,
                                          solve=False)
    else:
        # topology/costs-only strategies skip the calibration prefill
        casc = strategy.Cascade.uniform(cfg.n_ramps + 1, lam=args.lam)
    strat = build_strategy(name, casc, threshold=args.threshold,
                           patience=args.patience)
    if casc.line_tables is not None:
        tables = casc.line_tables
        print(f"calibrated T-Tamer tables: n={tables.n} K={tables.k} "
              f"online-optimal value {float(tables.value):.4f}")
    print(f"strategy: {name} (registry: {', '.join(strategy.available())})")

    if args.server:
        _serve_traffic(args, cfg, params, casc)
    else:
        if args.kv != "ring":
            print("note: --kv paged applies to --server traffic mode; "
                  "the one-shot batch path always uses ring caches")
        _serve_batch(args, cfg, params, strat)


if __name__ == "__main__":
    main()
