"""Serving launcher: loads (or initializes) a checkpoint, calibrates the
T-Tamer tables from a calibration batch, and serves batched greedy
generation with per-token early exit through the segment engine.

  PYTHONPATH=src python -m repro.launch.serve --arch paper-ee-100m \
      --smoke --policy recall --lam 0.5 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.line_dp import solve_line
from repro.core.markov import estimate_chain
from repro.core.support import build_support, quantize
from repro.models import model as M
from repro.models.param import materialize
from repro.serving.engine import Engine, RecallIndexPolicy, ThresholdPolicy
from repro.training import checkpoint


def calibrate(params, cfg, key, lam: float, k: int = 24, t: int = 512,
              seq: int = 64, segment_costs=None):
    """Fit support + Markov chain + if-stop tables from model traces."""
    toks = jax.random.randint(key, (t, seq), 0, cfg.vocab)
    _, _, node_losses, _ = M.prefill(params, cfg, {"tokens": toks},
                                     cache_len=seq + 8)
    scaled = lam * np.asarray(node_losses)
    support = build_support(scaled, k)
    bins = quantize(support, jnp.asarray(scaled))
    chain = estimate_chain(bins, k)
    n = node_losses.shape[1]
    if segment_costs is None:
        segment_costs = np.full((n,), 1.0 / n)
    costs = jnp.maximum(jnp.asarray(
        (1.0 - lam) * segment_costs, jnp.float32), 1e-6)
    return solve_line(chain, costs, support), support


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-ee-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--policy", default="recall",
                    choices=["recall", "threshold", "none"])
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--threshold", type=float, default=0.4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    if args.ckpt:
        state, _ = checkpoint.load(args.ckpt)
        params = jax.tree.map(jnp.asarray, state["params"])
        print(f"loaded checkpoint {args.ckpt}")
    else:
        params = materialize(M.model_defs(cfg), key)
        print("no checkpoint given — serving random init (demo mode)")

    n_nodes = cfg.n_ramps + 1
    if args.policy == "recall":
        tables, support = calibrate(params, cfg, key, args.lam)
        policy = RecallIndexPolicy(tables, support, args.lam)
        print(f"calibrated T-Tamer tables: n={tables.n} K={tables.k} "
              f"online-optimal value {float(tables.value):.4f}")
    elif args.policy == "threshold":
        policy = ThresholdPolicy(n_nodes, args.threshold)
    else:
        policy = ThresholdPolicy(n_nodes, -1.0)  # never exits early

    engine = Engine(params, cfg, policy, cache_len=args.cache_len)
    prompts = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    t0 = time.time()
    stats = engine.generate(prompts, args.tokens)
    dt = time.time() - t0
    n_seg = len(cfg.segments)
    print(f"generated {args.batch}x{args.tokens} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(f"segments: batch-run {stats.segments_run_batch} / "
          f"full {args.tokens * n_seg} per lane-step; "
          f"lane-level saved "
          f"{100 * (1 - stats.segments_run_policy / stats.segments_full):.0f}%")
    print(f"served-node histogram: "
          f"{np.bincount(stats.served_nodes.ravel(), minlength=n_nodes)}")


if __name__ == "__main__":
    main()
