"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE —
with scan-over-layers and microbatch accumulation that undercounts FLOPs,
HBM bytes and collective bytes by 1-2 orders of magnitude (verified:
scan(10 matmuls) reports 1 matmul of FLOPs).  This module re-derives the
three roofline terms by walking the optimized HLO:

  * computations are parsed into instruction lists; a per-computation
    symbol table (name -> result shape) resolves operand shapes,
  * ``while`` trip counts are recovered from the loop condition (largest
    integer constant — jax scans compare iv < N counting from 0),
  * ``fusion``/``while``/``call`` costs recurse into their called
    computations, multiplied by trip count,
  * FLOPs: dot = 2 * prod(result) * prod(lhs contracting dims); conv
    = 2 * prod(result) * prod(window) (depthwise approx); other
    arithmetic ops = 1 flop / output element; pure data movement
    (slice/copy/transpose/dus/...) contributes bytes, not flops,
  * HBM bytes: for every top-level (non-fused-internal) instruction,
    operand bytes + result bytes — the perfect-fusion traffic model,
  * collectives: result bytes x ring factor x trip multiplier, split by
    kind; the pod-crossing subset is identified from replica groups.

This is the "profile" the §Perf hillclimb iterates on (no real TPU in the
container — DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze", "HloCost"]

_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "bf16": 2,
          "f16": 2, "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4,
          "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
          "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(
    r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z][\w\[\]{},]*)\s+"
    r"([\w\-]+)\((.*)$")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_NO_TRAFFIC = {"parameter", "tuple", "get-tuple-element", "bitcast",
               "constant", "after-all", "partition-id", "replica-id",
               "opt-barrier", "optimization-barrier"}
# data movement: bytes yes, flops no
_NO_FLOPS = {"copy", "transpose", "reshape", "broadcast", "slice",
             "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
             "gather", "scatter", "iota", "convert", "reverse", "rng",
             "rng-bit-generator", "copy-start", "copy-done", "send", "recv",
             "custom-call", "while", "conditional", "call", "fusion",
             "reduce", "sort"} | _NO_TRAFFIC


def _size(shape_text: str, elems: bool = False) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n if elems else n * _BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result: str
    op: str
    rest: str


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collectives: dict
    wire_bytes: float
    pod_wire_bytes: float


def _parse(hlo: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur = None
    for line in hlo.splitlines():
        hm = _HEADER_RE.match(line)
        if hm:
            cur = []
            comps[hm.group(1)] = cur
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(Instr(*m.groups()))
    return comps


def _operands(rest: str) -> list[str]:
    """Operand instruction names = %refs inside the first (...) group."""
    depth = 0
    out = []
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                out.append(rest[:i])
                break
    head = out[0] if out else rest
    return _OPERAND_RE.findall(head)


def _trip_count(cond_instrs: list[Instr]) -> int:
    best = 1
    for i in cond_instrs:
        if i.op == "constant":
            m = re.search(r"^\s*(\d+)\s*\)", i.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(inst: Instr, table: dict[str, str]) -> float:
    out = _size(inst.result, elems=True)
    ops = _operands(inst.rest)
    k = 1
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
    if mdims and ops:
        lhs_shape = table.get(ops[0], "")
        m = _SHAPE_RE.search(lhs_shape)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d]
            for ci in mdims.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out * k


def _conv_flops(inst: Instr) -> float:
    out = _size(inst.result, elems=True)
    mwin = re.search(r"window=\{size=([0-9x]+)", inst.rest)
    k = 1
    if mwin:
        for d in mwin.group(1).split("x"):
            k *= int(d)
    return 2.0 * out * k


def _crosses_pod(rest: str, pod_stride: int) -> bool:
    m = re.search(r"replica_groups=\{\{([^=]*?)\}\}", rest)
    if not m:
        return True
    for grp in m.group(1).split("},{"):
        ids = [int(x) for x in grp.split(",") if x.strip().isdigit()]
        if ids and (min(ids) < pod_stride <= max(ids)):
            return True
    return False


def analyze(hlo: str, entry: str | None = None,
            pod_stride: int | None = None) -> HloCost:
    comps = _parse(hlo)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1).rstrip() if m else next(iter(comps))

    memo: dict[str, tuple] = {}

    def comp_cost(name: str, fused: bool) -> tuple:
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, {}, 0.0, 0.0)
        table = {i.name: i.result for i in comps.get(name, [])}
        flops = hbm = wire = pod_wire = 0.0
        colls: dict[str, dict] = {}
        for inst in comps.get(name, []):
            base = inst.op.replace("-start", "")
            if base in COLLECTIVES and not inst.op.endswith("-done"):
                b = _size(inst.result)
                c = colls.setdefault(base, {"count": 0, "bytes": 0.0})
                c["count"] += 1
                c["bytes"] += b
                w = b * _WIRE_FACTOR[base]
                wire += w
                if pod_stride and _crosses_pod(inst.rest, pod_stride):
                    pod_wire += w
                if not fused:
                    hbm += b + sum(_size(table.get(o, ""))
                                   for o in _operands(inst.rest))
                continue

            trips = 1.0
            sub = None
            if inst.op == "while":
                mb = _CALL_ATTR_RE.search(inst.rest)
                mc = _COND_ATTR_RE.search(inst.rest)
                if mb:
                    sub = mb.group(1)
                if mc and mc.group(1) in comps:
                    trips = float(_trip_count(comps[mc.group(1)]))
            elif inst.op in ("fusion", "call", "conditional", "map",
                             "reduce", "reduce-window", "scatter", "sort",
                             "reduce-scatter", "custom-call",
                             "select-and-scatter"):
                mb = _CALL_ATTR_RE.search(inst.rest)
                if mb and mb.group(1) in comps:
                    sub = mb.group(1)

            if inst.op == "dot":
                flops += _dot_flops(inst, table)
            elif inst.op == "convolution":
                flops += _conv_flops(inst)
            elif inst.op not in _NO_FLOPS:
                flops += _size(inst.result, elems=True)

            if sub is not None:
                sub_fused = inst.op in ("fusion", "map", "reduce",
                                        "reduce-window", "scatter", "sort",
                                        "select-and-scatter", "custom-call")
                sf, sh, sc, sw, spw = comp_cost(sub, sub_fused or fused)
                flops += trips * sf
                if not sub_fused:
                    hbm += trips * sh
                wire += trips * sw
                pod_wire += trips * spw
                for k, v in sc.items():
                    c = colls.setdefault(k, {"count": 0, "bytes": 0.0})
                    c["count"] += int(trips * v["count"])
                    c["bytes"] += trips * v["bytes"]

            if not fused and inst.op not in _NO_TRAFFIC:
                hbm += _size(inst.result) + sum(
                    _size(table.get(o, "")) for o in _operands(inst.rest))
        memo[key] = (flops, hbm, colls, wire, pod_wire)
        return memo[key]

    f, h, c, w, pw = comp_cost(entry, False)
    return HloCost(flops=f, hbm_bytes=h, collectives=c, wire_bytes=w,
                   pod_wire_bytes=pw)
