"""starcoder2-3b [dense] — 30L d_model=3072, 24H GQA kv=2, d_ff=12288,
vocab=49152, RoPE + native sliding window 4096.  [arXiv:2402.19173]"""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "starcoder2-3b"


def full_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID, n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        head_dim=128, d_ff=12_288, vocab=49_152, n_segments=6,
        window=4096, act="gelu", rope_theta=1_000_000.0, tie=True)


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
        head_dim=32, d_ff=256, vocab=512, n_segments=2, window=64,
        act="gelu")
