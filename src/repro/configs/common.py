"""Helpers shared by the architecture configs."""

from __future__ import annotations

from repro.models.config import (AttnConfig, BlockConfig, ModelConfig,
                                 Segment)

__all__ = ["dense_decoder", "split_segments"]


def split_segments(n_layers: int, n_segments: int) -> list[int]:
    """Split n_layers into n_segments near-equal scanned stacks."""
    base, rem = divmod(n_layers, n_segments)
    return [base + (1 if i >= n_segments - rem else 0)
            for i in range(n_segments)]


def dense_decoder(name: str, *, n_layers: int, d_model: int, n_heads: int,
                  n_kv_heads: int, head_dim: int, d_ff: int, vocab: int,
                  n_segments: int = 6, qk_norm: bool = False,
                  window: int | None = None, act: str = "swiglu",
                  rope_theta: float = 10_000.0, tie: bool = True,
                  input_mode: str = "tokens", image_tokens: int = 0,
                  ) -> ModelConfig:
    """Standard dense GQA decoder with EE ramps at segment boundaries."""
    attn = AttnConfig(n_heads=n_heads, n_kv_heads=n_kv_heads,
                      head_dim=head_dim, qk_norm=qk_norm, window=window,
                      rope_theta=rope_theta)
    block = BlockConfig(mixer="attn", attn=attn, mlp="dense", d_ff=d_ff,
                        act=act)
    sizes = split_segments(n_layers, n_segments)
    segments = tuple(
        Segment(block=block, n_layers=s, ramp=(i < len(sizes) - 1))
        for i, s in enumerate(sizes))
    return ModelConfig(name=name, d_model=d_model, vocab=vocab,
                       segments=segments, tie_embeddings=tie,
                       input_mode=input_mode, image_tokens=image_tokens)
