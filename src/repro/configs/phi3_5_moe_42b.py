"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096, 32H GQA kv=8,
16 experts top-2 with d_ff_expert=6400, vocab=32064.
[hf:microsoft/Phi-3.5-MoE-instruct]"""

from repro.models.config import (AttnConfig, BlockConfig, ModelConfig,
                                 MoEConfig, Segment)

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def full_config() -> ModelConfig:
    attn = AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128)
    moe = MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400)
    block = BlockConfig(mixer="attn", attn=attn, mlp="moe", moe=moe)
    sizes = [4, 4, 4, 4, 4, 4, 4, 4]
    segments = tuple(
        Segment(block=block, n_layers=s, ramp=(i < len(sizes) - 1))
        for i, s in enumerate(sizes))
    return ModelConfig(name=ARCH_ID, d_model=4096, vocab=32_064,
                       segments=segments, tie_embeddings=False)


def smoke_config() -> ModelConfig:
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32)
    # cf=4 -> drop-free at smoke scale (decode/prefill parity tests)
    moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                    capacity_factor=4.0)
    block = BlockConfig(mixer="attn", attn=attn, mlp="moe", moe=moe)
    segments = (Segment(block=block, n_layers=1, ramp=True),
                Segment(block=block, n_layers=1, ramp=False))
    return ModelConfig(name=ARCH_ID + "-smoke", d_model=128, vocab=512,
                       segments=segments, tie_embeddings=False)
