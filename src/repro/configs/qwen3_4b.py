"""qwen3-4b [dense] — 36L d_model=2560, 32H GQA kv=8, d_ff=9728,
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B family; head_dim=128]"""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-4b"


def full_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID, n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=9728, vocab=151_936, n_segments=6, qk_norm=True,
        rope_theta=1_000_000.0, tie=True)


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=512, n_segments=2, qk_norm=True)
