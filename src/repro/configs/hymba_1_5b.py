"""hymba-1.5b [hybrid] — 32L d_model=1600, 25H GQA kv=5 (head_dim=64) in
parallel with Mamba heads (ssm_state=16), d_ff=5504, vocab=32001.
[arXiv:2411.13676]

Simplifications recorded in DESIGN.md §4/§6: Hymba's per-head fusion of
attention and SSM outputs is implemented as per-branch RMSNorm + average;
all layers use sliding-window attention (window 1024) — Hymba keeps 3
global layers, we fold that into the window override mechanism.  Hybrid
SW+SSM => `long_500k` runs natively.
"""

from repro.models.config import (AttnConfig, BlockConfig, ModelConfig,
                                 Segment, SSMConfig)

ARCH_ID = "hymba-1.5b"


def full_config() -> ModelConfig:
    attn = AttnConfig(n_heads=25, n_kv_heads=5, head_dim=64, window=1024)
    ssm = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                    n_groups=1, chunk=256)
    block = BlockConfig(mixer="hybrid", attn=attn, ssm=ssm, mlp="dense",
                        d_ff=5504)
    sizes = [4, 4, 4, 4, 4, 4, 4, 4]
    segments = tuple(
        Segment(block=block, n_layers=s, ramp=(i < len(sizes) - 1))
        for i, s in enumerate(sizes))
    return ModelConfig(name=ARCH_ID, d_model=1600, vocab=32_001,
                       segments=segments, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    attn = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32, window=32)
    ssm = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=32,
                    n_groups=1, chunk=32)
    block = BlockConfig(mixer="hybrid", attn=attn, ssm=ssm, mlp="dense",
                        d_ff=256)
    segments = (Segment(block=block, n_layers=1, ramp=True),
                Segment(block=block, n_layers=1, ramp=False))
    return ModelConfig(name=ARCH_ID + "-smoke", d_model=128, vocab=512,
                       segments=segments, tie_embeddings=True)
