"""mamba2-130m [ssm] — 24L d_model=768, attention-free SSD blocks,
ssm_state=128, vocab=50280.  [arXiv:2405.21060]

Attention-free: decode state is O(1) in sequence length, so the
`long_500k` shape runs natively (DESIGN.md §4).
"""

from repro.models.config import BlockConfig, ModelConfig, Segment, SSMConfig

ARCH_ID = "mamba2-130m"


def full_config() -> ModelConfig:
    ssm = SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                    n_groups=1, chunk=256)
    block = BlockConfig(mixer="ssm", ssm=ssm, mlp="none")
    sizes = [4, 4, 4, 4, 4, 4]
    segments = tuple(
        Segment(block=block, n_layers=s, ramp=(i < len(sizes) - 1))
        for i, s in enumerate(sizes))
    return ModelConfig(name=ARCH_ID, d_model=768, vocab=50_280,
                       segments=segments, tie_embeddings=True,
                       long_context_window=None)


def smoke_config() -> ModelConfig:
    ssm = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                    n_groups=1, chunk=32)
    block = BlockConfig(mixer="ssm", ssm=ssm, mlp="none")
    segments = (Segment(block=block, n_layers=1, ramp=True),
                Segment(block=block, n_layers=1, ramp=False))
    return ModelConfig(name=ARCH_ID + "-smoke", d_model=128, vocab=512,
                       segments=segments, tie_embeddings=True,
                       long_context_window=None)
