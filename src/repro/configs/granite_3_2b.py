"""granite-3-2b [dense] — 40L d_model=2048, 32H GQA kv=8, d_ff=8192,
vocab=49155.  [hf:ibm-granite/granite-3.0-2b-base]"""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "granite-3-2b"


def full_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID, n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        head_dim=64, d_ff=8192, vocab=49_155, n_segments=5, tie=True)


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=256, vocab=512, n_segments=2)
