"""Paper-native early-exit workload config: a ~100M GPT-2-small-scale dense
decoder with a ramp after every pair of layers — the analogue of the
paper's BERT-base / GPT-2 EE backbones (§6, Figs. 5) used by the
end-to-end training example and the Pareto benchmarks."""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "paper-ee-100m"


def full_config() -> ModelConfig:
    # 12L, d_model 768, 12 heads -> ~100M params @ vocab 50257, ramps
    # every 2 layers => 6 T-Tamer nodes (5 ramps + final).
    return dense_decoder(
        ARCH_ID, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        head_dim=64, d_ff=3072, vocab=50_257, n_segments=6, act="gelu",
        tie=True)


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512, n_segments=2, act="gelu")
