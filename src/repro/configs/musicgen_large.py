"""musicgen-large [audio] — 48L d_model=2048, 32H (kv=32), d_ff=8192,
vocab=2048 (EnCodec codebook), decoder-only over audio tokens.
[arXiv:2306.05284]

Frontend carve-out (DESIGN.md §4): the EnCodec/mel conv stack is a STUB —
``input_specs`` feeds precomputed frame embeddings (B, S, d_model); the
language-model decoder implemented here consumes them.
"""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "musicgen-large"


def full_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID, n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        head_dim=64, d_ff=8192, vocab=2048, n_segments=6, act="gelu",
        tie=True, input_mode="embeds")


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=256, n_segments=2, act="gelu",
        input_mode="embeds")
