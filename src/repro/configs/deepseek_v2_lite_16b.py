"""deepseek-v2-lite-16b [moe] — 27L d_model=2048, 16H MLA (kv_lora=512),
2 shared + 64 routed experts top-6, vocab=102400.  [arXiv:2405.04434]

Following the DeepSeek-V2-Lite spec the pool entry names: first layer is a
dense MLP (d_ff=10944); remaining 26 layers are MoE with 64 routed top-6 +
2 shared experts of d_ff_expert=1408 ("160 routed" in the pool line is
full-V2; the Lite model card says 64 — see DESIGN.md §4).
"""

from __future__ import annotations

from repro.models.config import (AttnConfig, BlockConfig, MLAConfig,
                                 ModelConfig, MoEConfig, Segment)

ARCH_ID = "deepseek-v2-lite-16b"


def _attn(nope=128, rope=64, v=128, lora=512, heads=16):
    return AttnConfig(
        n_heads=heads, n_kv_heads=heads, head_dim=nope + rope,
        mla=MLAConfig(kv_lora_rank=lora, qk_nope_head_dim=nope,
                      qk_rope_head_dim=rope, v_head_dim=v))


def full_config() -> ModelConfig:
    attn = _attn()
    dense0 = BlockConfig(mixer="attn", attn=attn, mlp="dense", d_ff=10944)
    moe = MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                    num_shared=2, d_ff_shared=2816)
    moe_block = BlockConfig(mixer="attn", attn=attn, mlp="moe", moe=moe)
    # 1 dense layer + 26 MoE layers; ramps every ~5 MoE layers -> 6 nodes.
    moe_sizes = [5, 5, 5, 5, 6]
    segments = [Segment(block=dense0, n_layers=1, ramp=False)]
    segments += [Segment(block=moe_block, n_layers=s,
                         ramp=(i < len(moe_sizes) - 1))
                 for i, s in enumerate(moe_sizes)]
    return ModelConfig(name=ARCH_ID, d_model=2048, vocab=102_400,
                       segments=tuple(segments), tie_embeddings=False)


def smoke_config() -> ModelConfig:
    attn = _attn(nope=32, rope=16, v=32, lora=64, heads=4)
    # cf=4 -> provably drop-free at smoke scale (decode/prefill parity
    # tests need determinism; the full config keeps the production 1.25)
    moe = MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                    num_shared=1, d_ff_shared=128, capacity_factor=4.0)
    block = BlockConfig(mixer="attn", attn=attn, mlp="moe", moe=moe)
    segments = (Segment(block=block, n_layers=1, ramp=True),
                Segment(block=block, n_layers=1, ramp=False))
    return ModelConfig(name=ARCH_ID + "-smoke", d_model=128, vocab=512,
                       segments=segments, tie_embeddings=False)
