"""Architecture registry: every assigned architecture (plus the paper's
own EE workload) selectable via ``--arch <id>``."""

from __future__ import annotations

from repro.configs import (deepseek_v2_lite_16b, granite_3_2b, hymba_1_5b,
                           mamba2_130m, musicgen_large, paper_ee,
                           phi3_5_moe_42b, phi3_vision_4_2b, qwen3_14b,
                           qwen3_4b, starcoder2_3b)

_MODULES = (
    deepseek_v2_lite_16b, qwen3_4b, qwen3_14b, mamba2_130m, hymba_1_5b,
    phi3_5_moe_42b, granite_3_2b, musicgen_large, starcoder2_3b,
    phi3_vision_4_2b, paper_ee,
)

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ASSIGNED = [m.ARCH_ID for m in _MODULES if m is not paper_ee]


def get_config(arch: str, smoke: bool = False):
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    mod = REGISTRY[arch]
    return mod.smoke_config() if smoke else mod.full_config()
