"""qwen3-14b [dense] — 40L d_model=5120, 40H GQA kv=8, d_ff=17408,
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-8B family; head_dim=128]"""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "qwen3-14b"


def full_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID, n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=17_408, vocab=151_936, n_segments=5,
        qk_norm=True, rope_theta=1_000_000.0, tie=False)


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=160, n_heads=5, n_kv_heads=1,
        head_dim=32, d_ff=320, vocab=512, n_segments=2, qk_norm=True,
        tie=False)
