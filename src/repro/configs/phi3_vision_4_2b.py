"""phi-3-vision-4.2b [vlm] — 32L d_model=3072, 32H (kv=32), d_ff=8192,
vocab=32064; phi3-mini backbone + CLIP vision.
[hf:microsoft/Phi-3-vision-128k-instruct]

Frontend carve-out (DESIGN.md §4): the CLIP/SigLIP vision encoder +
projector are a STUB — ``input_specs`` feeds pre-projected patch
embeddings (B, image_tokens, d_model) concatenated before the text
tokens; the language decoder here consumes the merged stream.
"""

from repro.configs.common import dense_decoder
from repro.models.config import ModelConfig

ARCH_ID = "phi-3-vision-4.2b"


def full_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID, n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        head_dim=96, d_ff=8192, vocab=32_064, n_segments=8, tie=False,
        input_mode="multimodal", image_tokens=256)


def smoke_config() -> ModelConfig:
    return dense_decoder(
        ARCH_ID + "-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab=512, n_segments=2, tie=False,
        input_mode="multimodal", image_tokens=8)
