"""repro.sharding"""
