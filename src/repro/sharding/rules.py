"""Logical-axis -> mesh-axis sharding rules.

Weights / activations declare *logical* axes ("batch", "heads", "mlp",
"experts", "vocab", ...); a RuleSet lowers them to PartitionSpecs for a
concrete mesh, gating every assignment on divisibility (non-divisible dims
fall back to replication, e.g. granite's vocab=49155 on a 16-way model
axis — see DESIGN.md §5).

The BASELINE rules are Megatron-style tensor parallelism on the "model"
axis + (pod, data) batch parallelism.  Alternative rule sets (the perf
hillclimb's lever) are constructed by ``RuleSet.override``.
"""

from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["RuleSet", "BASELINE_RULES", "spec_for", "sharding_tree"]


@dataclasses.dataclass(frozen=True)
class RuleSet:
    """Mapping logical axis -> tuple of mesh axes (in sharding order)."""
    rules: dict

    def override(self, **kw) -> "RuleSet":
        r = dict(self.rules)
        for k, v in kw.items():
            r[k] = tuple(v) if v else ()
        return RuleSet(rules=r)

    def mesh_axes(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.rules.get(logical, ()))


BASELINE_RULES = RuleSet(rules={
    # data parallelism
    "batch": ("pod", "data"),
    # tensor parallelism (Megatron layout)
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "conv_dim": ("model",),
    # KV-cache sequence dim: sharded over "model" when kv-head sharding
    # isn't divisible (context-parallel decode; see launch/shapes.py)
    "kv_len": ("model",),
    # replicated by default
    "embed": (),
    "layers": (),
    "seq": (),
})

# Training shards weights 2-D: tensor-parallel on "model" AND fsdp-style on
# "data" along the embed (fan-in) dim — f32 master params + AdamW moments
# don't fit a 16 GiB chip under pure TP (EXPERIMENTS.md §Dry-run).
FSDP_TRAIN_RULES = BASELINE_RULES.override(embed=("data",))

# GQA-factorized mesh rules (mesh layout "gqa": model=8 x model2=2).
# Attention dims shard on the kv-aligned 8-way factor only; everything
# wide (FFN hidden, experts, vocab) spans both factors (16-way).
GQA_RULES = BASELINE_RULES.override(
    heads=("model",), kv_heads=("model",),
    mlp=("model", "model2"), experts=("model", "model2"),
    vocab=("model", "model2"), conv_dim=("model", "model2"))


def _axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    size = 1
    for n in names:
        if n in mesh.shape:
            size *= mesh.shape[n]
    return size


def spec_for(mesh: Mesh, rules: RuleSet, shape: tuple[int, ...],
             axes: tuple[str | None, ...]) -> P:
    """PartitionSpec for one array, with divisibility gating."""
    entries = []
    used: set[str] = set()
    for dim, logical in zip(shape, axes):
        names = tuple(n for n in rules.mesh_axes(logical)
                      if n in mesh.shape and n not in used)
        if names and dim % _axis_size(mesh, names) == 0:
            entries.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_tree(mesh: Mesh, rules: RuleSet, defs):
    """NamedSharding tree for a ParamDef tree."""
    from repro.models.param import ParamDef

    def one(d: ParamDef):
        return NamedSharding(mesh, spec_for(mesh, rules, d.shape, d.axes))

    return jax.tree.map(one, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))
