"""Activation-sharding context.

Model code is mesh-agnostic; the launcher installs the batch mesh axes
here and ``constrain_batch`` anchors the residual stream's sharding at
segment boundaries (GSPMD propagation alone drops batch sharding after
the vocab-sharded embedding gather — EXPERIMENTS.md §Dry-run).  Outside a
launcher (CPU unit tests) the context is empty and everything no-ops.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["activation_sharding", "constrain_batch"]

_BATCH_AXES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_batch_axes", default=None)


@contextlib.contextmanager
def activation_sharding(batch_axes):
    """batch_axes: mesh-axis tuple for the batch dim, e.g. ("pod","data")."""
    tok = _BATCH_AXES.set(tuple(batch_axes) if batch_axes else None)
    try:
        yield
    finally:
        _BATCH_AXES.reset(tok)


def constrain_batch(x: jax.Array, batch_dim: int = 0) -> jax.Array:
    """Constrain dim `batch_dim` of x to the installed batch axes (no-op
    when no context is installed or the dim doesn't divide)."""
    axes = _BATCH_AXES.get()
    if axes is None:
        return x
    # divisibility guard: decode-time groups/batches of 1 stay unsharded
    mesh = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
    if mesh is not None and getattr(mesh, "shape", None):
        size = 1
        for a in axes:
            size *= mesh.shape.get(a, 1)
        if size and x.shape[batch_dim] % size != 0:
            return x
    # NOTE: None dims force replication — the right anchor for the
    # residual stream.  (P.UNCONSTRAINED was tried and REFUTED: GSPMD
    # picked pathological shardings, wire 8x — EXPERIMENTS.md §Perf.)
    entries = [None] * x.ndim
    entries[batch_dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, P(*entries))


def constrain_expert(x: jax.Array, batch_dim: int = 0,
                     expert_dim: int = 1) -> jax.Array:
    """MoE dispatch/hidden/combine buffers: group dim on the batch axes,
    expert dim on "model", everything else replicated."""
    axes = _BATCH_AXES.get()
    if axes is None:
        return x
    mesh = getattr(jax.sharding, "get_abstract_mesh", lambda: None)()
    shape = getattr(mesh, "shape", None) or {}
    bsz = 1
    for a in axes:
        bsz *= shape.get(a, 1)
    entries: list = [None] * x.ndim
    if bsz and x.shape[batch_dim] % bsz == 0:
        entries[batch_dim] = axes if len(axes) > 1 else axes[0]
    if x.shape[expert_dim] % shape.get("model", 1) == 0:
        entries[expert_dim] = "model"
    return jax.lax.with_sharding_constraint(x, P(*entries))
