"""Train an early-exit LM end-to-end on the synthetic pipeline.

Trains the paper-native EE config (paper-ee-100m; ~160M params with ramps
every 2 layers) — or its smoke variant — with the multi-ramp objective,
then exports per-ramp calibration traces for T-Tamer.

  # fast demo (smoke config, ~1 min):
  PYTHONPATH=src python examples/train_ee.py --smoke --steps 60
  # the real thing (few hundred steps of the 100M model):
  PYTHONPATH=src python examples/train_ee.py --steps 300 --batch 8
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, batches
from repro.models import model as M
from repro.models.param import materialize
from repro.training import checkpoint
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ee_ckpt")
    args = ap.parse_args()

    cfg = get_config("paper-ee-100m", smoke=args.smoke)
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"ramps={cfg.n_ramps}")
    opt_cfg = AdamWConfig(lr=6e-4, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1))
    params = materialize(M.model_defs(cfg), jax.random.PRNGKey(0))
    data = batches(DataConfig(vocab=cfg.vocab, seq_len=args.seq + 1,
                              global_batch=args.batch))
    params, _, history = train(cfg, opt_cfg, params, data,
                               steps=args.steps, ckpt_dir=args.ckpt_dir)
    first, last = history[0], history[-1]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"({args.steps} steps)")

    # Export calibration traces: per-ramp loss proxies on held-out data.
    print("exporting calibration traces ...")
    cal = next(data)
    logits, caches, node_losses, _ = M.prefill(
        params, cfg, {"tokens": jnp.asarray(cal["tokens"])},
        cache_len=args.seq + 8)
    path = f"{args.ckpt_dir}/calibration.npz"
    np.savez(path, node_losses=np.asarray(node_losses))
    print(f"saved {node_losses.shape} node-loss traces to {path}")
    print(f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
